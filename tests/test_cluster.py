"""Clustering subsystem (DESIGN.md section 9): the k-mode degenerate-input
regressions, device-engine vs host-oracle bit-parity, the compile-cache
discipline of the packed engine, and the online ClusterIndex contracts
(incremental assignment, per-cluster bookkeeping, refit invariance,
snapshot round-trips)."""

import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests._hyp import given, settings, st

from repro.cluster import ClusterIndex
from repro.core import CabinParams, allpairs
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix, hamming_matrix_exact
from repro.core.kmode import (_modes, _seed_indices, kmode, kmode_packed,
                              kmode_precomputed)
from repro.index import QueryEngine

N_DIMS = 400
D = 256
P = CabinParams.create(N_DIMS, D, seed=1)

_cham_jit = jax.jit(cham_matrix, static_argnums=2)
_ham_jit = jax.jit(hamming_matrix_exact)


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for i in range(n):
        density = int(rng.integers(15, 60))
        idx = rng.choice(N_DIMS, size=density, replace=False)
        x[i, idx] = rng.integers(1, 8, size=density)
    return x


X = _rows(96, seed=0)
SK = np.asarray(sketch_dense(P, jnp.asarray(X)))


def _dist_fn(metric):
    """Host-oracle dense distance callback of the engine's metric."""
    if metric == "cham":
        return lambda a, b: np.asarray(
            _cham_jit(jnp.asarray(a), jnp.asarray(b), D))
    return lambda a, b: np.asarray(
        _ham_jit(jnp.asarray(a), jnp.asarray(b))).astype(np.float32)


# ---------------------------------------------------------------------------
# seeding / degenerate-input regressions (the primary bugfixes)
# ---------------------------------------------------------------------------


def test_kmode_all_duplicates_does_not_crash():
    """An all-duplicates matrix collapses the k-means++ min-distance vector
    to zero; the seeding used to die with 'Probabilities do not sum to 1.'
    and must now fall back to uniform sampling."""
    x = np.repeat(X[:1], 12, axis=0)
    labels, centers = kmode(x, 3, n_iter=4)
    assert labels.shape == (12,)
    # every row is identical, so every row lands in one cluster
    assert len(np.unique(labels)) == 1
    np.testing.assert_array_equal(centers[labels[0]], x[0])


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_kmode_precomputed_all_duplicates_both_modes(metric):
    sk = np.repeat(SK[:1], 10, axis=0)
    oracle = kmode_precomputed(_dist_fn(metric), sk, k=4, seed=3, n_iter=3)
    engine = kmode_precomputed(None, sk, k=4, seed=3, n_iter=3,
                               sketch_dim=D, metric=metric)
    np.testing.assert_array_equal(oracle, engine)
    assert len(np.unique(oracle)) == 1


def test_kmode_k_exceeds_n_rows():
    """k > n: the seeding pool runs dry and must reuse rows (duplicate
    centres are unavoidable) instead of crashing."""
    labels, _ = kmode(X[:3], 5, n_iter=2)
    assert labels.shape == (3,) and labels.max() < 5
    for metric in ("cham", "hamming"):
        oracle = kmode_precomputed(_dist_fn(metric), SK[:3], k=5, seed=1)
        engine = kmode_precomputed(None, SK[:3], k=5, seed=1, sketch_dim=D,
                                   metric=metric)
        np.testing.assert_array_equal(oracle, engine)


def test_seeding_returns_distinct_indices():
    """Whenever k <= n the seeding must return k DISTINCT medoid indices —
    sampling with replacement used to let a concentrated p elect the same
    medoid twice (a permanently dead cluster).  Exercised on duplicate-heavy
    data where the old path crashed or repeated."""
    sk = np.concatenate([np.repeat(SK[:1], 5, axis=0),
                         np.repeat(SK[1:2], 5, axis=0),
                         np.repeat(SK[2:3], 5, axis=0)])
    ref = np.asarray(_cham_jit(jnp.asarray(sk), jnp.asarray(sk), D))
    for seed in range(6):
        rng = np.random.default_rng(seed)
        idx = _seed_indices(len(sk), 7, rng,
                            lambda i: ref[:, i].astype(np.float64))
        assert len(np.unique(idx)) == 7  # distinct even past the 3 groups


def test_modes_empty_cluster_keeps_previous_center():
    """An empty cluster's centre must stay put — the all-zeros placeholder
    it used to get sits at the low-category corner and attracts rows on the
    next assignment pass."""
    x = np.asarray([[3, 3, 3], [3, 3, 3], [1, 1, 1]], np.int32)
    labels = np.asarray([0, 0, 2])  # cluster 1 is empty
    prev = np.asarray([[9, 9, 9], [7, 7, 7], [5, 5, 5]], np.int32)
    centers = _modes(x, labels, 3, 9, prev_centers=prev)
    np.testing.assert_array_equal(centers[1], prev[1])  # unchanged
    np.testing.assert_array_equal(centers[0], [3, 3, 3])
    np.testing.assert_array_equal(centers[2], [1, 1, 1])


def test_api_boundary_validation():
    """k >= 1, n_iter >= 1, non-empty x — clear ValueErrors instead of the
    old `int(x.max())` crash on empty input and obscure downstream shape
    errors for k = 0."""
    empty = np.zeros((0, 5), np.int32)
    for bad in (lambda: kmode(X[:4], 0),
                lambda: kmode(X[:4], 2, n_iter=0),
                lambda: kmode(empty, 2),
                lambda: kmode(X[0], 2),  # 1-d input
                lambda: kmode_precomputed(_dist_fn("cham"), SK[:4], 0),
                lambda: kmode_precomputed(_dist_fn("cham"), SK[:4], 2,
                                          n_iter=0),
                lambda: kmode_precomputed(_dist_fn("cham"), SK[:0], 2),
                lambda: kmode_precomputed(None, SK[:4], 2),  # no dist_fn
                lambda: kmode_precomputed(_dist_fn("cham"), SK[:4], 2,
                                          batch_rows=8),  # oracle minibatch
                lambda: kmode_packed(SK[:4], 0, d=D),
                lambda: kmode_packed(SK[:0], 2, d=D),
                lambda: kmode_packed(SK[:4], 2, d=D, n_iter=0),
                lambda: kmode_packed(SK[:4], 2, d=D, batch_rows=0)):
        with pytest.raises(ValueError):
            bad()


# ---------------------------------------------------------------------------
# device engine vs host oracle: the full-batch bit-parity contract
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**16), st.integers(1, 7))
def test_packed_engine_equals_host_oracle(seed, k):
    """Full-batch device labels are bit-identical to the host oracle on the
    same rng sequence — both metrics, including duplicate-heavy inputs and
    k >= #distinct rows (the cases that crashed before the seeding fix)."""
    rng = np.random.default_rng(seed)
    metric = ("cham", "hamming")[seed % 2]
    if seed % 3 == 0:
        # duplicate-heavy: a handful of distinct rows, many copies
        base = SK[rng.choice(96, size=int(rng.integers(1, 6)), replace=False)]
        sk = base[rng.integers(0, len(base), size=40)]
    else:
        sk = SK[rng.choice(96, size=int(rng.integers(8, 60)), replace=False)]
    oracle = kmode_precomputed(_dist_fn(metric), sk, k=k, seed=seed % 11,
                               n_iter=8)
    engine = kmode_precomputed(None, sk, k=k, seed=seed % 11, n_iter=8,
                               sketch_dim=D, metric=metric)
    np.testing.assert_array_equal(oracle, engine)


def test_kmode_packed_result_is_consistent():
    """The KmodeResult invariants an online consumer relies on: medoids are
    row indices whose rows equal the centres, labels equal a one-shot
    assignment against those centres, and each non-empty cluster's medoid
    belongs to it."""
    res = kmode_packed(SK, 5, d=D, n_iter=10, seed=2)
    np.testing.assert_array_equal(SK[res.medoids], res.centers)
    lab, _ = allpairs.argmin_rows(SK, res.centers, d=D)
    np.testing.assert_array_equal(res.labels, lab)
    for c in range(5):
        if (res.labels == c).any():
            assert res.labels[res.medoids[c]] == c


def test_labels_match_final_centers_even_when_unconverged():
    """An n_iter-exhausted run must still return labels assigned against
    the RETURNED centres (the loop's last medoid update used to land after
    the last assignment), and k=1 must actually elect its medoid (the
    zero-initialised label state used to read an all-zeros first
    assignment as instant convergence)."""
    for n_iter in (1, 2):
        res = kmode_packed(SK, 5, d=D, n_iter=n_iter, seed=0)
        lab, _ = allpairs.argmin_rows(SK, res.centers, d=D)
        np.testing.assert_array_equal(res.labels, lab)
        oracle = kmode_precomputed(_dist_fn("cham"), SK, 5, n_iter=n_iter,
                                   seed=0)
        np.testing.assert_array_equal(res.labels, oracle)  # parity holds
    res1 = kmode_packed(SK, 1, d=D, n_iter=5, seed=2)
    totals = allpairs.rowsum(SK, d=D)
    assert res1.medoids[0] == int(np.argmin(totals))
    # the host kmode path shares the fix: k=1 centres are the attribute
    # modes of the whole data, not the random k-means++ seed row
    from repro.core.kmode import _modes
    labels1, centers1 = kmode(X[:20], 1, n_iter=4)
    want = _modes(X[:20], np.zeros(20, np.int64), 1, int(X[:20].max()))
    np.testing.assert_array_equal(centers1, want)


def test_minibatch_mode_runs_and_is_deterministic():
    """Mini-batch is the documented deviation: not bit-identical to
    full-batch, but deterministic in (data, seed) and consistent — the
    returned labels are a one-shot assignment against the final centres."""
    a = kmode_packed(SK, 4, d=D, n_iter=4, seed=5, batch_rows=24)
    b = kmode_packed(SK, 4, d=D, n_iter=4, seed=5, batch_rows=24)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.centers, b.centers)
    lab, _ = allpairs.argmin_rows(SK, a.centers, d=D)
    np.testing.assert_array_equal(a.labels, lab)
    assert a.labels.shape == (96,) and a.labels.max() < 4


def test_kmode_packed_compile_cache_stays_bounded():
    """The centre block is pow2-padded once with a traced valid count and
    member gathers are pow2-bucketed, so a whole multi-iteration run
    compiles O(log n) graphs — NOT one per iteration or per cluster size —
    and an identical re-run compiles nothing (same discipline as
    test_argmin_rows_bucketed_no_recompile)."""
    kw = dict(d=D, n_iter=12, seed=7)
    before_a = allpairs._argmin_rows_impl._cache_size()
    before_r = allpairs._rowsum_impl._cache_size()
    kmode_packed(SK, 5, **kw)
    grow_a = allpairs._argmin_rows_impl._cache_size() - before_a
    grow_r = allpairs._rowsum_impl._cache_size() - before_r
    # 96 rows -> member buckets within {8,16,32,64,128}; centre block is one
    # 8-row bucket.  5 clusters x 12 iterations would be 60 without bucketing.
    assert grow_a <= 3, grow_a
    assert grow_r <= 5, grow_r
    mid_a = allpairs._argmin_rows_impl._cache_size()
    mid_r = allpairs._rowsum_impl._cache_size()
    kmode_packed(SK, 5, **kw)  # identical replay: zero new graphs
    assert allpairs._argmin_rows_impl._cache_size() == mid_a
    assert allpairs._rowsum_impl._cache_size() == mid_r


# ---------------------------------------------------------------------------
# ClusterIndex: online centres over the live index
# ---------------------------------------------------------------------------


def test_cluster_index_bootstrap_and_incremental_assignment():
    eng = QueryEngine(P, cache_entries=4)
    ci = eng.cluster(4, seed=0, n_iter=8)
    assert not ci.fitted
    ids = eng.add_dense(X[:48])  # first add bootstraps a fit
    assert ci.fitted and ci.n_refits == 1
    ref = kmode_packed(SK[:48], 4, d=D, n_iter=8, seed=0)
    lab_ids, lab = ci.labels()
    np.testing.assert_array_equal(lab_ids, ids)
    np.testing.assert_array_equal(lab, ref.labels)
    np.testing.assert_array_equal(ci.counts, np.bincount(ref.labels,
                                                         minlength=4))
    # incremental adds (through the ENGINE, not the wrapper: the store hook
    # must observe them) are assigned against the current centres exactly
    # as argmin would
    ids2 = eng.add_dense(X[48:64])
    want, _ = allpairs.argmin_rows(SK[48:64], ref.centers, d=D)
    np.testing.assert_array_equal(ci.label_of(ids2), want)
    assert ci.counts.sum() == 64
    # per-cluster weights mirror the store's sketch weights
    store_w = eng.store.weights()
    _, all_lab = ci.labels()
    np.testing.assert_array_equal(
        ci.weights, np.bincount(all_lab, weights=store_w,
                                minlength=4).astype(np.int64))
    # the wrapper returns (ids, labels) in one call
    ids3, lab3 = ci.add_dense(X[64:70])
    np.testing.assert_array_equal(lab3, ci.label_of(ids3))
    # read-only classification agrees with what ingest would assign
    np.testing.assert_array_equal(ci.assign(X[64:70]), lab3)
    np.testing.assert_array_equal(ci.assign_packed(SK[64:70]), lab3)


def test_cluster_index_remove_compact_bookkeeping():
    eng = QueryEngine(P)
    ci = ClusterIndex(eng, 3, seed=1, n_iter=6)
    ids = eng.add_dense(X[:40])
    lab_before = ci.label_of(ids)
    eng.remove(ids[5:15])
    want = np.bincount(np.delete(lab_before, np.s_[5:15]), minlength=3)
    np.testing.assert_array_equal(ci.counts, want)
    with pytest.raises(KeyError):
        ci.label_of(ids[7])
    # compaction renumbers slots but not ids: labels survive untouched
    lab_ids0, lab0 = ci.labels()
    eng.compact()
    lab_ids1, lab1 = ci.labels()
    np.testing.assert_array_equal(lab_ids0, lab_ids1)
    np.testing.assert_array_equal(lab0, lab1)
    assert ci.counts.sum() == 30


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16), st.lists(st.integers(1, 14), min_size=1,
                                       max_size=5))
def test_cluster_refit_invariant_across_histories(seed, chunks):
    """The acceptance property: same final membership => same centres and
    labels after refit, no matter the add/remove/compact history (and after
    a snapshot round-trip)."""
    rng = np.random.default_rng(seed)
    eng = QueryEngine(P)
    ci = ClusterIndex(eng, 3, seed=2, n_iter=6)
    pos = 0
    for c in chunks:
        take = X[pos: pos + c]
        if len(take) == 0:
            break
        eng.add_dense(take)
        pos += len(take)
        alive = eng.ids()
        if len(alive) > 3 and rng.random() < 0.6:
            kk = int(rng.integers(1, max(2, len(alive) // 3)))
            eng.remove(rng.choice(alive, size=kk, replace=False))
        if rng.random() < 0.3:
            eng.compact()
    survivors = eng.ids()
    if len(survivors) == 0:
        return
    lab = ci.refit()
    # fresh build from the survivors: same membership, trivial history
    fresh_eng = QueryEngine(P)
    fresh = ClusterIndex(fresh_eng, 3, seed=2, n_iter=6)
    fresh_eng.add_dense(X[survivors])
    flab = fresh.refit()
    np.testing.assert_array_equal(lab, flab)
    np.testing.assert_array_equal(ci.centers, fresh.centers)
    np.testing.assert_array_equal(ci.counts, fresh.counts)
    # snapshot round-trip: the restored index refits identically too
    with tempfile.TemporaryDirectory() as td:
        ci.save(td, step=1)
        back = ClusterIndex.restore(td)
    np.testing.assert_array_equal(back.labels()[1], ci.labels()[1])
    np.testing.assert_array_equal(back.refit(), lab)


def test_cluster_index_save_restore_exact_state():
    """Restore reproduces the EXACT live state — including labels assigned
    incrementally since the last refit, which a re-fit would not — and the
    restored index keeps serving mutations."""
    eng = QueryEngine(P)
    ci = ClusterIndex(eng, 4, seed=0, n_iter=8)
    eng.add_dense(X[:50])
    eng.add_dense(X[50:70])  # incremental, post-refit labels
    assert ci.n_refits == 1 and ci.mutations_since_refit == 20
    with tempfile.TemporaryDirectory() as td:
        ci.save(td, step=2)
        back = ClusterIndex.restore(td)
    np.testing.assert_array_equal(back.labels()[0], ci.labels()[0])
    np.testing.assert_array_equal(back.labels()[1], ci.labels()[1])
    np.testing.assert_array_equal(back.counts, ci.counts)
    np.testing.assert_array_equal(back.weights, ci.weights)
    np.testing.assert_array_equal(back.centers, ci.centers)
    np.testing.assert_array_equal(back.medoid_ids, ci.medoid_ids)
    assert back.mutations_since_refit == 20 and back.n_refits == 1
    # the restored store hook is live: new rows get labels on arrival
    ids, lab = back.add_dense(X[70:76])
    want, _ = allpairs.argmin_rows(SK[70:76], ci.centers, d=D)
    np.testing.assert_array_equal(lab, want)


def test_cluster_index_refit_every_and_empty_store():
    eng = QueryEngine(P)
    ci = ClusterIndex(eng, 2, seed=0, n_iter=4, refit_every=10)
    eng.add_dense(X[:8])  # bootstrap fit
    assert ci.n_refits == 1
    eng.add_dense(X[8:20])  # 12 mutations >= 10: auto-refit
    assert ci.n_refits == 2 and ci.mutations_since_refit == 0
    # draining the store resets to the unfitted state; the next add
    # bootstraps again
    eng.remove(eng.ids())
    ci.refit()
    assert not ci.fitted and ci.counts.sum() == 0
    with pytest.raises(RuntimeError, match="no centres"):
        ci.assign(X[:2])
    eng.add_dense(X[:6])
    assert ci.fitted and ci.counts.sum() == 6
    # validation
    with pytest.raises(ValueError):
        ClusterIndex(QueryEngine(P), 0)
    with pytest.raises(ValueError):
        ClusterIndex(QueryEngine(P), 2, n_iter=0)
    with pytest.raises(ValueError):
        ClusterIndex(QueryEngine(P), 2, refit_every=0)


def test_cluster_index_detach_and_empty_assign():
    """detach() stops the store hook (no double bookkeeping after
    attaching a replacement index), and assign/assign_packed handle an
    empty query batch instead of crashing on the (0, 0) topk result."""
    eng = QueryEngine(P)
    ci = eng.cluster(3, seed=0, n_iter=4)
    eng.add_dense(X[:16])
    assert ci.assign(X[:0]).shape == (0,)
    assert ci.assign_packed(SK[:0]).shape == (0,)
    assert ci.label_of([]).shape == (0,)
    ci.detach()
    n_before = len(ci.labels()[0])
    eng.add_dense(X[16:24])  # no longer observed
    assert len(ci.labels()[0]) == n_before
    ci2 = eng.cluster(3, seed=1, n_iter=4)  # replacement tracks alone
    eng.add_dense(X[24:30])
    assert len(ci2.labels()[0]) == 30 and len(ci.labels()[0]) == n_before


def test_cluster_index_restore_keeps_refit_every_and_mode():
    """save/restore round-trips the auto-refit policy (it used to come
    back disabled) and the centre engine inherits the parent's tile mode."""
    eng = QueryEngine(P, mode="popcount")
    ci = ClusterIndex(eng, 2, seed=0, n_iter=4, refit_every=7)
    eng.add_dense(X[:12])
    assert ci._centre_engine.mode == "popcount"
    with tempfile.TemporaryDirectory() as td:
        ci.save(td, step=1)
        back = ClusterIndex.restore(td)
    assert back.refit_every == 7
    back.engine.add_dense(X[12:20])  # 8 mutations >= 7: auto-refit fires
    assert back.n_refits == ci.n_refits + 1


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_cluster_index_metric_follows_engine(metric):
    """The index clusters under the ENGINE's metric: refit labels equal the
    device engine run with that metric on the same membership."""
    eng = QueryEngine(P, metric=metric)
    ci = ClusterIndex(eng, 3, seed=4, n_iter=6)
    eng.add_dense(X[:32])
    ref = kmode_packed(SK[:32], 3, d=D, n_iter=6, seed=4, metric=metric)
    np.testing.assert_array_equal(ci.labels()[1], ref.labels)
    ids2 = eng.add_dense(X[32:40])
    want, _ = allpairs.argmin_rows(SK[32:40], ref.centers, d=D,
                                   metric=metric)
    np.testing.assert_array_equal(ci.label_of(ids2), want)
