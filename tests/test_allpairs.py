"""Streaming all-pairs engine vs dense references, and end-to-end
equivalence of the rewired dedup / k-mode consumers."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import allpairs
from repro.core.cabin import CabinParams, sketch_dense
from repro.core.cham import cham_matrix, hamming_matrix_exact
from repro.core.kmode import kmode_precomputed
from repro.data.dedup import (dedup_by_sketch, dedup_by_sketch_blocked,
                              docs_to_categorical, sketch_corpus)
from repro.data.pipeline import synthetic_documents

D = 512
_cham_jit = jax.jit(cham_matrix, static_argnums=2)


def _sketches(n_rows=96, n=2500, density=150, seed=0):
    rng = np.random.default_rng(seed)
    x = np.zeros((n_rows, n), np.int32)
    for i in range(n_rows):
        idx = rng.choice(n, size=density, replace=False)
        x[i, idx] = rng.integers(1, 10, size=density)
    p = CabinParams.create(n, D, seed=1)
    return np.asarray(sketch_dense(p, jnp.asarray(x)))


SK = _sketches()
REF = np.asarray(_cham_jit(jnp.asarray(SK), jnp.asarray(SK), D))
IU = np.triu_indices(len(SK), 1)


# ---------------------------------------------------------------------------
# threshold candidate extraction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["matmul", "popcount", "pallas"])
@pytest.mark.parametrize("block", [17, 64, 96])
def test_threshold_pairs_matches_dense(mode, block):
    thr = float(np.percentile(REF[IU], 10))
    got = allpairs.threshold_pairs(SK, d=D, threshold=thr, block=block,
                                   mode=mode)
    want = {(i, j) for i, j in zip(*IU) if REF[i, j] < thr}
    assert {tuple(p) for p in got} == want
    assert got.dtype == np.int32 and got.shape[1] == 2


def test_threshold_pairs_overflow_retry():
    thr = float(np.percentile(REF[IU], 50))  # lots of candidates
    got = allpairs.threshold_pairs(SK, d=D, threshold=thr, block=32,
                                   capacity=4)  # forces doubling re-runs
    want = {(i, j) for i, j in zip(*IU) if REF[i, j] < thr}
    assert {tuple(p) for p in got} == want


def test_threshold_pairs_asymmetric_and_hamming():
    b = SK[:30]
    ref_ab = np.asarray(hamming_matrix_exact(jnp.asarray(SK), jnp.asarray(b)))
    thr = float(np.percentile(ref_ab, 15))
    got = allpairs.threshold_pairs(SK, b, d=D, threshold=thr,
                                   metric="hamming", block=25)
    want = set(zip(*np.where(ref_ab < thr)))
    assert {tuple(p) for p in got} == want


def test_threshold_pairs_empty_result():
    got = allpairs.threshold_pairs(SK, d=D, threshold=-1.0, block=64)
    assert got.shape == (0, 2)


def _off_boundary_threshold(vals: np.ndarray, q: float) -> float:
    """A threshold near the q-th percentile that sits in a wide gap of the
    distance distribution: the banded path's log-free comparison is exactly
    equivalent in real arithmetic but can flip knife-edge pairs whose
    distance EQUALS the threshold to the last float ulp."""
    s = np.unique(np.sort(vals))
    k = int(np.clip(np.searchsorted(s, np.percentile(vals, q)), 1, len(s) - 1))
    for off in range(len(s) - k - 1):
        lo, hi = s[k - 1 + off], s[k + off]
        if hi - lo > 1e-2:
            return float((lo + hi) / 2)
    return float(s[-1] + 1.0)


@pytest.mark.parametrize("block", [16, 32, 96])
def test_threshold_pairs_banded_matches_dense(block):
    """Weight-sorted banded fast path: same candidate set as the dense
    reference — the band bound (cham >= 2|a_hat - b_hat|) never drops a
    true candidate."""
    order = np.argsort(
        np.unpackbits(np.ascontiguousarray(SK).view(np.uint8), axis=1)
        .sum(axis=1), kind="stable")
    sks = SK[order]
    refs = np.asarray(_cham_jit(jnp.asarray(sks), jnp.asarray(sks), D))
    for q in [5, 40]:
        thr = _off_boundary_threshold(refs[IU], q)
        got = allpairs.threshold_pairs(sks, d=D, threshold=thr, block=block,
                                       sorted_by_weight=True)
        want = {(i, j) for i, j in zip(*IU) if refs[i, j] < thr}
        assert {tuple(p) for p in got} == want


def test_threshold_pairs_banded_rejects_unsorted():
    with pytest.raises(ValueError, match="not sorted"):
        # SK is in random order with overwhelming probability
        allpairs.threshold_pairs(SK, d=D, threshold=10.0,
                                 sorted_by_weight=True)


# ---------------------------------------------------------------------------
# row-wise reductions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["matmul", "popcount"])
def test_argmin_rows_matches_dense(mode):
    rng = np.random.default_rng(3)
    centers = SK[rng.choice(len(SK), 7, replace=False)]
    refc = np.asarray(_cham_jit(jnp.asarray(SK), jnp.asarray(centers), D))
    for block in [3, 7]:
        idxs, vals = allpairs.argmin_rows(SK, centers, d=D, block=block,
                                          mode=mode)
        np.testing.assert_array_equal(idxs, refc.argmin(axis=1))
        np.testing.assert_allclose(vals, refc.min(axis=1), rtol=1e-6)


def test_topk_rows_matches_dense():
    idxs, vals = allpairs.topk_rows(SK, SK, 5, d=D, block=41)
    order = np.argsort(REF, axis=1, kind="stable")[:, :5]
    np.testing.assert_array_equal(idxs, order)
    np.testing.assert_allclose(vals, np.take_along_axis(REF, order, axis=1),
                               rtol=1e-6)
    # self is always the nearest neighbour at (near-)zero distance
    np.testing.assert_array_equal(idxs[:, 0], np.arange(len(SK)))
    assert float(np.abs(vals[:, 0]).max()) < 1e-3


def test_topk_rows_tie_break_across_tiles():
    """Duplicate rows scattered across tile boundaries => equal distances
    straddling the k cut; the O(k) lax.top_k merge must keep the LOWER
    column, exactly like the stable argsort it replaced."""
    b = np.concatenate([SK[:20], SK[:20], SK[:20]])  # 3 copies, cols i, i+20, i+40
    refd = np.asarray(_cham_jit(jnp.asarray(SK[:10]), jnp.asarray(b), D))
    order = np.argsort(refd, axis=1, kind="stable")[:, :5]
    for block in [7, 16, 60]:  # copies split across tiles every which way
        idxs, vals = allpairs.topk_rows(SK[:10], b, 5, d=D, block=block)
        np.testing.assert_array_equal(idxs, order)
        np.testing.assert_array_equal(
            vals, np.take_along_axis(refd, order, axis=1))


def test_argmin_rows_bucketed_no_recompile():
    """m is traced and b is pow2-bucketed: the k-mode medoid loop's drifting
    cluster sizes must reuse one compiled graph per bucket."""
    centers = SK[:13]
    before = allpairs._argmin_rows_impl._cache_size()
    for m in (5, 6, 7, 8):
        idxs, vals = allpairs.argmin_rows(SK[:10], centers[:m], d=D)
        ref = np.asarray(_cham_jit(jnp.asarray(SK[:10]),
                                   jnp.asarray(centers[:m]), D))
        np.testing.assert_array_equal(idxs, ref.argmin(axis=1))
        np.testing.assert_allclose(vals, ref.min(axis=1), rtol=1e-6)
    # all four sizes bucket to 8 rows -> exactly one new compile
    assert allpairs._argmin_rows_impl._cache_size() == before + 1


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_topk_rows_banded_matches_full_scan(metric):
    """Progressive band expansion returns exactly the full scan's answer —
    positions, values, and (value, key) tie-break — for both the default
    positional keys and a shuffled external-id keying."""
    from repro.core.packing import np_popcount_rows

    weights = np_popcount_rows(SK)
    order = np.argsort(weights, kind="stable")
    sks = SK[order]
    w_sorted = weights[order]
    n = len(sks)
    band_rows = 8
    n_bands = -(-n // band_rows)
    scores = allpairs.prune_score_host(w_sorted, D, metric)
    band_lo = np.asarray([scores[b * band_rows] for b in range(n_bands)])
    band_hi = np.asarray(
        [scores[min((b + 1) * band_rows, n) - 1] for b in range(n_bands)])
    q = SK[:7]
    q_scores = allpairs.prune_score_host(np_popcount_rows(q), D, metric)

    pos, vals = allpairs.topk_rows_banded(
        q, jnp.asarray(sks), 5, d=D, metric=metric, q_scores=q_scores,
        band_lo=band_lo, band_hi=band_hi, band_rows=band_rows, n_valid=n,
        block=32)
    ref_i, ref_v = allpairs.topk_rows(q, sks, 5, d=D, metric=metric)
    np.testing.assert_array_equal(pos, ref_i)
    np.testing.assert_array_equal(vals, ref_v)

    # external-id keying: results must match the full scan over the rows
    # REARRANGED in key order (ties -> lower key), mapped back to positions
    ids = np.random.default_rng(5).permutation(n).astype(np.int64)
    key_order = np.argsort(ids, kind="stable")
    ref_ki, ref_kv = allpairs.topk_rows(q, sks[key_order], 5, d=D,
                                        metric=metric)
    pos2, vals2 = allpairs.topk_rows_banded(
        q, jnp.asarray(sks), 5, d=D, metric=metric, q_scores=q_scores,
        band_lo=band_lo, band_hi=band_hi, band_rows=band_rows, n_valid=n,
        order_by=ids, block=32)
    np.testing.assert_array_equal(pos2, key_order[ref_ki])
    np.testing.assert_array_equal(vals2, ref_kv)


def test_rowsum_matches_dense():
    got = allpairs.rowsum(SK, d=D, block=29)
    np.testing.assert_allclose(got, REF.sum(axis=1), rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end consumer equivalence (the rewire contract)
# ---------------------------------------------------------------------------


def _corpus_sketches(n_docs=220, vocab=4096, seed=7):
    gen = synthetic_documents(vocab, seed=seed, dup_fraction=0.3)
    docs = [next(gen) for _ in range(n_docs)]
    idx, val = docs_to_categorical(docs, vocab)
    _, sk = sketch_corpus(idx, val, vocab, sketch_dim=D, seed=0)
    return sk


def test_dedup_streaming_equals_blocked_seed_path():
    sk = _corpus_sketches()
    new = dedup_by_sketch(sk, D, threshold=40.0, block=64)
    old = dedup_by_sketch_blocked(sk, D, threshold=40.0, block=64)
    np.testing.assert_array_equal(new.keep_mask, old.keep_mask)
    np.testing.assert_array_equal(new.group_ids, old.group_ids)
    assert new.n_groups == old.n_groups
    assert new.n_removed == old.n_removed
    assert new.n_removed > 0  # the corpus really contains duplicates


def test_dedup_handles_no_duplicates_and_empty():
    sk = _corpus_sketches(n_docs=40)
    none = dedup_by_sketch(sk, D, threshold=0.0)
    assert none.n_removed == 0 and none.n_groups == 40
    empty = dedup_by_sketch(sk[:0], D, threshold=40.0)
    assert empty.n_groups == 0 and empty.n_removed == 0


def test_kmode_precomputed_engine_equals_oracle():
    sk = _corpus_sketches(n_docs=150)

    def dist_fn(a, b):
        return np.asarray(_cham_jit(jnp.asarray(a), jnp.asarray(b), D))

    for seed in range(3):
        legacy = kmode_precomputed(dist_fn, sk.copy(), k=4, seed=seed)
        engine = kmode_precomputed(None, sk.copy(), k=4, seed=seed,
                                   sketch_dim=D)
        np.testing.assert_array_equal(legacy, engine)
