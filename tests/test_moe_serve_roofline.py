"""Coverage widening: MoE invariants, serve engine e2e, roofline parsing,
dedup pipeline, theory helpers."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis when installed, fallback otherwise

from repro.configs.base import (MoEConfig, ParallelConfig, reduced_for_smoke)
from repro.configs.registry import get_config
from repro.launch import roofline as rl
from repro.models import moe as moe_mod
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def _moe_cfg(capacity_factor=64.0, dispatch_dtype="float32"):
    import dataclasses

    cfg = reduced_for_smoke(get_config("dbrx_132b"))
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                     dispatch_dtype=dispatch_dtype))


def test_moe_no_drop_preserves_token_mass():
    """With huge capacity, every token is routed: output equals the exact
    per-token mixture of its top-k experts."""
    cfg = _moe_cfg()
    params = moe_mod.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_mod.moe_apply(cfg, params, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    # reference: dense per-token computation
    m = cfg.moe
    logits = x.reshape(-1, cfg.d_model) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    toks = x.reshape(-1, cfg.d_model)

    def expert_fn(e, t):
        g = jax.nn.silu(t @ params["w_gate"][e]) * (t @ params["w_up"][e])
        return g @ params["w_down"][e]

    want = jnp.zeros_like(toks)
    for i in range(toks.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(m.top_k):
            acc = acc + gates[i, j] * expert_fn(idx[i, j], toks[i])
        want = want.at[i].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity far below demand, some tokens lose expert mass (output
    norm shrinks) but nothing breaks."""
    cfg_big = _moe_cfg(capacity_factor=64.0)
    cfg_small = _moe_cfg(capacity_factor=0.25)
    params = moe_mod.moe_init(cfg_big, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg_big.d_model))
    out_big, _ = moe_mod.moe_apply(cfg_big, params, x)
    out_small, _ = moe_mod.moe_apply(cfg_small, params, x)
    assert float(jnp.linalg.norm(out_small)) < float(jnp.linalg.norm(out_big))
    assert bool(jnp.all(jnp.isfinite(out_small)))


def test_moe_dispatch_dtype_agrees():
    cfg32 = _moe_cfg(dispatch_dtype="float32")
    cfg16 = _moe_cfg(dispatch_dtype="bfloat16")
    params = moe_mod.moe_init(cfg32, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg32.d_model))
    o32, _ = moe_mod.moe_apply(cfg32, params, x)
    o16, _ = moe_mod.moe_apply(cfg16, params, x)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o16),
                               rtol=0.05, atol=0.05)


def test_moe_aux_loss_balanced_vs_skewed():
    """Aux loss is ~1 for uniform routing and larger for skewed routing."""
    cfg = _moe_cfg()
    e = cfg.moe.num_experts
    # uniform: f_e = p_e = 1/E -> aux = E * E * (1/E * 1/E) = 1
    f = jnp.full((e,), 1.0 / e)
    aux_uniform = e * jnp.sum(f * f)
    assert float(aux_uniform) == pytest.approx(1.0)
    skew = jnp.zeros((e,)).at[0].set(1.0)
    aux_skew = e * jnp.sum(skew * skew)
    assert float(aux_skew) == pytest.approx(e)


# ---------------------------------------------------------------------------
# serve engine e2e
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_serve_engine_generates(kv_dtype):
    from repro.serve.engine import ServeEngine

    cfg = reduced_for_smoke(get_config("internlm2_1_8b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pcfg = ParallelConfig(remat="none", sequence_parallel=False,
                          kv_cache_dtype=kv_dtype)
    eng = ServeEngine(cfg, params, pcfg, jit=False)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(3, cfg.vocab_size, (2, 8)), jnp.int32)
    res = eng.generate(prompts, max_new=4, max_len=16)
    assert res.tokens.shape == (2, 4)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()


def test_int8_kv_decode_close_to_bf16():
    """Quantized-cache decode logits stay close to full-precision."""
    cfg = reduced_for_smoke(get_config("llama3_8b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(5)
    batch = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 8)),
                                   jnp.int32)}
    pcfg = ParallelConfig(remat="none", sequence_parallel=False)
    _, c_bf = T.prefill(cfg, params, batch, 12, pcfg, "bfloat16")
    _, c_i8 = T.prefill(cfg, params, batch, 12, pcfg, "int8")
    tok = batch["tokens"][:, -1:]
    l_bf, _ = T.decode_step(cfg, params, c_bf, tok, jnp.int32(8), pcfg)
    l_i8, _ = T.decode_step(cfg, params, c_i8, tok, jnp.int32(8), pcfg)
    # int8 quantization error is bounded; top-1 predictions should agree
    assert (np.asarray(l_bf.argmax(-1)) == np.asarray(l_i8.argmax(-1))).mean() \
        > 0.9
    np.testing.assert_allclose(np.asarray(l_bf), np.asarray(l_i8),
                               atol=0.35, rtol=0.1)


# ---------------------------------------------------------------------------
# roofline parsing
# ---------------------------------------------------------------------------


def test_parse_collectives_accounting():
    hlo = """
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %ag = f32[1024,16]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[512,512]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,16]{1,0} reduce-scatter(%z), replica_groups=[2,128]<=[256], dimensions={0}
  %cp = f32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""
    stats = rl.parse_collectives(hlo, default_group=256)
    assert stats.count == 4
    ag = stats.by_op["all-gather"]
    assert ag["bytes"] == 1024 * 16 * 4
    np.testing.assert_allclose(ag["traffic"], 1024 * 16 * 4 * 15 / 16)
    ar = stats.by_op["all-reduce"]
    np.testing.assert_allclose(ar["traffic"], 2 * 512 * 512 * 2 * 3 / 4)
    rs = stats.by_op["reduce-scatter"]
    np.testing.assert_allclose(rs["traffic"], 64 * 16 * 4 * 127)
    assert stats.by_op["collective-permute"]["traffic"] == 128 * 4


def test_parse_convert_bytes_skips_fusions():
    hlo = """
%fused_computation.1 (p: bf16[8,8]) -> f32[8,8] {
  %c1 = f32[8,8]{1,0} convert(%p)
}
ENTRY %main (a: bf16[16,16]) -> f32[16,16] {
  %c2 = f32[16,16]{1,0} convert(%a)
}
"""
    got = rl.parse_convert_bytes(hlo)
    assert got == 16 * 16 * 4 * 1.5  # only the entry-computation convert


def test_roofline_analyze_dominant():
    rec = {"flops_per_device": rl.PEAK_FLOPS,  # 1 s compute
           "bytes_per_device": rl.HBM_BW * 2,  # 2 s memory
           "collective_traffic_bytes": rl.ICI_BW * 0.5,  # 0.5 s
           "model_flops": rl.PEAK_FLOPS * 128}
    roof = rl.analyze(rec, chips=256)
    assert roof.dominant == "memory"
    assert roof.compute_s == pytest.approx(1.0)
    assert roof.useful_ratio == pytest.approx(0.5)


@given(st.integers(1, 60), st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_model_flops_positive(layers, heads):
    from repro.configs.base import SHAPES

    cfg = reduced_for_smoke(get_config("llama3_8b"))
    n = rl.active_params(cfg)
    assert n > 0
    assert rl.model_flops(cfg, SHAPES["train_4k"], n) == 6.0 * n * 256 * 4096
    assert rl.model_flops(cfg, SHAPES["decode_32k"], n) == 2.0 * n * 128


def test_active_params_moe_discount():
    cfg = get_config("deepseek_v3_671b")
    n_active = rl.active_params(cfg)
    # dsv3: ~37B active of 671B total
    assert 25e9 < n_active < 50e9, n_active
