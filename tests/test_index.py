"""repro.index: the online index's bit-identity contracts vs the batch
engine (core.allpairs), plus store/cache/checkpoint/ingest behaviour.

The load-bearing property: no matter how the store reached its current
membership (chunked adds, tombstones, compactions, snapshot round-trips),
`topk` and `radius` return EXACTLY what core.allpairs returns on a freshly
assembled matrix of the same vectors — same ids, same float bits.
"""

import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from tests._hyp import given, settings, st

from repro.core import (CabinParams, threshold_pairs, topk_rows)
from repro.core.cabin import sketch_dense
from repro.index import BandedLayout, QueryEngine, SketchStore, \
    TieredLayout, ingest_documents

N_DIMS = 500
D = 256
P = CabinParams.create(N_DIMS, D, seed=3)


def _rows(n, seed):
    """Varied per-row density (10..80 features) so sketch weights spread —
    the structure the weight-banded layout exists to exploit."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for i in range(n):
        density = int(rng.integers(10, 80))
        idx = rng.choice(N_DIMS, size=density, replace=False)
        x[i, idx] = rng.integers(1, 8, size=density)
    return x


X = _rows(96, seed=0)
SK = np.asarray(sketch_dense(P, jnp.asarray(X)))
QUERIES = X[:5]


def _radius_ref(q_sk, data_sk, ids, r, metric):
    """Per-query sorted id arrays from the batch engine."""
    pairs = threshold_pairs(jnp.asarray(q_sk), jnp.asarray(data_sk), d=D,
                            threshold=r, metric=metric)
    return [np.sort(ids[pairs[pairs[:, 0] == qi, 1]])
            for qi in range(len(q_sk))]


# ---------------------------------------------------------------------------
# bit-identity vs the batch engine (the acceptance contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_engine_bit_identical_through_mutations(metric, tmp_path):
    """One full serving journey per metric: chunked build -> topk/radius
    parity -> remove -> parity -> compact -> parity -> more adds -> parity
    -> snapshot/restore -> parity.  Every comparison is exact equality
    against core.allpairs on the alive membership."""
    eng = QueryEngine(P, metric=metric, band_rows=16)

    def check():
        alive = eng.ids()
        data_sk = SK[alive]
        ref_i, ref_v = topk_rows(SK[:5], data_sk, 7, d=D, metric=metric)
        got_i, got_v = eng.topk(QUERIES, 7)
        np.testing.assert_array_equal(got_i, alive[ref_i])
        np.testing.assert_array_equal(got_v, ref_v)
        r = float(np.percentile(ref_v, 70) + 0.37)
        got_r = eng.radius(QUERIES, r)
        want_r = _radius_ref(SK[:5], data_sk, alive, r, metric)
        for a, b in zip(got_r, want_r):
            np.testing.assert_array_equal(a, b)

    eng.add_dense(X[:40])
    eng.add_dense(X[40:70])
    check()
    eng.remove(np.arange(10, 35))
    check()
    eng.compact()
    check()
    eng.add_dense(X[70:])
    check()
    eng.save(str(tmp_path / metric), step=2)
    restored = QueryEngine.restore(str(tmp_path / metric))
    assert restored.metric == metric
    with pytest.raises(ValueError, match="fixed by the snapshot"):
        QueryEngine.restore(str(tmp_path / metric), metric="cham")
    got_i, got_v = eng.topk(QUERIES, 7)
    res_i, res_v = restored.topk(QUERIES, 7)
    np.testing.assert_array_equal(res_i, got_i)
    np.testing.assert_array_equal(res_v, got_v)
    # restored engine keeps serving mutations from where it left off
    new_ids = restored.add_dense(X[:4])
    assert new_ids.min() > eng.ids().max()


def test_topk_ties_resolve_to_lower_id():
    """Duplicate vectors => equal distances; the winner must be the lower
    id, matching topk_rows' stable merge."""
    eng = QueryEngine(P)
    eng.add_dense(np.concatenate([X[:8], X[:8]]))  # ids 8..15 duplicate 0..7
    ids, vals = eng.topk(X[:8], 2)
    np.testing.assert_array_equal(ids[:, 0], np.arange(8))
    np.testing.assert_array_equal(ids[:, 1], np.arange(8, 16))
    np.testing.assert_array_equal(vals[:, 0], vals[:, 1])


def test_sparse_and_dense_ingest_agree():
    nz = [np.flatnonzero(row) for row in X[:20]]
    m = max(len(z) for z in nz)
    idx = np.zeros((20, m), np.int32)
    val = np.zeros((20, m), np.int32)
    for i, z in enumerate(nz):
        idx[i, : len(z)] = z
        val[i, : len(z)] = X[i, z]
    e1 = QueryEngine(P)
    e1.add_sparse(idx, val)
    e2 = QueryEngine(P)
    e2.add_dense(X[:20])
    g1 = e1.topk(QUERIES, 5)
    g2 = e2.topk(QUERIES, 5)
    np.testing.assert_array_equal(g1[0], g2[0])
    np.testing.assert_array_equal(g1[1], g2[1])
    # COO queries hit the same sketch space as dense queries
    gq = e2.topk((idx[:5], val[:5]), 5)
    np.testing.assert_array_equal(gq[0], g2[0])
    np.testing.assert_array_equal(gq[1], g2[1])


def test_pairwise_matches_topk_distances():
    eng = QueryEngine(P)
    eng.add_dense(X[:30])
    ids, dists = eng.pairwise(QUERIES)
    np.testing.assert_array_equal(ids, np.arange(30))
    top_i, top_v = eng.topk(QUERIES, 3)
    # cham: same exact integer stats, float estimator agrees to cross-graph
    # libm noise (see kernels.hamming.ops.dist_matrix)
    np.testing.assert_allclose(
        np.take_along_axis(dists, top_i.astype(np.int64), axis=1), top_v,
        rtol=1e-5, atol=1e-3)
    sub_ids, sub = eng.pairwise(QUERIES, ids=np.asarray([3, 7]))
    np.testing.assert_array_equal(sub, dists[:, [3, 7]])
    with pytest.raises(KeyError):
        eng.pairwise(QUERIES, ids=np.asarray([99]))
    # hamming: integer metric, exact equality end to end
    enh = QueryEngine(P, metric="hamming")
    enh.add_dense(X[:30])
    _, dh = enh.pairwise(QUERIES)
    hi, hv = enh.topk(QUERIES, 3)
    np.testing.assert_array_equal(
        np.take_along_axis(dh, hi.astype(np.int64), axis=1), hv)


# ---------------------------------------------------------------------------
# property tests: incremental == fresh, snapshot round-trip (tests/_hyp)
# ---------------------------------------------------------------------------


def _mutate(eng, rng, chunks):
    """Apply a random interleaving of chunked adds, removes, compactions."""
    pos = 0
    for c in chunks:
        take = X[pos: pos + c]
        if len(take) == 0:
            break
        eng.add_dense(take)
        pos += len(take)
        alive = eng.ids()
        if len(alive) > 3 and rng.random() < 0.7:
            k = int(rng.integers(1, max(2, len(alive) // 3)))
            eng.remove(rng.choice(alive, size=k, replace=False))
        if rng.random() < 0.3:
            eng.compact()
    return eng


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16), st.lists(st.integers(1, 14), min_size=1,
                                       max_size=6))
def test_incremental_build_equals_fresh_batch(seed, chunks):
    """An index built in random-sized chunks with interleaved deletes and
    compactions answers bit-identically to one built fresh from the
    surviving vectors."""
    rng = np.random.default_rng(seed)
    eng = _mutate(QueryEngine(P, band_rows=16), rng, chunks)
    survivors = eng.ids()
    if len(survivors) == 0:
        return
    fresh = QueryEngine(P, band_rows=16)
    fresh.add_dense(X[survivors])  # fresh ids = positions into survivors
    gi, gv = eng.topk(QUERIES, 5)
    fi, fv = fresh.topk(QUERIES, 5)
    np.testing.assert_array_equal(gi, survivors[fi])
    np.testing.assert_array_equal(gv, fv)
    r = float(np.percentile(gv, 60) + 0.37) if gv.size else 1.0
    ra = eng.radius(QUERIES, r)
    rb = fresh.radius(QUERIES, r)
    for a, b in zip(ra, rb):
        np.testing.assert_array_equal(a, survivors[b])


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**16))
def test_snapshot_restore_roundtrips_exactly(seed):
    rng = np.random.default_rng(seed)
    chunks = [int(c) for c in rng.integers(1, 14, size=4)]
    eng = _mutate(QueryEngine(P, band_rows=16), rng, chunks)
    with tempfile.TemporaryDirectory() as td:
        eng.save(td, step=7)
        back = QueryEngine.restore(td)
    # store state is reproduced bit-for-bit, tombstones included
    a, b = eng.store.state_tree(), back.store.state_tree()
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert eng.store.state_meta() == back.store.state_meta()
    gi, gv = eng.topk(QUERIES, 4)
    ri, rv = back.topk(QUERIES, 4)
    np.testing.assert_array_equal(gi, ri)
    np.testing.assert_array_equal(gv, rv)


# ---------------------------------------------------------------------------
# store mechanics, cache, edge cases
# ---------------------------------------------------------------------------


def test_store_capacity_doubles_and_compacts():
    store = SketchStore(D)
    assert store.capacity == 8
    store.add(jnp.asarray(SK[:20]))
    assert store.capacity == 32 and store.size == 20 and len(store) == 20
    store.remove(np.arange(5, 19))
    assert len(store) == 6 and store.size == 20  # tombstones keep slots
    store.compact()
    assert store.size == 6 and store.capacity == 8
    np.testing.assert_array_equal(store.ids(), [0, 1, 2, 3, 4, 19])
    mat, n, ids = store.gather_alive()
    assert n == 6 and mat.shape[0] == 8
    np.testing.assert_array_equal(np.asarray(mat[:6]), SK[[0, 1, 2, 3, 4, 19]])


def test_store_errors():
    store = SketchStore(D)
    store.add(jnp.asarray(SK[:4]))
    with pytest.raises(KeyError):
        store.remove([11])
    store.remove([2])
    with pytest.raises(KeyError):  # double-remove
        store.remove([2])
    with pytest.raises(ValueError):  # duplicate batch
        store.remove([0, 0])
    with pytest.raises(ValueError):  # wrong packed width
        store.add(jnp.zeros((2, 3), jnp.int32))
    with pytest.raises(ValueError):  # over-declared valid count
        store.add(jnp.asarray(SK[:4]), n_valid=9)
    with pytest.raises(ValueError):  # negative valid count
        store.add(jnp.asarray(SK[:4]), n_valid=-3)
    with pytest.raises(ValueError):
        threshold_pairs(SK[:4], SK[:8], d=D, threshold=1.0, n_valid=6)
    with pytest.raises(ValueError):
        topk_rows(SK[:4], SK[:8], 2, d=D, m_valid=9)
    eng = QueryEngine(P)
    with pytest.raises(ValueError):  # wrong dense width
        eng.add_dense(np.zeros((2, 7), np.int32))
    with pytest.raises(ValueError):  # out-of-vocab COO index
        eng.add_sparse(np.full((1, 3), N_DIMS, np.int32),
                       np.ones((1, 3), np.int32))
    with pytest.raises(ValueError):
        QueryEngine(P, metric="cosine")


def test_empty_and_clamped_queries():
    eng = QueryEngine(P)
    ids, vals = eng.topk(QUERIES, 3)  # empty store
    assert ids.shape == (5, 0) and vals.shape == (5, 0)
    assert all(len(a) == 0 for a in eng.radius(QUERIES, 10.0))
    eng.add_dense(X[:2])
    ids, vals = eng.topk(QUERIES, 9)  # k clamps to n_alive
    assert ids.shape == (5, 2)
    ids0, _ = eng.topk(X[:0], 3)  # empty query batch
    assert ids0.shape == (0, 0)
    assert eng.radius(X[:0], 5.0) == []


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_empty_traffic_is_well_typed_both_metrics(metric):
    """API-boundary hardening: n=0 stores and 0-row query batches answer
    as explicit host-side fast paths with well-typed empties — topk,
    radius AND pairwise — instead of riding pow2 padding of degenerate
    shapes through the kernels.  Validation does not weaken at q=0."""
    eng = QueryEngine(P, metric=metric)
    q0, q2 = X[:0], QUERIES[:2]
    # empty engine, live queries
    ids, vals = eng.topk(q2, 5)
    assert ids.shape == (2, 0) and ids.dtype == np.int64
    assert vals.shape == (2, 0) and vals.dtype == np.float32
    assert [len(h) for h in eng.radius(q2, 10.0)] == [0, 0]
    pids, pd = eng.pairwise(q2)
    assert pids.shape == (0,) and pd.shape == (2, 0)
    assert pd.dtype == np.float32
    with pytest.raises(KeyError):  # explicit ids on an empty store
        eng.pairwise(q2, ids=[0])
    # empty engine, empty batch
    pids, pd = eng.pairwise(q0)
    assert pids.shape == (0,) and pd.shape == (0, 0)
    # populated engine, 0-row batch
    stored = eng.add_dense(X[:6])
    ids, vals = eng.topk(q0, 5)
    assert ids.shape == (0, 0) and vals.shape == (0, 0)
    assert eng.radius(q0, 10.0) == []
    pids, pd = eng.pairwise(q0)
    np.testing.assert_array_equal(pids, np.sort(stored))
    assert pd.shape == (0, 6) and pd.dtype == np.float32
    pids, pd = eng.pairwise(q0, ids=stored[:2])
    assert pd.shape == (0, 2) and len(pids) == 2
    with pytest.raises(ValueError):  # duplicate ids still a caller bug
        eng.pairwise(q0, ids=[stored[0], stored[0]])
    with pytest.raises(KeyError):  # membership still enforced at q=0
        eng.pairwise(q0, ids=[10 ** 9])


def test_result_cache_hits_and_invalidates():
    eng = QueryEngine(P, cache_entries=4)
    eng.add_dense(X[:32])
    a = eng.topk(QUERIES, 4)
    assert (eng.cache_hits, eng.cache_misses) == (0, 1)
    b = eng.topk(QUERIES, 4)
    assert eng.cache_hits == 1
    np.testing.assert_array_equal(a[0], b[0])
    eng.radius(QUERIES, 50.0)
    eng.radius(QUERIES, 50.0)
    assert eng.cache_hits == 2
    eng.add_dense(X[32:34])  # mutation invalidates via version bump
    c = eng.topk(QUERIES, 4)
    assert eng.cache_misses == 3
    alive = eng.ids()
    ref_i, ref_v = topk_rows(SK[:5], SK[alive], 4, d=D)
    np.testing.assert_array_equal(c[0], alive[ref_i])
    np.testing.assert_array_equal(c[1], ref_v)
    # callers may mutate returned (writable) arrays without corrupting the
    # cache (the distance array is a read-only jax view — unmutable anyway)
    c[0].fill(-7)
    d2 = eng.topk(QUERIES, 4)
    np.testing.assert_array_equal(d2[0], alive[ref_i])
    np.testing.assert_array_equal(d2[1], ref_v)
    hits = eng.radius(QUERIES, 50.0)
    for h in hits:
        h.fill(-1)
    for h, ref in zip(eng.radius(QUERIES, 50.0),
                      _radius_ref(SK[:5], SK[alive], alive, 50.0, "cham")):
        np.testing.assert_array_equal(h, ref)


def test_topk_cache_hit_skips_gather_and_layout(monkeypatch):
    """An LRU hit must be O(1): no store gather, no banded layout, no
    device work — only the key bytes and the cached copy."""
    eng = QueryEngine(P, cache_entries=4)
    eng.add_dense(X[:40])
    a = eng.topk(QUERIES, 3)  # miss: builds the layout, runs the scan

    def _boom(what):
        def fn(*args, **kwargs):
            raise AssertionError(f"{what} touched on a cache hit")
        return fn

    monkeypatch.setattr(eng.store, "gather_alive", _boom("gather_alive"))
    monkeypatch.setattr(eng, "_banded_layout", _boom("_banded_layout"))
    monkeypatch.setattr(eng, "_layout", _boom("_layout"))
    b = eng.topk(QUERIES, 3)
    assert eng.cache_hits == 1
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_banded_topk_certificate_prunes_but_never_drops(metric, monkeypatch):
    """A narrow query's progressive expansion stops at the certificate
    after touching a fraction of the store, yet returns exactly the full
    scan's answer; a diverse batch degrades gracefully to a full visit."""
    from repro.core import allpairs as ap
    from repro.core.packing import np_popcount_rows

    eng = QueryEngine(P, metric=metric, band_rows=8, cache_entries=0)
    eng.add_dense(X)
    visited = []
    orig = ap.topk_rows

    def counting(a, b, k, **kw):
        visited.append(kw.get("m_valid", np.shape(b)[0]))
        return orig(a, b, k, **kw)

    monkeypatch.setattr(ap, "topk_rows", counting)
    weights = np_popcount_rows(SK)
    qi = int(np.argmin(weights))  # narrowest sketch: strongest certificate
    got_i, got_v = eng.topk(X[qi: qi + 1], 3)
    assert 0 < sum(visited) < len(X)  # the certificate actually fired
    monkeypatch.setattr(ap, "topk_rows", orig)
    ref_i, ref_v = topk_rows(SK[qi: qi + 1], SK, 3, d=D, metric=metric)
    np.testing.assert_array_equal(got_i, ref_i)
    np.testing.assert_array_equal(got_v, ref_v)
    # the full query mix (diverse weights) still answers exactly
    got5 = eng.topk(QUERIES, 7)
    ref5 = topk_rows(SK[:5], SK, 7, d=D, metric=metric)
    np.testing.assert_array_equal(got5[0], ref5[0])
    np.testing.assert_array_equal(got5[1], ref5[1])


def test_banded_layout_prunes_but_never_drops():
    """With tiny bands, many get pruned for a small radius, yet the result
    equals the unpruned batch reference."""
    eng = QueryEngine(P, band_rows=8)
    eng.add_dense(X)
    layout = eng._banded_layout()
    assert isinstance(layout, BandedLayout) and layout.n_bands == 12
    # a single narrow query with a tight radius reaches only a few bands
    import repro.core.packing as packing
    q = X[2:3]
    r = 10.0
    weights = np.asarray(packing.popcount_rows(jnp.asarray(SK[2:3])))
    mask = layout.candidate_bands(weights, r)
    assert 0 < mask.sum() < layout.n_bands  # pruning actually happened
    got = eng.radius(q, r)
    want = _radius_ref(SK[2:3], SK, np.arange(96, dtype=np.int64), r, "cham")
    np.testing.assert_array_equal(got[0], want[0])
    # wide query mix still agrees with the unpruned batch reference
    got5 = eng.radius(QUERIES, 25.0)
    want5 = _radius_ref(SK[:5], SK, np.arange(96, dtype=np.int64), 25.0,
                        "cham")
    for a, b in zip(got5, want5):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# tiered layout: O(delta) serving after mutations (DESIGN.md 8.5)
# ---------------------------------------------------------------------------


def _check_exact(eng, jmap=None):
    """topk + radius of `eng` vs the batch engine on the alive membership.
    `jmap` maps external id -> row of X/SK (default: identity)."""
    alive = eng.ids()
    rows = alive if jmap is None else np.asarray([jmap[i] for i in alive])
    data_sk = SK[rows]
    ref_i, ref_v = topk_rows(SK[:3], data_sk, 5, d=D, metric=eng.metric)
    got_i, got_v = eng.topk(X[:3], 5)
    np.testing.assert_array_equal(got_i, alive[ref_i])
    np.testing.assert_array_equal(got_v, ref_v)
    r = float(np.percentile(ref_v, 60) + 0.37) if ref_v.size else 1.0
    got_r = eng.radius(X[:3], r)
    want_r = _radius_ref(SK[:3], data_sk, alive, r, eng.metric)
    for a, b in zip(got_r, want_r):
        np.testing.assert_array_equal(a, b)


def test_tiered_layout_serves_delta_without_rebuild():
    """The load-bearing tentpole property: after the base tier is built,
    adds land in the delta tier and removes in the alive masks — the base
    BandedLayout object SURVIVES the mutation (no O(N log N) rebuild), yet
    every answer stays bit-identical to a fresh batch build."""
    eng = QueryEngine(P, band_rows=16, merge_ratio=0.5, cache_entries=0)
    jmap = {}

    def add(rows):
        for i, j in zip(eng.add_dense(X[rows]), rows):
            jmap[int(i)] = int(j)

    add(np.arange(64))
    eng.topk(QUERIES, 5)  # first query builds the base tier
    lay = eng._tiered
    assert isinstance(lay, TieredLayout) and lay.n_merges == 0
    base0 = lay.base
    assert base0.n == 64 and lay.delta_n == 0

    add(np.arange(64, 80))  # 16 live delta <= 0.5 * 64: no merge
    _check_exact(eng, jmap)
    assert eng._tiered.base is base0, "add must not rebuild the base tier"
    assert eng._tiered.delta_n == 16 and eng._tiered.n_merges == 0

    # removes thread through per-tier alive masks — still no rebuild
    eng.remove([64, 3])  # one delta row, one base row
    _check_exact(eng, jmap)
    assert eng._tiered.base is base0
    assert eng._tiered.delta_n == 15 and eng._tiered.base.n_alive == 63
    assert eng.stats()["delta_rows"] == 15

    # an unmutated re-query syncs for free: same layout, same base
    _check_exact(eng, jmap)
    assert eng._tiered.base is base0

    # the size-ratio policy folds the tiers once delta outgrows its share
    add(np.arange(40))
    _check_exact(eng, jmap)
    assert eng._tiered.base is not base0
    assert eng._tiered.delta_n == 0 and eng._tiered.n_merges == 1

    # compact() bumps the slot epoch: the next query rebuilds and serves on
    eng.remove(eng.ids()[:5])
    eng.compact()
    _check_exact(eng, jmap)
    assert eng._tiered.delta_n == 0


def test_merge_ratio_zero_rebuilds_per_mutation():
    """merge_ratio=0 is the pre-tiered behaviour (the bench baseline):
    every mutation folds immediately, so the delta tier never persists."""
    eng = QueryEngine(P, band_rows=16, merge_ratio=0.0, cache_entries=0)
    eng.add_dense(X[:32])
    eng.topk(QUERIES, 4)
    base0 = eng._tiered.base
    eng.add_dense(X[32:40])
    _check_exact(eng)
    assert eng._tiered.base is not base0 and eng._tiered.delta_n == 0
    # remove-only mutations rebuild too — the old path had no alive masks
    base1 = eng._tiered.base
    eng.remove([5])
    _check_exact(eng)
    assert eng._tiered.base is not base1
    assert eng._tiered.base.n_alive == eng._tiered.base.n == 39


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**16), st.integers(0, 2))
def test_mutate_query_interleaving_bit_identity(seed, ratio_idx):
    """Random add/remove/compact between EVERY query: topk and radius stay
    bit-identical to the batch engine across tier boundaries, merges, and
    cache hits, under both metrics and all three merge policies."""
    ratio = (0.0, 0.5, None)[ratio_idx]
    metric = ("cham", "hamming")[seed % 2]
    rng = np.random.default_rng(seed)
    eng = QueryEngine(P, metric=metric, band_rows=16, merge_ratio=ratio,
                      cache_entries=8)
    jmap: dict[int, int] = {}
    pos = 0
    saw_delta = False
    for _ in range(5):
        op = rng.random()
        if op < 0.55 or len(eng) < 4:
            c = int(rng.integers(1, 14))
            rows = np.arange(pos, pos + c) % len(X)
            pos += c
            for i, j in zip(eng.add_dense(X[rows]), rows):
                jmap[int(i)] = int(j)
        elif op < 0.85:
            alive = eng.ids()
            kk = int(rng.integers(1, max(2, len(alive) // 2)))
            eng.remove(rng.choice(alive, size=kk, replace=False))
        else:
            eng.compact()
        _check_exact(eng, jmap)
        _check_exact(eng, jmap)  # immediate re-ask: cache-hit path agrees
        saw_delta = saw_delta or bool(eng._tiered and eng._tiered.delta_n)
    if ratio is None and pos > 0:
        # with auto-merge off, at least one query must have been served
        # across a live tier boundary (first add builds base, later adds
        # can only leave via compact)
        assert saw_delta or eng._tiered is None or eng._tiered.n_merges > 0


# ---------------------------------------------------------------------------
# API-boundary regressions: k < 0, r <= 0, duplicate ids, stale gathers
# ---------------------------------------------------------------------------


def test_topk_negative_k_raises():
    eng = QueryEngine(P)
    eng.add_dense(X[:8])
    with pytest.raises(ValueError, match="k must be >= 0"):
        eng.topk(QUERIES, -1)
    with pytest.raises(ValueError, match="k must be >= 0"):
        eng.topk_packed(jnp.asarray(SK[:2]), -3)
    ids, vals = eng.topk(QUERIES, 0)  # k = 0 stays a valid empty query
    assert ids.shape == (5, 0) and vals.shape == (5, 0)


def test_radius_nonpositive_r_returns_empty():
    """dist >= 0 and the test is strict, so r <= 0 is a documented
    empty-results contract (not an error) — including on an empty store."""
    eng = QueryEngine(P)
    assert all(len(a) == 0 for a in eng.radius(QUERIES, -3.0))
    eng.add_dense(X[:16])
    for r in (-3.0, 0.0):
        out = eng.radius(QUERIES, r)
        assert len(out) == 5 and all(len(a) == 0 for a in out)
    out = eng.radius_packed(jnp.asarray(SK[:2]), -1.0)
    assert len(out) == 2 and all(len(a) == 0 for a in out)


def test_pairwise_duplicate_ids_raise():
    """Consistent with SketchStore.remove: duplicate ids are a caller bug,
    not a request for duplicated distance columns."""
    eng = QueryEngine(P)
    eng.add_dense(X[:8])
    with pytest.raises(ValueError, match="duplicate ids"):
        eng.pairwise(QUERIES, ids=np.asarray([3, 3]))
    sub_ids, sub = eng.pairwise(QUERIES, ids=np.asarray([3, 5]))
    np.testing.assert_array_equal(sub_ids, [3, 5])


def test_gather_alive_stale_view_is_rejected(monkeypatch):
    """A view held across a mutation must fail the cheap version check with
    a clear message — not surface as jax's 'Array has been deleted' after
    a donated append."""
    store = SketchStore(D)
    store.add(jnp.asarray(SK[:8]))
    view = store.gather_alive()
    store.check_fresh(view)  # fresh: fine
    assert view.n_alive == 8 and view.version == store.version
    store.add(jnp.asarray(SK[8:12]))
    with pytest.raises(RuntimeError, match="stale gather"):
        store.check_fresh(view)
    # engine consumer: pairwise guards its gather before the device compute
    eng = QueryEngine(P)
    eng.add_dense(X[:8])
    stale = eng.store.gather_alive()
    eng.add_dense(X[8:16])
    monkeypatch.setattr(eng.store, "gather_alive", lambda: stale)
    with pytest.raises(RuntimeError, match="stale gather"):
        eng.pairwise(QUERIES)
    with pytest.raises(RuntimeError, match="stale gather"):
        # the id-subset branch gathers from the view too: the guard must
        # fire before that dereference, not just before the kernel call
        eng.pairwise(QUERIES, ids=np.asarray([1, 2]))


def test_dedup_by_sketch_metric_param():
    """Ingest dedups in the ENGINE's metric: hamming thresholds group
    exactly the sketch-identical rows at threshold < 1."""
    from repro.data.dedup import dedup_by_sketch

    sk = np.concatenate([SK[:10], SK[:10]])
    res = dedup_by_sketch(sk, D, threshold=0.5, metric="hamming")
    assert res.n_removed == 10
    np.testing.assert_array_equal(res.group_ids[:10], res.group_ids[10:])


def test_ingest_documents_stream():
    from repro.data.dedup import docs_to_categorical
    from repro.data.pipeline import synthetic_documents

    vocab = 2048
    params = CabinParams.create(vocab, D, seed=5)
    eng = QueryEngine(params)
    gen = synthetic_documents(vocab, seed=5, dup_fraction=0.3)
    docs = [next(gen) for _ in range(90)]
    got = ingest_documents(eng, docs, window=32, dedup_threshold=40.0)
    assert got.shape == (90,)
    dropped = int((got == -1).sum())
    assert dropped > 0  # the stream really contains near-duplicates
    assert len(eng) == 90 - dropped
    np.testing.assert_array_equal(np.sort(got[got >= 0]), eng.ids())
    # no-dedup ingest keeps everything; max_docs consumes EXACTLY that many
    # docs from the caller's iterator (nothing pulled and dropped)
    eng2 = QueryEngine(params)
    it = iter(docs)
    got2 = ingest_documents(eng2, it, window=32, max_docs=50)
    assert got2.shape == (50,) and len(eng2) == 50
    leftover = list(it)
    assert len(leftover) == 40
    np.testing.assert_array_equal(leftover[0], docs[50])
    # ingested docs are queryable: each doc's nearest neighbour is itself
    idx_q, val_q = docs_to_categorical(docs[:6], vocab)
    ids, vals = eng2.topk((idx_q, val_q), 1)
    np.testing.assert_array_equal(ids[:, 0], got2[:6])


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16),
       st.lists(st.integers(0, 6), min_size=6, max_size=18))
def test_lru_accounting_matches_shadow_model(seed, ops):
    """The LRU's hit/miss accounting is EXACT against an independent shadow
    model of its policy (key = (op, args, store version, query bytes);
    capacity eviction in least-recent order; mutations invalidate via the
    version in the key; sync_layout touches nothing) — both the engine's
    python attrs and the repro.obs counter mirror, op by op."""
    from collections import OrderedDict

    rng = np.random.default_rng(seed)
    cap = 3
    eng = QueryEngine(P, cache_entries=cap, band_rows=16)
    eng.add_dense(X[:24])
    shadow: OrderedDict = OrderedDict()
    hits = misses = 0

    def probe(key):
        nonlocal hits, misses
        if key in shadow:
            shadow.move_to_end(key)
            hits += 1
        else:
            misses += 1
            shadow[key] = True
            if len(shadow) > cap:
                shadow.popitem(last=False)

    next_row = 24
    for op in ops:
        if op <= 2:  # topk on one of three fixed query batches
            q = X[8 * op: 8 * op + 3]
            eng.topk(q, 4)
            probe(("topk", min(4, len(eng)), eng.store.version, op))
        elif op == 3:  # radius (its own key space, same cache)
            eng.radius(QUERIES, 50.0)
            probe(("radius", 50.0, eng.store.version, "q"))
        elif op == 4:  # add: version bump invalidates every live key
            eng.add_dense(X[next_row % 64: next_row % 64 + 1])
            next_row += 1
        elif op == 5:  # remove one alive row (keep the store non-empty)
            alive = eng.ids()
            if len(alive) > 1:
                i = int(rng.integers(len(alive)))
                eng.remove(alive[i: i + 1])
        else:  # sync_layout: maintenance, not traffic — no cache effect
            eng.sync_layout()
        assert (eng.cache_hits, eng.cache_misses) == (hits, misses)
        assert len(eng._cache) == len(shadow)
        if not eng.obs.is_null:  # the obs mirror counts the same events
            snap = eng.obs_snapshot()
            assert snap.get("engine_cache_hits_total", 0) == hits
            assert snap.get("engine_cache_misses_total", 0) == misses
            assert snap["engine_lru_entries"] == float(len(shadow))
