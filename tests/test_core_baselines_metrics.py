"""Tests for baseline sketches, clustering metrics and k-mode."""

import numpy as np
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.baselines import (
    BaselineParams,
    bcs_estimate,
    bcs_sketch,
    fh_estimate,
    fh_sketch,
    hlsh_estimate,
    hlsh_sketch,
    simhash_estimate,
    simhash_sketch,
)
from repro.core.cabin import binem
from repro.core.kmode import kmode, kmode_precomputed
from repro.core.metrics import ari, nmi, purity


def _binary_pair(rng, n, density):
    bits = np.zeros((2, n), np.int32)
    common = rng.choice(n, size=density // 2, replace=False)
    bits[:, common] = 1
    for r in range(2):
        extra = rng.choice(n, size=density // 2, replace=False)
        bits[r, extra] = 1
    return bits


def test_bcs_estimator_mean():
    rng = np.random.default_rng(0)
    n, density, d = 4000, 300, 2048
    bits = _binary_pair(rng, n, density)
    true_hd = int((bits[0] != bits[1]).sum())
    ests = []
    for seed in range(24):
        p = BaselineParams(n, d, seed)
        y = bcs_sketch(p, jnp.asarray(bits))
        ests.append(float(bcs_estimate(p, y[0], y[1])))
    assert abs(np.mean(ests) - true_hd) < 0.15 * true_hd + 10


def test_hlsh_estimator_mean():
    rng = np.random.default_rng(1)
    n, density, d = 4000, 300, 2048
    bits = _binary_pair(rng, n, density)
    true_hd = int((bits[0] != bits[1]).sum())
    ests = []
    for seed in range(24):
        p = BaselineParams(n, d, seed)
        y = hlsh_sketch(p, jnp.asarray(bits))
        ests.append(float(hlsh_estimate(p, y[0], y[1])))
    assert abs(np.mean(ests) - true_hd) < 0.25 * true_hd + 10


def test_fh_estimator_mean():
    rng = np.random.default_rng(2)
    n, density, d = 4000, 300, 2048
    bits = _binary_pair(rng, n, density)
    true_hd = int((bits[0] != bits[1]).sum())
    wu, wv = float(bits[0].sum()), float(bits[1].sum())
    ests = []
    for seed in range(24):
        p = BaselineParams(n, d, seed)
        y = fh_sketch(p, jnp.asarray(bits))
        ests.append(float(fh_estimate(p, y[0], y[1], wu, wv)))
    assert abs(np.mean(ests) - true_hd) < 0.15 * true_hd + 10


def test_simhash_estimator_mean():
    rng = np.random.default_rng(3)
    n, density, d = 1000, 120, 512
    bits = _binary_pair(rng, n, density)
    true_hd = int((bits[0] != bits[1]).sum())
    wu, wv = float(bits[0].sum()), float(bits[1].sum())
    p = BaselineParams(n, d, 0)
    y = simhash_sketch(p, jnp.asarray(bits))
    est = float(simhash_estimate(p, y[0], y[1], wu, wv))
    assert abs(est - true_hd) < 0.35 * true_hd + 15


def test_binem_feeds_baselines():
    # Full paper comparison path: categorical -> BinEm -> baseline sketch.
    rng = np.random.default_rng(4)
    n, c = 1000, 10
    x = rng.integers(0, c + 1, size=(2, n)).astype(np.int32)
    p = CabinParams.create(n, 256, seed=0)
    u1 = binem(p, jnp.asarray(x))
    bp = BaselineParams(n, 256, 0)
    assert bcs_sketch(bp, u1).shape == (2, 256)
    assert fh_sketch(bp, u1).shape == (2, 256)
    assert hlsh_sketch(bp, u1).shape == (2, 256)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_metrics_perfect_clustering():
    truth = np.asarray([0, 0, 1, 1, 2, 2])
    assert purity(truth, truth) == 1.0
    assert nmi(truth, truth) > 0.999
    assert ari(truth, truth) == 1.0


def test_metrics_label_permutation_invariant():
    truth = np.asarray([0, 0, 1, 1, 2, 2])
    pred = np.asarray([2, 2, 0, 0, 1, 1])
    assert purity(truth, pred) == 1.0
    assert ari(truth, pred) == 1.0


def test_metrics_random_clustering_low():
    rng = np.random.default_rng(0)
    truth = np.repeat(np.arange(4), 50)
    pred = rng.integers(0, 4, size=200)
    assert ari(truth, pred) < 0.15
    assert nmi(truth, pred) < 0.2


# ---------------------------------------------------------------------------
# k-mode
# ---------------------------------------------------------------------------


def _clustered_categorical(rng, k, per, n, c, noise=0.05):
    centers = rng.integers(1, c + 1, size=(k, n)).astype(np.int32)
    rows, labels = [], []
    for ci in range(k):
        for _ in range(per):
            row = centers[ci].copy()
            flip = rng.random(n) < noise
            row[flip] = rng.integers(1, c + 1, size=int(flip.sum()))
            rows.append(row)
            labels.append(ci)
    return np.stack(rows), np.asarray(labels)


def test_kmode_recovers_separable_clusters():
    rng = np.random.default_rng(5)
    x, truth = _clustered_categorical(rng, k=3, per=30, n=120, c=6)
    labels, _ = kmode(x, k=3, seed=1, n_categories=6)
    assert purity(truth, labels) > 0.9


def test_kmode_precomputed_with_exact_distance():
    rng = np.random.default_rng(6)
    x, truth = _clustered_categorical(rng, k=3, per=25, n=100, c=5)

    def dist(a, b):
        return (a[:, None, :] != b[None, :, :]).sum(-1)

    labels = kmode_precomputed(dist, x, k=3, seed=1)
    assert purity(truth, labels) > 0.9
