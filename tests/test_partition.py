"""repro.index.partition: the partition layer's pinned contracts.

Three load-bearing properties:

1. API boundary — `merge_topk_parts` / `kbest_lex_merge` reject k < 0 and
   return well-typed empties for empty inputs (shape (0, k), int64/float32),
   so cross-partition merges degrade to no-ops instead of crashing on an
   engine with zero shards' worth of candidates.
2. Sharded bit-identity — `shard(n_shards)` after ANY interleaved
   add/remove/compact/migrate history answers topk/radius/pairwise with
   exactly the bits the unsharded engine produces, both metrics, including
   queries served mid-migration (the partition exactness argument).
3. Shard-local maintenance — folds touch one shard's partitions and leave
   sibling base layouts untouched; per-partition gauges and the
   `partition.merge` span land in render_prom()/the trace.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from tests._hyp import given, settings, st

from repro.core import CabinParams, threshold_pairs, topk_rows
from repro.core.allpairs import kbest_lex_merge
from repro.core.cabin import sketch_dense
from repro.index import QueryEngine, merge_topk_parts
from repro.index.partition import shard_of
from repro.runtime import faultinject

N_DIMS = 500
D = 256
P = CabinParams.create(N_DIMS, D, seed=3)


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for i in range(n):
        density = int(rng.integers(10, 80))
        idx = rng.choice(N_DIMS, size=density, replace=False)
        x[i, idx] = rng.integers(1, 8, size=density)
    return x


X = _rows(96, seed=0)
SK = np.asarray(sketch_dense(P, jnp.asarray(X)))
QUERIES = X[:5]


# ---------------------------------------------------------------------------
# merge API boundary (satellite: k validation + well-typed empties)
# ---------------------------------------------------------------------------


def test_merge_topk_parts_negative_k_raises():
    part = (np.zeros((2, 3), np.int64), np.zeros((2, 3), np.float32))
    with pytest.raises(ValueError, match="k must be >= 0"):
        merge_topk_parts(-1, [part])


def test_kbest_lex_merge_negative_k_raises():
    with pytest.raises(ValueError, match="k must be >= 0"):
        kbest_lex_merge(-2, np.zeros((1, 2), np.float32),
                        np.zeros((1, 2), np.int64))


@pytest.mark.parametrize("kk", [0, 3])
def test_merge_topk_parts_empty_parts_well_typed(kk):
    """Zero partitions (an empty engine's shard walk) must merge to a
    well-typed empty answer, not an exception or an object array."""
    ids, vals = merge_topk_parts(kk, [])
    assert ids.shape == (0, kk) and vals.shape == (0, kk)
    assert ids.dtype == np.int64 and vals.dtype == np.float32


def test_merge_topk_parts_pads_narrow_parts():
    """A partition holding fewer than k rows contributes padded columns
    that always lose the lex merge — never garbage ids."""
    a = (np.array([[5]], np.int64), np.array([[1.0]], np.float32))
    b = (np.array([[2, 7]], np.int64), np.array([[0.5, 3.0]], np.float32))
    ids, vals = merge_topk_parts(3, [a, b])
    np.testing.assert_array_equal(ids, [[2, 5, 7]])
    np.testing.assert_array_equal(vals, np.array([[0.5, 1.0, 3.0]],
                                                 np.float32))


def test_shard_of_is_id_mod_n():
    ids = np.array([0, 1, 5, 8, 13], np.int64)
    np.testing.assert_array_equal(shard_of(ids, 3), ids % 3)


# ---------------------------------------------------------------------------
# partition topology invariants
# ---------------------------------------------------------------------------


def test_partitions_route_by_id_and_cover_alive_set():
    """Every alive id lands in exactly one shard's partitions, chosen by
    id % n_shards — deterministic and independent of insertion history."""
    eng = QueryEngine(P, band_rows=8, cache_entries=0)
    eng.add_dense(X[:48])
    eng.remove(np.arange(0, 48, 7))
    eng.shard(n_shards=3)
    lay = eng.sync_layout()
    seen = []
    for p in lay.partitions():
        assert p.kind in ("sorted-banded", "brute-delta")
        if p.n_rows:
            np.testing.assert_array_equal(p.ids % 3, p.shard)
        seen.append(p.ids)
    got = np.sort(np.concatenate(seen))
    np.testing.assert_array_equal(got, np.sort(eng.ids()))
    assert eng.stats()["n_shards"] == 3


def test_fold_is_shard_local():
    """Tombstoning one shard's rows folds THAT shard; the sibling shard's
    base layout object is untouched (no global rebuild)."""
    eng = QueryEngine(P, band_rows=4, merge_ratio=0.5, cache_entries=0)
    eng.add_dense(X[:32])
    eng.shard(n_shards=2)
    lay = eng.sync_layout()
    parts = lay.partitions()  # [base0, delta0, base1, delta1]
    base0, base1 = parts[0].banded, parts[2].banded
    merges0 = lay.n_merges
    # kill 14 of shard 0's 16 rows: dead_base > base_alive trips the fold
    eng.remove(np.arange(0, 28, 2))
    lay2 = eng.sync_layout()
    assert lay2 is lay  # same PartitionSet, synced in place
    parts2 = lay2.partitions()
    assert parts2[0].banded is not base0  # shard 0 folded
    assert parts2[2].banded is base1      # shard 1 untouched
    assert lay2.n_merges == merges0 + 1   # exactly one shard-local fold
    alive = eng.ids()
    ref_i, ref_v = topk_rows(SK[:4], SK[alive], 5, d=D, metric="cham")
    got_i, got_v = eng.topk(X[:4], 5)
    np.testing.assert_array_equal(got_i, alive[ref_i])
    np.testing.assert_array_equal(got_v, ref_v)


# ---------------------------------------------------------------------------
# sharded bit-identity over arbitrary histories (the tentpole contract)
# ---------------------------------------------------------------------------


def _assert_parity(ref, sh, rng):
    q = X[rng.integers(0, len(X), size=4)]
    k = int(rng.integers(1, 9))
    ri, rv = ref.topk(q, k)
    si, sv = sh.topk(q, k)
    np.testing.assert_array_equal(si, ri)
    np.testing.assert_array_equal(sv, rv)
    r = 60.0 if ref.metric == "cham" else 30.0
    for a, b in zip(sh.radius(q, r), ref.radius(q, r)):
        np.testing.assert_array_equal(a, b)
    if not ref.migrating:
        rp = ref.pairwise(q[:2])
        sp = sh.pairwise(q[:2])
        np.testing.assert_array_equal(sp[0], rp[0])
        np.testing.assert_array_equal(sp[1], rp[1])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 2))
def test_sharded_engine_bit_identical_any_history(seed, shard_idx):
    """The acceptance property: a sharded engine is bit-identical to the
    unsharded engine after ANY interleaved add/remove/compact/migrate
    history, for topk, radius AND pairwise, both metrics — including
    queries answered mid-migration across spec tiers."""
    n_shards = (2, 3, 8)[shard_idx]
    metric = ("cham", "hamming")[seed % 2]
    rng = np.random.default_rng(seed)
    kw = dict(metric=metric, band_rows=16, merge_ratio=0.5, cache_entries=0)
    ref = QueryEngine(P, **kw)
    sh = QueryEngine(P, **kw)
    sh.shard(n_shards=n_shards)
    pos = 0
    for _ in range(5):
        op = rng.random()
        if op < 0.50 or len(ref) < 8:
            c = int(rng.integers(1, 14))
            rows = np.arange(pos, pos + c) % len(X)
            pos += c
            np.testing.assert_array_equal(ref.add_dense(X[rows]),
                                          sh.add_dense(X[rows]))
        elif op < 0.72:
            alive = ref.ids()
            drop = rng.choice(alive, size=int(rng.integers(1, 5)),
                              replace=False)
            assert ref.remove(drop) == sh.remove(drop)
        elif op < 0.88 or ref.migrating:
            ref.compact()
            sh.compact()
        else:
            ref.migrate(d=320, drive="manual", batch_rows=16)
            sh.migrate(d=320, drive="manual", batch_rows=16)
            ref.migration_step()
            sh.migration_step()  # mid-migration: three-store serving
        _assert_parity(ref, sh, np.random.default_rng(seed + 1))
    if ref.migrating:
        ref.migrate_all()
        sh.migrate_all()
    _assert_parity(ref, sh, np.random.default_rng(seed + 2))


def test_reshard_changes_topology_not_answers():
    """shard() is a pure layout move: re-sharding an already-sharded
    engine (including back to 1) never changes a single answer bit."""
    eng = QueryEngine(P, band_rows=8, cache_entries=0)
    eng.add_dense(X[:64])
    eng.remove(np.arange(5))
    want_i, want_v = eng.topk(QUERIES, 6)
    for n in (4, 8, 1, 3):
        eng.shard(n_shards=n)
        got_i, got_v = eng.topk(QUERIES, 6)
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_v, want_v)
        assert eng.stats()["n_shards"] == n


# ---------------------------------------------------------------------------
# observability: per-partition gauges + the merge span (satellite)
# ---------------------------------------------------------------------------


def test_partition_gauges_and_merge_span_shapes():
    from repro import obs

    eng = QueryEngine(P, band_rows=8, cache_entries=0)
    eng.add_dense(X[:24])
    eng.shard(n_shards=2)
    eng.topk(QUERIES, 4)
    if eng.obs.is_null:  # REPRO_OBS=0: the instruments are no-ops
        pytest.skip("obs disabled in this environment")
    text = eng.render_prom()
    assert "partition_rows" in text
    for shard in ("0", "1"):
        assert f'shard="{shard}"' in text
    for kind in ("sorted-banded", "brute-delta"):
        assert f'kind="{kind}"' in text
    assert 'role="serve"' in text and 'device="host"' in text
    names = {e["name"] for e in obs.trace_events()}
    assert "partition.merge" in names


# ---------------------------------------------------------------------------
# crash safety: shard.rebalance is a derived-state point (satellite)
# ---------------------------------------------------------------------------


def test_shard_rebalance_crash_is_retryable():
    """A crash mid-rebalance loses no state: the layout is derived, the
    point fires before any group is swapped, so the next query simply
    rebuilds and serves the exact same bits as an engine that never
    crashed."""
    eng = QueryEngine(P, band_rows=8, cache_entries=0)
    eng.add_dense(X[:40])
    want_i, want_v = eng.topk(QUERIES, 5)
    eng.shard(n_shards=4)
    faultinject.record_hits(True)
    faultinject.clear_hits()
    try:
        with faultinject.armed("shard.rebalance"):
            with pytest.raises(faultinject.InjectedCrash) as exc:
                eng.topk(QUERIES, 5)  # first sharded query rebuilds
        assert exc.value.point == "shard.rebalance"
        got_i, got_v = eng.topk(QUERIES, 5)  # retry: rebuild succeeds
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_v, want_v)
        assert eng.stats()["n_shards"] == 4
    finally:
        faultinject.record_hits(False)
