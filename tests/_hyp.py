"""Optional-`hypothesis` shim for the property tests.

The container this repo is developed in does not ship `hypothesis`
(see requirements-dev.txt); hard imports used to abort the whole tier-1
suite at collection.  This module re-exports the real `given` / `settings` /
`strategies` when the package is installed, and otherwise provides a tiny
deterministic fallback that draws a fixed number of seeded examples from the
few strategy shapes these tests actually use (`integers`, `lists`, `text`).

The fallback is NOT hypothesis: no shrinking, no database, no edge-case
bias — just seeded random sampling so the properties still get exercised.
Install `hypothesis` (pip install -r requirements-dev.txt) for the real
thing.
"""

from __future__ import annotations

import string

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def sample(self, rng):  # pragma: no cover - abstract
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Lists(_Strategy):
        def __init__(self, elems: _Strategy, min_size: int, max_size: int):
            self.elems, self.min_size, self.max_size = elems, min_size, max_size

        def sample(self, rng):
            size = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elems.sample(rng) for _ in range(size)]

    class _Text(_Strategy):
        _ALPHABET = string.ascii_letters + string.digits + " .,;:!?\n\t"

        def __init__(self, max_size: int):
            self.max_size = max_size

        def sample(self, rng):
            size = int(rng.integers(0, self.max_size + 1))
            chars = rng.integers(0, len(self._ALPHABET), size=size)
            return "".join(self._ALPHABET[c] for c in chars)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elems: _Strategy, *, min_size: int = 0,
                  max_size: int = 16) -> _Strategy:
            return _Lists(elems, min_size, max_size)

        @staticmethod
        def text(*, max_size: int = 32) -> _Strategy:
            return _Text(max_size)

    st = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_kw):
        """Record max_examples on the decorated function (order-agnostic
        with `given`: the wrapper re-reads the attribute at call time)."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            # No functools.wraps: copying __wrapped__ would make pytest
            # introspect the original signature and demand fixtures for the
            # strategy-filled parameters.
            def wrapper():
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = np.random.default_rng(0xB1A5)
                for _ in range(n):
                    fn(*[s.sample(rng) for s in strats])

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
