"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis when installed, fallback otherwise

from repro.kernels.cabin_build.kernel import cabin_build
from repro.kernels.cabin_build.ops import cabin_sketch
from repro.kernels.cabin_build.ref import cabin_build_ref
from repro.kernels.cabin_build_sparse.kernel import cabin_build_sparse
from repro.kernels.cabin_build_sparse.ops import cabin_sketch_sparse
from repro.kernels.cabin_build_sparse.ref import cabin_build_sparse_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention, chunked_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hamming.kernel import pair_stats, row_popcount
from repro.kernels.hamming.ops import cham_matrix_fast
from repro.kernels.hamming.ref import pair_stats_ref, row_popcount_ref
from repro.core.cabin import CabinParams
from repro.core.cham import cham_matrix

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# hamming / pair_stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n,w,bm,bn,bk",
    [
        (1, 1, 1, 8, 8, 4),
        (16, 16, 8, 8, 8, 4),
        (37, 29, 9, 16, 16, 4),   # ragged: padding on every axis
        (64, 33, 17, 32, 16, 8),
        (128, 128, 32, 128, 128, 32),  # exact tiling
    ],
)
def test_pair_stats_shapes(m, n, w, bm, bn, bk):
    a = jnp.asarray(RNG.integers(-(2**31), 2**31, size=(m, w)).astype(np.int32))
    b = jnp.asarray(RNG.integers(-(2**31), 2**31, size=(n, w)).astype(np.int32))
    i1, h1 = pair_stats(a, b, interpret=True, bm=bm, bn=bn, bk=bk)
    i2, h2 = pair_stats_ref(a, b)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_pair_stats_single_op_modes():
    a = jnp.asarray(RNG.integers(-(2**31), 2**31, size=(9, 5)).astype(np.int32))
    inner, ham = pair_stats(a, a, op_ham=False, interpret=True, bm=8, bn=8, bk=4)
    assert ham is None
    inner2, ham2 = pair_stats(a, a, op_inner=False, interpret=True, bm=8, bn=8, bk=4)
    assert inner2 is None
    ri, rh = pair_stats_ref(a, a)
    np.testing.assert_array_equal(np.asarray(inner), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ham2), np.asarray(rh))


@given(st.integers(1, 80), st.integers(1, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_row_popcount_property(m, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-(2**31), 2**31, size=(m, w)).astype(np.int32))
    got = row_popcount(x, interpret=True, bm=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(row_popcount_ref(x)))


def test_cham_matrix_fast_matches_core():
    d = 512
    p = CabinParams.create(1000, d, seed=0)
    from repro.core.cabin import sketch_dense

    x = jnp.asarray(RNG.integers(0, 5, size=(24, 1000)).astype(np.int32))
    sk = sketch_dense(p, x)
    fast = cham_matrix_fast(sk, sk, d, use_pallas=True)
    slow = cham_matrix(sk, sk, d)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow), rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# cabin_build
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rows,n,d,bm,bd,bk",
    [
        (1, 50, 128, 8, 128, 64),
        (19, 700, 256, 8, 128, 128),
        (8, 1000, 512, 8, 512, 256),
        (33, 333, 384, 16, 128, 128),  # d with non-power-of-two block count
    ],
)
def test_cabin_build_shapes(rows, n, d, bm, bd, bk):
    x = jnp.asarray(RNG.integers(0, 9, size=(rows, n)).astype(np.int32))
    got = cabin_build(x, d=d, psi_seed=7, pi_seed=13, bm=bm, bd=bd, bk=bk,
                      interpret=True)
    want = cabin_build_ref(x, d=d, psi_seed=7, pi_seed=13)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cabin_build_all_missing():
    x = jnp.zeros((4, 100), jnp.int32)
    got = cabin_build(x, d=128, psi_seed=1, pi_seed=2, bm=8, bd=128, bk=64,
                      interpret=True)
    assert int(jnp.abs(got).sum()) == 0


def test_cabin_ops_wrapper_dispatch():
    p = CabinParams.create(200, 128, seed=5)
    x = jnp.asarray(RNG.integers(0, 4, size=(6, 200)).astype(np.int32))
    a = cabin_sketch(p, x, use_pallas=True, interpret=True)
    b = cabin_sketch(p, x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # unaligned d falls back to reference silently
    p2 = CabinParams.create(200, 100, seed=5)
    c = cabin_sketch(p2, x)
    assert c.shape == (6, 4)  # ceil(100/32)


# ---------------------------------------------------------------------------
# cabin_build_sparse
# ---------------------------------------------------------------------------


def _coo_rows(rng, rows, n, m, c=12):
    """Padded-COO rows with per-row random support (value 0 = padding)."""
    idx = np.zeros((rows, m), np.int32)
    val = np.zeros((rows, m), np.int32)
    for i in range(rows):
        nnz = int(rng.integers(0, m + 1))
        if nnz:
            idx[i, :nnz] = rng.choice(n, size=nnz, replace=False)
            val[i, :nnz] = rng.integers(1, c + 1, size=nnz)
    return idx, val


@pytest.mark.parametrize(
    "rows,n,m,d,bm,bd,bk",
    [
        (1, 500, 7, 128, 8, 128, 64),
        (19, 5000, 60, 256, 8, 128, 32),
        (33, 100000, 130, 384, 16, 128, 128),  # non-power-of-two block count
        (8, 1000, 200, 512, 8, 512, 128),
    ],
)
def test_cabin_build_sparse_shapes(rows, n, m, d, bm, bd, bk):
    idx, val = _coo_rows(RNG, rows, n, m)
    got = cabin_build_sparse(jnp.asarray(idx), jnp.asarray(val), d=d,
                             psi_seed=7, pi_seed=13, bm=bm, bd=bd, bk=bk,
                             interpret=True)
    want = cabin_build_sparse_ref(jnp.asarray(idx), jnp.asarray(val),
                                  n_dims=n, d=d, psi_seed=7, pi_seed=13)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cabin_build_sparse_all_padding():
    idx = jnp.zeros((4, 50), jnp.int32)
    val = jnp.zeros((4, 50), jnp.int32)
    got = cabin_build_sparse(idx, val, d=128, psi_seed=1, pi_seed=2,
                             interpret=True)
    assert int(jnp.abs(got).sum()) == 0


def test_cabin_build_sparse_matches_dense_kernel():
    """Sparse and dense fused kernels agree on the same logical rows."""
    rng = np.random.default_rng(77)
    rows, n, density, d = 6, 700, 40, 256
    x = np.zeros((rows, n), np.int32)
    idx = np.zeros((rows, density), np.int32)
    val = np.zeros((rows, density), np.int32)
    for i in range(rows):
        pos = rng.choice(n, size=density, replace=False)
        cats = rng.integers(1, 9, size=density)
        x[i, pos] = cats
        idx[i], val[i] = pos, cats
    dense = cabin_build(jnp.asarray(x), d=d, psi_seed=3, pi_seed=5,
                        bm=8, bd=128, bk=128, interpret=True)
    sparse = cabin_build_sparse(jnp.asarray(idx), jnp.asarray(val), d=d,
                                psi_seed=3, pi_seed=5, interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


def test_cabin_sparse_ops_wrapper_dispatch():
    p = CabinParams.create(3000, 128, seed=5)
    idx, val = _coo_rows(RNG, 6, 3000, 40)
    a = cabin_sketch_sparse(p, jnp.asarray(idx), jnp.asarray(val),
                            use_pallas=True, interpret=True)
    b = cabin_sketch_sparse(p, jnp.asarray(idx), jnp.asarray(val),
                            use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # unaligned d falls back to the jnp reference silently
    p2 = CabinParams.create(3000, 100, seed=5)
    c = cabin_sketch_sparse(p2, jnp.asarray(idx), jnp.asarray(val))
    assert c.shape == (6, 4)  # ceil(100/32)


def test_sketch_sparse_core_dispatch_bit_identical():
    """core.cabin.sketch_sparse: kernel dispatch == jnp fallback, bit for bit."""
    from repro.core.cabin import sketch_sparse, sketch_sparse_jnp

    p = CabinParams.create(5000, 256, seed=9)
    idx, val = _coo_rows(RNG, 11, 5000, 70)
    via_kernel = sketch_sparse(p, jnp.asarray(idx), jnp.asarray(val),
                               use_pallas=True, interpret=True)
    via_jnp = sketch_sparse_jnp(p, jnp.asarray(idx), jnp.asarray(val))
    np.testing.assert_array_equal(np.asarray(via_kernel), np.asarray(via_jnp))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,bq,bk,causal",
    [
        (1, 2, 2, 128, 64, 64, 64, True),
        (2, 4, 2, 256, 64, 64, 64, True),    # GQA 2:1
        (1, 8, 1, 128, 32, 64, 32, True),    # MQA
        (1, 2, 2, 128, 64, 64, 64, False),   # bidirectional (encoder)
        (2, 4, 4, 128, 128, 128, 128, True), # single block
    ],
)
def test_flash_attention_shapes(b, hq, hkv, s, dh, bq, bk, causal):
    q = jnp.asarray(RNG.standard_normal((b, hq, s, dh)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, dh)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, dh)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
        rtol=0.05, atol=0.05,
    )


def test_chunked_attention_matches_ref_cross_lengths():
    # decode-like: q shorter than kv
    q = jnp.asarray(RNG.standard_normal((1, 4, 64, 32)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=False, block=64)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_dispatcher():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)).astype(np.float32))
    for impl in ("pallas", "chunked", "ref"):
        out = attention(q, k, v, causal=True, impl=impl, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(attention_ref(q, k, v, causal=True)),
            rtol=2e-5, atol=2e-5,
        )
