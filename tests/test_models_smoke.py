"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward + one train-ish step on CPU, shape and NaN assertions, plus
decode-vs-forward autoregressive consistency for cached mixers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParallelConfig, reduced_for_smoke
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T

PCFG = ParallelConfig(remat="none", sequence_parallel=False)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(3, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.kind == "encdec" or cfg.frontend is not None:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced_for_smoke(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = T.forward(cfg, params, batch, PCFG)
    s_total = batch["tokens"].shape[1]
    if cfg.frontend is not None and cfg.kind != "encdec":
        s_total += cfg.n_frontend_tokens
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_grad_step_no_nans(arch):
    cfg = reduced_for_smoke(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, s=8)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = T.forward(cfg, p, batch, PCFG)
        logits = logits[:, -labels.shape[1]:, :]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll) + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch):
    cfg = reduced_for_smoke(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, s=12)
    logits_f, _ = T.forward(cfg, params, batch, PCFG)
    logits_p, caches = T.prefill(cfg, params, batch, max_len=24, pcfg=PCFG)
    np.testing.assert_allclose(np.asarray(logits_f), np.asarray(logits_p),
                               rtol=1e-4, atol=1e-4)
    assert caches is not None


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_7b", "deepseek_v3_671b",
                                  "xlstm_350m", "jamba_v0_1_52b"])
def test_decode_consistent_with_forward(arch):
    """Teacher-forced decode after prefill reproduces forward() logits.

    MoE configs get a no-drop capacity factor: capacity-based token dropping
    legitimately differs between a 20-token forward and a 2-token decode
    step, which is a property of capacity MoE, not of the cache."""
    from dataclasses import replace

    cfg = reduced_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=64.0))
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    b, s_pre, s_tot = 2, 6, 10
    full = _batch(cfg, b=b, s=s_tot, seed=7)
    pre = {k: (v[:, :s_pre] if k == "tokens" else v) for k, v in full.items()}
    logits_full, _ = T.forward(cfg, params, full, PCFG)
    _, caches = T.prefill(cfg, params, pre, max_len=s_tot, pcfg=PCFG)
    offset = cfg.n_frontend_tokens if (cfg.frontend and cfg.kind != "encdec") else 0
    for t in range(s_pre, s_tot):
        # decode consumes the token AT position t (teacher forcing the true
        # token) and must reproduce forward logits at position t.
        tok = full["tokens"][:, t:t + 1]
        logits_d, caches = T.decode_step(cfg, params, caches, tok,
                                         jnp.int32(t + offset), PCFG)
        want = logits_full[:, t + offset]
        got = logits_d[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs_match_names():
    """Full (non-reduced) configs produce parameter counts in the right
    ballpark of their names, via abstract eval (no allocation)."""
    import re

    expected = {
        "llama3_8b": 8.0e9,
        "deepseek_7b": 6.9e9,
        "qwen2_7b": 7.6e9,
        "internlm2_1_8b": 1.8e9,
        "deepseek_v3_671b": 671e9,
        "dbrx_132b": 132e9,
        "jamba_v0_1_52b": 52e9,
        "xlstm_350m": 0.35e9,
        "phi_3_vision_4_2b": 3.8e9,  # backbone only (vision tower stubbed)
        "whisper_tiny": 0.037e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda key: T.init_params(cfg, key), jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        assert 0.5 * target < n < 1.6 * target, (arch, n, target)


def test_hashed_embedding_variant():
    """CabinEmbed flag shrinks embedding params and still trains."""
    from dataclasses import replace

    cfg = reduced_for_smoke(get_config("llama3_8b"))
    cfg = replace(cfg, hashed_embedding=True, hashed_embedding_buckets=64,
                  hashed_embedding_k=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    assert "hashed_embed" in params and "embed" not in params
    assert params["hashed_embed"]["table"].shape == (64, cfg.d_model)
    batch = _batch(cfg, s=8)
    logits, _ = T.forward(cfg, params, batch, PCFG)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))
