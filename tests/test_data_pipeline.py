"""Data-substrate tests: pipeline determinism, dedup correctness, synthetic
generator statistics, tokenizer round-trips."""

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis when installed, fallback otherwise

from repro.data import tokenizer
from repro.data.dedup import (dedup_by_sketch, dedup_exact,
                              docs_to_categorical, sketch_corpus)
from repro.data.pipeline import (BatchPipeline, PipelineConfig,
                                 synthetic_documents)
from repro.data.synthetic import TABLE1, sample_dense, sample_sparse, scaled_spec


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------


@given(st.text(max_size=80))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip(text):
    ids = tokenizer.encode(text)
    assert ids[0] == tokenizer.BOS_ID and ids[-1] == tokenizer.EOS_ID
    assert tokenizer.decode(ids) == text


def test_tokenizer_pad_or_trim():
    ids = tokenizer.encode("hello")
    padded = tokenizer.pad_or_trim(ids, 32)
    assert padded.shape == (32,) and (padded[len(ids):] == 0).all()
    trimmed = tokenizer.pad_or_trim(ids, 3)
    assert trimmed.shape == (3,)


def test_tokenizer_decode_ignores_out_of_range():
    # 100 -> byte 97 ('a'); 0/1/2 specials and >=259 ids are skipped
    assert tokenizer.decode([1, 2, 0, 99999, 100]) == "a"


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_across_instances():
    cfg = PipelineConfig(vocab_size=256, seq_len=64, global_batch=4, seed=7)
    p1, p2 = BatchPipeline(cfg), BatchPipeline(cfg)
    for _ in range(3):
        b1, b2 = next(p1), next(p2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    p1.close()
    p2.close()


def test_pipeline_labels_shifted():
    cfg = PipelineConfig(vocab_size=256, seq_len=32, global_batch=2, seed=1)
    p = BatchPipeline(cfg)
    b = next(p)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    p.close()


def test_pipeline_host_sharding():
    """Two hosts of a 2-host pipeline produce disjoint, stable streams."""
    kw = dict(vocab_size=256, seq_len=32, global_batch=4, seed=3, n_hosts=2)
    p0 = BatchPipeline(PipelineConfig(host_index=0, **kw))
    p1 = BatchPipeline(PipelineConfig(host_index=1, **kw))
    b0, b1 = next(p0), next(p1)
    assert b0["tokens"].shape == (2, 32)  # global 4 / 2 hosts
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    p0.close()
    p1.close()


# ---------------------------------------------------------------------------
# dedup
# ---------------------------------------------------------------------------


def test_dedup_sketch_matches_exact():
    gen = synthetic_documents(2048, seed=9, dup_fraction=0.3)
    docs = [next(gen) for _ in range(120)]
    idx, val = docs_to_categorical(docs, 2048)
    _, sk = sketch_corpus(idx, val, 2048, sketch_dim=512, seed=0)
    got = dedup_by_sketch(sk, 512, threshold=30.0)
    want = dedup_exact(idx, val, 2048, threshold=30.0)
    agreement = (got.keep_mask == want.keep_mask).mean()
    assert agreement > 0.95
    assert got.n_removed > 10  # duplicates exist and are found


def test_dedup_no_duplicates_keeps_all():
    gen = synthetic_documents(2048, seed=11, dup_fraction=0.0)
    docs = [next(gen) for _ in range(60)]
    idx, val = docs_to_categorical(docs, 2048)
    _, sk = sketch_corpus(idx, val, 2048, sketch_dim=512, seed=0)
    got = dedup_by_sketch(sk, 512, threshold=5.0)
    assert got.n_removed == 0


# ---------------------------------------------------------------------------
# synthetic generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TABLE1))
def test_synthetic_matches_table1_stats(name):
    spec = scaled_spec(TABLE1[name], 0.02)
    idx, val, _ = sample_sparse(spec, 32, seed=0)
    density = (val != 0).sum(1)
    assert abs(density.mean() - spec.density) < 0.35 * spec.density + 4
    assert val.max() <= spec.n_categories
    assert idx.max() < spec.n_dims


def test_sample_dense_clusters_are_coherent():
    spec = scaled_spec(TABLE1["kos"], 0.1)
    x, labels = sample_dense(spec, 24, seed=1, cluster_centers=3)
    # same-cluster rows are closer than cross-cluster rows on average
    same, cross = [], []
    for i in range(24):
        for j in range(i + 1, 24):
            hd = int((x[i] != x[j]).sum())
            (same if labels[i] == labels[j] else cross).append(hd)
    assert np.mean(same) < np.mean(cross)


def test_document_windows_shapes_and_reiterables():
    from repro.data.pipeline import document_windows

    docs = [np.full(i + 1, i, np.int32) for i in range(7)]
    # a LIST input is consumed once, not restarted per window
    wins = list(document_windows(docs, window=3))
    assert [len(w) for w in wins] == [3, 3, 1]
    np.testing.assert_array_equal(wins[2][0], docs[6])
    # exact multiple: no trailing empty window
    wins = list(document_windows(iter(docs[:6]), window=3))
    assert [len(w) for w in wins] == [3, 3]
    assert list(document_windows(iter([]), window=4)) == []
    with pytest.raises(ValueError):
        list(document_windows(iter(docs), window=0))
