"""Fused topk_select kernel (interpret mode) vs oracles.

Contracts pinned here:
  * the kernel's running compare-exchange merge equals the dense-matrix +
    stable-argsort reference — same columns, same (distance, column)
    tie-break — across ragged shapes, both metrics, with and without
    m_valid masking;
  * "hamming" distances are exact integers and match bit-for-bit on every
    path; "cham" indices match and values agree to cross-graph libm noise
    (the same ~1e-7-relative caveat kernels.hamming.ops.dist_matrix
    documents — the bit-identity contract belongs to core.allpairs, whose
    jnp path the serving layer uses off-TPU);
  * core.allpairs.topk_rows mode="pallas" (the TPU serving route) agrees
    with its jnp tile loop.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import allpairs
from repro.kernels.topk_select.kernel import topk_select as topk_select_kernel
from repro.kernels.topk_select.ops import topk_select
from repro.kernels.topk_select.ref import topk_select_ref

RNG = np.random.default_rng(4321)
D = 256


def _rows(n, w):
    return jnp.asarray(
        RNG.integers(-(2**31), 2**31, size=(n, w)).astype(np.int32))


def _check(metric, kv, ki, rv, ri):
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    if metric == "hamming":  # exact integer distances: bit-identical
        np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    else:  # cham: same exact integer stats, cross-graph libm noise
        np.testing.assert_allclose(np.asarray(kv), np.asarray(rv),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("metric", ["cham", "hamming"])
@pytest.mark.parametrize(
    "q,n,w,k,bq,bn",
    [
        (1, 1, 1, 1, 8, 8),
        (9, 37, 8, 5, 4, 8),       # ragged: padding on every axis
        (16, 64, 8, 3, 8, 16),     # exact tiling
        (33, 70, 9, 7, 16, 32),
        (5, 12, 4, 12, 8, 4),      # k == n: every column is a winner
    ],
)
def test_topk_select_shapes(metric, q, n, w, k, bq, bn):
    a = _rows(q, w)
    b = _rows(n, w)
    kv, ki = topk_select_kernel(a, b, n, k, metric=metric, d=D, bq=bq, bn=bn,
                                interpret=True)
    rv, ri = topk_select_ref(a, b, k, d=D, metric=metric)
    _check(metric, kv, ki, rv, ri)


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_topk_select_tie_break_lower_column(metric):
    """Duplicate store rows => equal distances straddling the k boundary on
    every tile edge; the winner must always be the LOWER column."""
    base = _rows(6, 8)
    b = jnp.concatenate([base, base, base], axis=0)  # 3 copies of each
    a = _rows(4, 8)
    kv, ki = topk_select_kernel(a, b, b.shape[0], 7, metric=metric, d=D,
                                bq=4, bn=4, interpret=True)
    rv, ri = topk_select_ref(a, b, 7, d=D, metric=metric)
    _check(metric, kv, ki, rv, ri)
    # self-query on the duplicated store: first two hits are copies at the
    # same distance, ordered by column
    kv2, ki2 = topk_select_kernel(base, b, b.shape[0], 2, metric=metric, d=D,
                                  bq=4, bn=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(ki2[:, 0]), np.arange(6))
    np.testing.assert_array_equal(np.asarray(ki2[:, 1]), np.arange(6, 12))
    np.testing.assert_array_equal(np.asarray(kv2[:, 0]),
                                  np.asarray(kv2[:, 1]))


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_topk_select_m_valid_masks_padding(metric):
    """Columns past the traced valid count can never be returned, whatever
    garbage the padding rows hold."""
    a = _rows(6, 8)
    b = _rows(40, 8)
    for m in (17, 32, 40):
        kv, ki = topk_select_kernel(a, b, m, 9, metric=metric, d=D,
                                    bq=8, bn=16, interpret=True)
        rv, ri = topk_select_ref(a, b, 9, d=D, metric=metric, m_valid=m)
        _check(metric, kv, ki, rv, ri)
        assert int(np.asarray(ki).max()) < m


def test_topk_select_ops_dispatch_and_errors():
    a = _rows(5, 8)
    b = _rows(21, 8)
    kv, ki = topk_select(a, b, 4, d=D, use_pallas=True, interpret=True)
    rv, ri = topk_select(a, b, 4, d=D, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kv), np.asarray(rv),
                               rtol=1e-5, atol=1e-3)
    # k clamps to m_valid; empty edges return (Q, 0)
    kv0, ki0 = topk_select(a, b, 3, d=D, m_valid=0)
    assert kv0.shape == (5, 0) and ki0.shape == (5, 0)
    kv1, ki1 = topk_select(a[:0], b, 3, d=D)
    assert kv1.shape == (0, 0)
    with pytest.raises(ValueError, match="m_valid"):
        topk_select(a, b, 3, d=D, m_valid=22)
    with pytest.raises(ValueError, match="metric"):
        topk_select(a, b, 3, d=D, metric="cosine", use_pallas=False)


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_topk_rows_pallas_mode_matches_jnp(metric):
    """The serving dispatch: allpairs.topk_rows mode="pallas" (fused kernel)
    vs its jnp tile loop — identical columns under both metrics."""
    a = _rows(9, 8)
    b = _rows(50, 8)
    pi, pv = allpairs.topk_rows(a, b, 6, d=D, metric=metric, mode="pallas",
                                block=16, m_valid=44)
    ji, jv = allpairs.topk_rows(a, b, 6, d=D, metric=metric,
                                block=16, m_valid=44)
    np.testing.assert_array_equal(pi, ji)
    if metric == "hamming":
        np.testing.assert_array_equal(pv, jv)
    else:
        np.testing.assert_allclose(pv, jv, rtol=1e-5, atol=1e-3)
