"""repro.obs: the flight recorder's accuracy, exporters, and off switch.

Four contracts under test:

  * instrument accuracy — pow2-bucket histogram quantiles are within one
    bucket of the true order statistic, merge is lossless at the bucket
    level, counters stay exact (they mirror the engine's own accounting);
  * exporters — `render_prom()` is valid Prometheus text exposition
    (cumulative monotone buckets, `_count`/`_sum` agreement) and
    `export_trace()` is loadable Chrome trace-event JSON whose spans cover
    the serving ops and whose instants mark faultinject crash points;
  * the off switch — REPRO_OBS=0 (env, subprocess-tested) and
    `obs.configure(False)` (runtime) hand every call site shared null
    instruments: results stay bit-identical and ZERO additional jit graphs
    compile relative to the instrumented run;
  * gauge truth at recovery — `engine_migration_progress` is exact at
    every faultinject crash/resume point of the migration matrix.
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.core.cabin import CabinParams
from repro.index import QueryEngine
from repro.index.engine import compile_cache_entries
from repro.obs.registry import Histogram, MetricsRegistry
from repro.runtime import faultinject

N_DIMS = 300
P = CabinParams(n_dims=N_DIMS, sketch_dim=64, psi_seed=21, pi_seed=22)
P_NEW = CabinParams(n_dims=N_DIMS, sketch_dim=128, psi_seed=21, pi_seed=22)

requires_obs = pytest.mark.skipif(
    not obs.enabled(), reason="suite running with REPRO_OBS=0")


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for r in range(n):
        cols = rng.choice(N_DIMS, size=rng.integers(8, 25), replace=False)
        x[r, cols] = rng.integers(1, 6, size=len(cols))
    return x


X = _rows(64, seed=0)
QUERIES = X[:4]


@pytest.fixture
def obs_restore():
    """Restore the module switch (and the faultinject observer binding)
    after a test that flips `obs.configure`."""
    was = obs.enabled()
    yield
    obs.configure(was)


def _same_or_adjacent_bucket(a: float, b: float) -> bool:
    """True when a and b fall in the same or neighbouring pow2 buckets —
    the histogram's advertised quantile accuracy."""
    ea = math.frexp(a)[1]
    eb = math.frexp(b)[1]
    return abs(ea - eb) <= 1


# ---------------------------------------------------------------------------
# instrument accuracy
# ---------------------------------------------------------------------------


def test_histogram_quantiles_within_one_bucket():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=1.0, sigma=1.5, size=2000)
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    assert h.count == len(samples)
    assert h.min == samples.min() and h.max == samples.max()
    np.testing.assert_allclose(h.sum, samples.sum(), rtol=1e-9)
    for p in (1, 25, 50, 75, 95, 99):
        want = float(np.percentile(samples, p))
        got = h.quantile(p)
        assert h.min <= got <= h.max
        assert _same_or_adjacent_bucket(got, want), (p, got, want)
    # degenerate cases: empty -> NaN; single observation -> that value
    assert math.isnan(Histogram().quantile(50))
    h1 = Histogram()
    h1.observe(3.7)
    assert h1.quantile(50) == 3.7 == h1.quantile(99)


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(8)
    a_s = rng.lognormal(1.0, 1.0, size=500)
    b_s = rng.lognormal(2.0, 0.5, size=700)
    ha, hb, hu = Histogram(), Histogram(), Histogram()
    for v in a_s:
        ha.observe(float(v))
        hu.observe(float(v))
    for v in b_s:
        hb.observe(float(v))
        hu.observe(float(v))
    ha.merge_from(hb)
    assert ha.count == hu.count and ha.buckets == hu.buckets
    assert ha.min == hu.min and ha.max == hu.max
    np.testing.assert_allclose(ha.sum, hu.sum, rtol=1e-9)
    for p in (10, 50, 90):
        assert ha.quantile(p) == hu.quantile(p)


def test_registry_merge_and_kind_collisions():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("reqs_total").inc(3)
    b.counter("reqs_total").inc(4)
    b.counter("other_total", shard="1").inc(2)
    a.histogram("lat_ms").observe(1.0)
    b.histogram("lat_ms").observe(9.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["reqs_total"] == 7
    assert snap["other_total"]["shard=1"] == 2
    assert snap["lat_ms"]["count"] == 2
    # one name keeps one kind — a mixed fleet could not merge or render
    with pytest.raises(ValueError, match="already a Counter"):
        a.gauge("reqs_total")
    # merging a null registry is a no-op, not an error
    a.merge(obs.NULL_REGISTRY)
    assert a.snapshot()["reqs_total"] == 7


def test_render_prom_is_valid_exposition():
    r = MetricsRegistry()
    r.counter("engine_cache_hits_total").inc(5)
    r.gauge_fn("rows_alive", lambda: 42.0)
    h = r.histogram("lat_ms", op="topk")
    for v in (0.3, 0.9, 2.0, 2.1, 7.5):
        h.observe(v)
    text = r.render_prom()
    lines = [ln for ln in text.strip().splitlines()]
    assert "# TYPE engine_cache_hits_total counter" in lines
    assert "engine_cache_hits_total 5" in lines
    assert "rows_alive 42.0" in lines
    # histogram: cumulative bucket counts are monotone and end at _count
    buckets = [ln for ln in lines if ln.startswith("lat_ms_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)
    assert buckets[-1].startswith('lat_ms_bucket{op="topk",le="+Inf"}')
    assert counts[-1] == 5
    assert 'lat_ms_count{op="topk"} 5' in lines
    # every sample line is NAME{LABELS} VALUE with a parseable value
    for ln in lines:
        if ln.startswith("#"):
            continue
        float(ln.rsplit(" ", 1)[1])


# ---------------------------------------------------------------------------
# the off switch
# ---------------------------------------------------------------------------


def test_disabled_path_bit_identical_and_zero_new_graphs(obs_restore):
    """The REPRO_OBS=0 contract: an engine built under the disabled switch
    answers bit-identically AND compiles zero jit graphs beyond what the
    instrumented run already compiled — instrumentation never reaches the
    compiled graphs, it only wraps them on host."""
    obs.configure(True)
    eng_on = QueryEngine(P, cache_entries=4)
    assert not eng_on.obs.is_null

    def journey(eng):
        eng.add_dense(X[:48])
        a = eng.topk(QUERIES, 5)
        r = eng.radius(QUERIES, 60.0)
        eng.remove(np.arange(5))
        b = eng.topk(QUERIES, 5)
        b2 = eng.topk(QUERIES, 5)  # LRU hit path
        return a, r, b, b2

    on = journey(eng_on)
    assert eng_on.obs.snapshot()["engine_cache_hits_total"] == 1
    n_graphs = compile_cache_entries()

    obs.configure(False)
    eng_off = QueryEngine(P, cache_entries=4)
    assert eng_off.obs.is_null
    off = journey(eng_off)
    assert compile_cache_entries() == n_graphs, \
        "REPRO_OBS=0 run compiled additional graphs"
    for got, want in zip(off, on):
        if isinstance(got, list):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_array_equal(got[0], want[0])
            np.testing.assert_array_equal(got[1], want[1])
    # the python-side accounting still works; the obs mirror is inert
    assert (eng_off.cache_hits, eng_off.cache_misses) == \
        (eng_on.cache_hits, eng_on.cache_misses)
    assert eng_off.obs.snapshot() == {}
    assert eng_off.render_prom() == ""
    assert "latency_ms" not in eng_off.stats()
    assert "latency_ms" in eng_on.stats()


def test_repro_obs_env_kills_the_layer_in_subprocess():
    """The deployment switch: REPRO_OBS=0 read at import time."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    child = (
        "import numpy as np\n"
        "from repro import obs\n"
        "from repro.core.cabin import CabinParams\n"
        "from repro.index import QueryEngine\n"
        "assert not obs.enabled()\n"
        "assert obs.new_registry() is obs.NULL_REGISTRY\n"
        "p = CabinParams(n_dims=64, sketch_dim=32, psi_seed=1, pi_seed=2)\n"
        "eng = QueryEngine(p)\n"
        "assert eng.obs.is_null\n"
        "x = np.zeros((4, 64), np.int32)\n"
        "x[:, :5] = 1 + np.arange(5)\n"
        "eng.add_dense(x)\n"
        "eng.topk(x, 2)\n"
        "assert eng.obs.snapshot() == {}\n"
        "assert 'latency_ms' not in eng.stats()\n"
        "assert obs.trace_events() == []\n"
        "print('NULLED')\n")
    env = dict(os.environ, PYTHONPATH=src, REPRO_OBS="0")
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "NULLED" in proc.stdout


# ---------------------------------------------------------------------------
# the acceptance run: live engine -> trace + prom + truthful quantiles
# ---------------------------------------------------------------------------


@requires_obs
def test_flight_recorder_acceptance(tmp_path):
    """One mixed serving journey (adds, removes, queries, a full spec
    migration) exports a loadable Chrome trace whose spans cover every op
    and whose instants mark the crash points crossed, plus a Prometheus
    snapshot whose latency quantiles agree with independently measured
    wall times to within one pow2 bucket."""
    import time

    obs.clear_trace()
    eng = QueryEngine(P, cache_entries=0, keep_raw=True)
    eng.add_dense(X[:40])
    eng.remove(np.arange(3))
    eng.add_dense(X[40:])

    outer_ms = []
    for _ in range(8):
        t0 = time.perf_counter()
        eng.topk(QUERIES, 5)
        outer_ms.append((time.perf_counter() - t0) * 1e3)
    eng.radius(QUERIES, 60.0)
    eng.pairwise(QUERIES[:2], ids=eng.ids()[:10])

    eng.migrate(new_params=P_NEW, batch_rows=16, drive="manual")
    while eng.migration_step():
        pass
    assert not eng.migrating

    # -- counters/histograms tell the same story as the engine ------------
    snap = eng.obs_snapshot()
    lat = snap["engine_query_latency_ms"]
    assert lat["op=topk"]["count"] == 8
    assert lat["op=radius"]["count"] == 1
    assert lat["op=pairwise"]["count"] == 1
    h50 = lat["op=topk"]["p50"]
    # the recorder's p50 vs the test's own stopwatch: within one bucket
    # (outer timing adds only host dispatch around the timed region)
    assert _same_or_adjacent_bucket(h50, float(np.percentile(outer_ms, 50)))
    assert lat["op=topk"]["min"] <= h50 <= lat["op=topk"]["p99"] \
        <= lat["op=topk"]["max"] <= sum(outer_ms)
    assert snap["engine_migration_progress"] == 1.0
    assert snap["engine_rows_alive"] == float(len(eng))
    assert snap["migration_rows_resketched_total"] == 61  # 64 - 3 removed
    assert snap["migration_phase_ms"]["phase=resketch"]["count"] >= 4
    assert snap["migration_phase_ms"]["phase=fold"]["count"] == 1
    assert eng.stats()["latency_ms"]["topk"]["p50"] == h50

    # -- prom text covers the same instruments ----------------------------
    text = eng.render_prom()
    assert 'engine_query_latency_ms_bucket{op="topk",le="+Inf"} 8' in text
    assert "engine_rows_alive" in text and "store_rows_added_total" in text

    # -- the trace is loadable and structurally sound ----------------------
    out = str(tmp_path / "trace.json")
    n = obs.export_trace(out)
    with open(out) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    assert len(evs) == n > 0
    names = {e["name"] for e in evs}
    assert {"engine.topk", "engine.radius", "engine.pairwise",
            "migrate.batch", "migrate.fold", "store.append",
            "crash_point"} <= names or \
        {"engine.topk", "engine.radius", "engine.pairwise",
         "migrate.batch", "migrate.fold", "crash_point"} <= names
    for e in evs:
        assert e["ph"] in ("X", "i")
        assert e["ts"] >= 0 and "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0
    crossed = {e["args"]["point"] for e in evs if e["name"] == "crash_point"}
    assert {"migrate.start", "migrate.batch.resketched",
            "migrate.batch.committed", "migrate.fold",
            "migrate.published"} <= crossed
    # export is a read, clear is the reset
    assert obs.trace_events()
    obs.clear_trace()
    assert obs.trace_events() == []


# ---------------------------------------------------------------------------
# migration_progress: exact at every crash/resume point
# ---------------------------------------------------------------------------


@requires_obs
@pytest.mark.parametrize("point", [
    "migrate.start", "migrate.batch.resketched", "migrate.batch.committed",
    "migrate.fold", "migrate.published"])
def test_migration_progress_gauge_exact_at_resume(tmp_path, point):
    """Crash the migration at `point`, restore FROM DISK ONLY, and require
    the progress gauge to be truthful at the resume state and monotone to
    1.0 as the migration is driven home."""
    x = _rows(26, seed=hash(point) % 1000)
    journal = str(tmp_path / "journal")
    eng = QueryEngine(P, cache_entries=0)
    eng.add_dense(x)
    eng.save(journal, step=0, keep=20)

    with faultinject.armed(point):
        try:
            eng.migrate(new_params=P_NEW, batch_rows=7, drive="manual",
                        journal_dir=journal, journal_every=1,
                        journal_keep=20)
            eng.migrate_all()
            crashed = False
        except faultinject.InjectedCrash:
            crashed = True
    assert crashed, f"never reached {point}"

    res = QueryEngine.restore(journal)

    def progress(e):
        return e.obs_snapshot()["engine_migration_progress"]

    p0 = progress(res)
    if res.migrating:
        m = res.stats()["migration"]
        assert p0 == m["progress"]
        # truthful against the migration's own row accounting
        done = res.migration.rows_migrated
        total = done + len(res.migration.src)
        assert p0 == (done / total if total else 1.0)
        assert 0.0 <= p0 <= 1.0
        # monotone to completion, exact at every step
        last = p0
        while res.migration_step():
            p = progress(res)
            assert p >= last
            last = p
    assert not res.migrating
    assert progress(res) == 1.0
    assert res.obs_snapshot()["engine_migration_cursor"] == -1.0


# ---------------------------------------------------------------------------
# thread safety: the front door's real threads vs exporters
# ---------------------------------------------------------------------------


def test_registry_reads_are_safe_under_concurrent_writes():
    """Writers hammer a histogram + counter while readers continuously
    render/snapshot/merge.  Pre-fix, snapshot and render_prom iterated
    live bucket dicts without the instrument lock ("dictionary changed
    size during iteration" under a concurrent observe); now every reader
    goes through Histogram.state().  Final totals must also be exact —
    no update may be lost to a read."""
    import threading

    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    c = reg.counter("events_total")
    n_writers, per_writer = 4, 3000
    stop = threading.Event()
    errors = []

    def writer(seed):
        rng = np.random.default_rng(seed)
        vals = rng.random(per_writer) * 1e4
        for v in vals:
            h.observe(float(v))
            c.inc()

    def reader():
        sink = MetricsRegistry()
        while not stop.is_set():
            try:
                reg.render_prom()
                snap = reg.snapshot()
                hs = snap["lat_ms"]
                # a torn read would let count drift from the bucket sum
                assert hs["count"] >= 0
                h.quantile(99)
                sink.merge(reg)
            except Exception as e:  # pragma: no cover - the regression
                errors.append(e)
                return

    writers = [threading.Thread(target=writer, args=(s,))
               for s in range(n_writers)]
    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors, f"exporter raced a writer: {errors[:1]}"
    assert c.value == n_writers * per_writer
    buckets, count, total, mn, mx = h.state()
    assert count == n_writers * per_writer
    assert sum(buckets.values()) == count
    assert math.isfinite(total) and mn >= 0.0 and mx <= 1e4


def test_histogram_state_is_a_consistent_copy():
    h = Histogram()
    for v in (1.0, 3.0, 100.0):
        h.observe(v)
    buckets, count, total, mn, mx = h.state()
    assert count == 3 and total == pytest.approx(104.0)
    assert (mn, mx) == (1.0, 100.0)
    buckets[99] = 10**6  # mutating the copy must not touch the histogram
    assert h.state()[0] != buckets
    assert h.count == 3
