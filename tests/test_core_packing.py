"""Unit + property tests for bit-packing and popcount primitives."""

import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis when installed, fallback otherwise

from repro.core import packing


@given(st.integers(1, 300), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(d, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(3, d)).astype(np.int32)
    packed = packing.pack_bits(jnp.asarray(bits))
    assert packed.shape == (3, packing.packed_width(d))
    back = packing.unpack_bits(packed, d)
    np.testing.assert_array_equal(np.asarray(back), bits)


@given(st.lists(st.integers(-(2**31), 2**31 - 1), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_popcount32_matches_python(words):
    arr = jnp.asarray(np.asarray(words, dtype=np.int64).astype(np.int32))
    got = np.asarray(packing.popcount32(arr))
    want = [bin(w & 0xFFFFFFFF).count("1") for w in words]
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_packed_hamming_and_inner_match_unpacked(d, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, size=d).astype(np.int32)
    b = rng.integers(0, 2, size=d).astype(np.int32)
    pa, pb = packing.pack_bits(jnp.asarray(a)), packing.pack_bits(jnp.asarray(b))
    assert int(packing.packed_hamming(pa, pb)) == int((a != b).sum())
    assert int(packing.packed_inner(pa, pb)) == int((a & b).sum())
    assert int(packing.popcount_rows(pa)) == int(a.sum())


def test_np_pack_matches_jnp():
    rng = np.random.default_rng(7)
    bits = rng.integers(0, 2, size=(5, 97)).astype(np.int32)
    np.testing.assert_array_equal(
        packing.np_pack_bits(bits), np.asarray(packing.pack_bits(jnp.asarray(bits)))
    )
