"""Front-door serving: admission, deadlines, coalescing, degradation.

The contract under test (DESIGN.md section 12): every ADMITTED request
is answered exactly once — even when faultinject kills a flush mid-
flight — every `partial=False` answer is bit-identical to the
synchronous `QueryEngine` result, rejected requests carry actionable
backpressure (retry-after), bulk is shed before interactive, and
deadline knife-edges (expired at admission, expiring mid-walk, zero
timeout) degrade to certified-partial answers instead of blocking or
lying.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.cabin import CabinParams
from repro.index import QueryEngine
from repro.runtime import faultinject
from repro.serve import (CLASS_BULK, CLASS_INTERACTIVE, AdmissionQueue,
                         Deadline, FrontDoor, FrontDoorClosed,
                         RejectedError, ServiceEstimator)

N_DIMS = 400
P = CabinParams.create(N_DIMS, 256, seed=11)


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, N_DIMS)) < 0.05).astype(np.int32)


@pytest.fixture(scope="module")
def engine():
    eng = QueryEngine(P, band_rows=64)
    eng.add_dense(_rows(2048, 1))
    eng.compact()
    return eng


class GatedEngine:
    """Engine proxy whose query path blocks on a gate — makes queue
    buildup deterministic for backpressure tests."""

    def __init__(self, eng, gate):
        self._eng = eng
        self.obs = eng.obs
        self.gate = gate

    def topk(self, queries, k):
        self.gate.wait()
        return self._eng.topk(queries, k)

    def topk_budgeted(self, queries, k, deadline=None):
        self.gate.wait()
        return self._eng.topk_budgeted(queries, k, deadline=deadline)

    def radius(self, queries, r):
        self.gate.wait()
        return self._eng.radius(queries, r)


class CountdownDeadline:
    """Scripted deadline: `expired` flips True after `checks` reads —
    lets a test place the expiry exactly between band-walk rounds
    without sleeping."""

    def __init__(self, checks, remaining_s=1e-4):
        self.checks = checks
        self._rem = remaining_s

    def remaining_s(self):
        return self._rem  # tiny: the front door routes us to the
        # budgeted sub-batch without treating us as already dead

    @property
    def expired(self):
        self.checks -= 1
        return self.checks < 0


# ---------------------------------------------------------------------------
# deadline / estimator units
# ---------------------------------------------------------------------------


def test_deadline_clock_injection():
    t = [100.0]
    d = Deadline(timeout_ms=50.0, clock=lambda: t[0])
    assert not d.expired
    assert d.remaining_ms() == pytest.approx(50.0)
    t[0] = 100.049
    assert not d.expired
    t[0] = 100.051
    assert d.expired
    assert d.remaining_ms() < 0
    with pytest.raises(ValueError):
        Deadline()
    with pytest.raises(ValueError):
        Deadline(timeout_ms=1.0, at=1.0)
    assert Deadline(at=99.0, clock=lambda: t[0]).expired


def test_service_estimator_ewma_and_prior():
    est = ServiceEstimator(default_ms=20.0, alpha=0.5)
    assert est.estimate_ms("topk") == 20.0  # prior before any observation
    est.observe("topk", 10.0)
    assert est.estimate_ms("topk") == 10.0  # first observation replaces
    est.observe("topk", 20.0)
    assert est.estimate_ms("topk") == pytest.approx(15.0)
    assert est.estimate_ms("radius") == 20.0  # per-op isolation
    est.observe("topk", -5.0)  # garbage observation is ignored
    assert est.estimate_ms("topk") == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# admission queue: bounds, shed ordering, retry-after
# ---------------------------------------------------------------------------


class _FakeReq:
    def __init__(self, cls, rows=1, key=("topk", 10, "dense")):
        self.cls = cls
        self.rows = rows
        self.key = key


def test_admission_sheds_bulk_before_interactive():
    q = AdmissionQueue(interactive_limit=4, bulk_limit=4, bulk_headroom=0.5)
    q.offer(_FakeReq(CLASS_BULK))  # admitted while interactive is empty
    q.offer(_FakeReq(CLASS_INTERACTIVE))
    q.offer(_FakeReq(CLASS_INTERACTIVE))  # interactive depth 2 == shed bar
    with pytest.raises(RejectedError) as ei:
        q.offer(_FakeReq(CLASS_BULK))
    assert ei.value.reason == "shed"
    assert ei.value.cls == CLASS_BULK
    # interactive still has room — it is NOT shed
    q.offer(_FakeReq(CLASS_INTERACTIVE))
    q.offer(_FakeReq(CLASS_INTERACTIVE))
    with pytest.raises(RejectedError) as ei:
        q.offer(_FakeReq(CLASS_INTERACTIVE))
    assert ei.value.reason == "full"
    assert q.depth(CLASS_INTERACTIVE) == 4
    assert q.depth(CLASS_BULK) == 1


def test_admission_bulk_full_and_retry_after_from_drain_rate():
    q = AdmissionQueue(interactive_limit=64, bulk_limit=2, bulk_headroom=1.0)
    q.offer(_FakeReq(CLASS_BULK))
    q.offer(_FakeReq(CLASS_BULK))
    with pytest.raises(RejectedError) as ei:
        q.offer(_FakeReq(CLASS_BULK))
    assert ei.value.reason == "full"
    assert ei.value.retry_after_s > 0  # default hint before any drain
    q.note_drained(10)  # 10 answered recently -> rate = 2/s over 5s window
    assert q.drain_rate() == pytest.approx(2.0)
    with pytest.raises(RejectedError) as ei:
        q.offer(_FakeReq(CLASS_BULK))
    # depth 2, rate 2/s -> (2+1)/2 = 1.5s
    assert ei.value.retry_after_s == pytest.approx(1.5)


def test_admission_take_group_prefers_interactive_and_coalesces():
    q = AdmissionQueue(interactive_limit=8, bulk_limit=8, bulk_headroom=1.0)
    other = ("topk", 5, "dense")
    q.offer(_FakeReq(CLASS_BULK, rows=2))
    q.offer(_FakeReq(CLASS_INTERACTIVE, rows=1))
    q.offer(_FakeReq(CLASS_INTERACTIVE, rows=1, key=other))
    q.offer(_FakeReq(CLASS_BULK, rows=3))
    group = q.take_group(max_rows=64)
    # leader is the first INTERACTIVE request even though bulk arrived
    # first; both same-key bulk requests coalesce behind it
    assert [g.cls for g in group] == [CLASS_INTERACTIVE, CLASS_BULK,
                                      CLASS_BULK]
    assert q.depth() == 1  # the other-key request stays queued
    group2 = q.take_group(max_rows=64)
    assert group2[0].key == other


# ---------------------------------------------------------------------------
# front door: exactness, concurrency, deadline knife-edges
# ---------------------------------------------------------------------------


def test_concurrent_no_deadline_answers_bit_identical(engine):
    batches = [_rows(3, 100 + i) for i in range(12)]
    want = [engine.topk(b, 10) for b in batches]
    results: list = [None] * len(batches)
    with FrontDoor(engine, max_wait_ms=1.0) as fd:
        def worker(i):
            results[i] = fd.topk(batches[i], 10)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(batches))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert fd.double_answers == 0
        assert fd.answered == len(batches)
    for res, (ids, dists) in zip(results, want):
        assert res.ok and not res.partial and res.cert_gap == 0.0
        np.testing.assert_array_equal(res.ids, ids)
        np.testing.assert_array_equal(res.dists, dists)


def test_assign_coalesces_with_top1(engine):
    q = _rows(4, 7)
    ids1, d1 = engine.topk(q, 1)
    with FrontDoor(engine) as fd:
        res = fd.assign(q)
    assert res.ids.shape == (4,)
    np.testing.assert_array_equal(res.ids, ids1[:, 0])
    np.testing.assert_array_equal(res.dists, d1[:, 0])


def test_radius_through_front_door(engine):
    q = _rows(3, 8)
    r = float(np.median(engine.topk(q, 5)[1])) + 0.5
    want = engine.radius(q, r)
    with FrontDoor(engine) as fd:
        res = fd.radius(q, r)
    assert res.ok and not res.partial
    assert len(res.hits) == 3
    for got, exp in zip(res.hits, want):
        np.testing.assert_array_equal(got, exp)


def test_zero_timeout_contract_never_enqueued(engine):
    with FrontDoor(engine) as fd:
        h = fd.submit("topk", _rows(2, 9), k=5, timeout_ms=0)
        res = h.result(timeout=5)
        assert res.partial and res.timed_out and res.ok
        assert res.ids.shape == (2, 0) and res.cert_gap == np.inf
        assert fd.queue.depth() == 0  # it never touched the queue
        # radius + assign honour the same contract with their own shapes
        ra = fd.submit("assign", _rows(2, 9), timeout_ms=0).result(timeout=5)
        assert ra.timed_out and (ra.ids == -1).all()
        rr = fd.submit("radius", _rows(2, 9), r=1.0,
                       timeout_ms=0).result(timeout=5)
        assert rr.timed_out and [len(h) for h in rr.hits] == [0, 0]


def test_deadline_expiring_mid_flush_returns_certified_partial(engine):
    q = _rows(2, 10)
    with FrontDoor(engine, max_wait_ms=0.0) as fd:
        # 1 pre-walk check (admission); expiry then lands between band
        # rounds inside topk_rows_banded — the mid-flush knife edge.
        # NOTE: the exact reference is computed AFTER this call — a
        # budgeted query that finds the exact answer already in the LRU
        # is upgraded to it (partial results never enter the cache)
        h = fd.submit("topk", q, k=10, deadline=CountdownDeadline(checks=1))
        res = h.result(timeout=30)
    ids_x, d_x = engine.topk(q, 10)
    assert res.ok
    assert res.partial
    assert res.cert_gap > 0
    # degraded, not wrong: every returned candidate is a true stored row
    # at its true distance, so distances can only be >= the exact answer
    assert res.ids.shape == (2, 10)
    filled = res.ids >= 0
    assert np.all(res.dists[filled] >= d_x[filled] - 1e-6)
    assert np.all(np.isinf(res.dists[~filled]))


def test_partial_false_property_under_mixed_deadlines(engine):
    """Property test: whatever the deadline mix and thread interleaving,
    partial=False answers are bit-identical to the synchronous engine."""
    pool = [_rows(2, 200 + i) for i in range(10)]
    want = [engine.topk(b, 8) for b in pool]
    rng = np.random.default_rng(0)
    jobs = [(int(rng.integers(len(pool))),
             [None, 0.0, 0.05, 50.0, None][int(rng.integers(5))])
            for _ in range(40)]
    out: list = [None] * len(jobs)
    with FrontDoor(engine, max_wait_ms=1.0,
                   interactive_limit=len(jobs)) as fd:
        def worker(j):
            qi, tmo = jobs[j]
            out[j] = fd.topk(pool[qi], 8, timeout_ms=tmo)

        threads = [threading.Thread(target=worker, args=(j,))
                   for j in range(len(jobs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert fd.double_answers == 0
        assert fd.answered == len(jobs)
    for j, res in enumerate(out):
        qi = jobs[j][0]
        assert res.ok
        if not res.partial:
            assert res.cert_gap == 0.0
            np.testing.assert_array_equal(res.ids, want[qi][0])
            np.testing.assert_array_equal(res.dists, want[qi][1])
        else:
            assert res.cert_gap > 0


# ---------------------------------------------------------------------------
# backpressure and shutdown through the full stack
# ---------------------------------------------------------------------------


def test_backpressure_sheds_bulk_first_through_front_door(engine):
    gate = threading.Event()
    fd = FrontDoor(GatedEngine(engine, gate), interactive_limit=4,
                   bulk_limit=4, bulk_headroom=0.5, max_wait_ms=0.0)
    try:
        handles = [fd.submit("topk", _rows(1, 20), k=5)]
        deadline = time.monotonic() + 5
        while fd.queue.depth() > 0:  # dispatcher holds it at the gate
            assert time.monotonic() < deadline, "dispatcher never picked up"
            time.sleep(0.001)
        handles += [fd.submit("topk", _rows(1, 21 + i), k=5)
                    for i in range(4)]  # exactly fills the bounded queue
        assert fd.queue.depth(CLASS_INTERACTIVE) == 4
        with pytest.raises(RejectedError) as ei:
            fd.submit("topk", _rows(1, 30), k=5, cls=CLASS_BULK)
        assert ei.value.reason == "shed"  # bulk dies before interactive
        with pytest.raises(RejectedError) as ei:
            fd.submit("topk", _rows(1, 31), k=5)
        assert ei.value.reason == "full"
        assert ei.value.retry_after_s > 0
        gate.set()
        for h in handles:
            assert h.result(timeout=30).ok
    finally:
        gate.set()
        fd.close()


def test_close_drains_admitted_requests(engine):
    gate = threading.Event()
    fd = FrontDoor(GatedEngine(engine, gate), max_wait_ms=0.0)
    handles = [fd.submit("topk", _rows(1, 40 + i), k=3) for i in range(6)]
    closer = threading.Thread(target=fd.close)
    closer.start()
    time.sleep(0.02)
    gate.set()  # release the engine AFTER close began: drain must finish
    closer.join(timeout=30)
    assert not closer.is_alive()
    for h in handles:
        assert h.result(timeout=5).ok  # drained, not dropped
    with pytest.raises((FrontDoorClosed, RejectedError)):
        fd.submit("topk", _rows(1, 50), k=3)


# ---------------------------------------------------------------------------
# chaos: crash points at enqueue / flush / publish
# ---------------------------------------------------------------------------


def test_crash_at_enqueue_is_not_an_ack(engine):
    with FrontDoor(engine) as fd:
        with faultinject.armed("frontdoor.enqueue"):
            with pytest.raises(faultinject.InjectedCrash):
                fd.submit("topk", _rows(1, 60), k=5)
        assert fd.queue.depth() == 0  # never admitted -> nothing owed
        res = fd.topk(_rows(1, 61), 5)  # the door still serves
        assert res.ok and not res.partial


@pytest.mark.parametrize("point", ["frontdoor.flush", "frontdoor.publish"])
def test_crash_mid_flush_retries_exactly_once_answered(engine, point):
    q = _rows(2, 70)
    want = engine.topk(q, 6)
    with FrontDoor(engine, max_wait_ms=0.0, backoff_ms=0.1) as fd:
        faultinject.record_hits()
        faultinject.clear_hits()
        with faultinject.armed(point):
            res = fd.topk(q, 6)
        faultinject.record_hits(False)
        assert point in faultinject.hits()  # the crash actually fired
        assert res.ok and not res.partial
        np.testing.assert_array_equal(res.ids, want[0])
        np.testing.assert_array_equal(res.dists, want[1])
        assert fd.double_answers == 0
        assert fd.answered == 1
    snap = engine.obs_snapshot()
    if snap:  # REPRO_OBS=1: the fault and retry were recorded
        assert snap["frontdoor_faults_total"] >= 1
        assert snap["frontdoor_retries_total"] >= 1


def test_retries_exhausted_surface_as_error_result(engine):
    class BrokenEngine:
        obs = engine.obs

        def topk(self, queries, k):
            raise RuntimeError("engine on fire")

    fd = FrontDoor(BrokenEngine(), max_retries=2, backoff_ms=0.1,
                   max_wait_ms=0.0)
    try:
        res = fd.topk(_rows(1, 80), 5)
        assert not res.ok
        assert isinstance(res.error, RuntimeError)
        assert fd.answered == 1  # an error result is still an answer
        assert fd.double_answers == 0
    finally:
        fd.close()
