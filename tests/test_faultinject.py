"""Fault injection: the crash matrix, checkpoint integrity, orphan sweep.

The recovery story is only as strong as the set of interruption points it
was tested at, so these tests enumerate `faultinject.registered_points()`
and kill the system at EVERY one — in-process (InjectedCrash caught at the
test's top level, then recovery FROM DISK ONLY, which is exactly the state
a dead process leaves) and once via a real subprocess os._exit, to prove
the in-process form isn't hiding behind interpreter teardown.  The
invariant asserted everywhere: restore finds an intact snapshot, resumes,
and the final state is bit-identical to the run that was never killed —
with every journaled (acked-durable) mutation present.
"""

import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointCorruptError,
                                           Checkpointer)
from repro.core.cabin import CabinParams
from repro.index import QueryEngine
from repro.runtime import faultinject

N_DIMS = 300
P_OLD = CabinParams(n_dims=N_DIMS, sketch_dim=64, psi_seed=21, pi_seed=22)
P_NEW = CabinParams(n_dims=N_DIMS, sketch_dim=128, psi_seed=21, pi_seed=22)

SAVE_POINTS = tuple(p for p in faultinject.registered_points()
                    if p.startswith("checkpointer.save."))
MIGRATE_POINTS = tuple(p for p in faultinject.registered_points()
                       if p.startswith("migrate."))


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for r in range(n):
        cols = rng.choice(N_DIMS, size=rng.integers(8, 25), replace=False)
        x[r, cols] = rng.integers(1, 6, size=len(cols))
    return x


# ---------------------------------------------------------------------------
# harness mechanics
# ---------------------------------------------------------------------------


def test_registry_covers_every_durability_path():
    pts = faultinject.registered_points()
    assert set(SAVE_POINTS) == {
        "checkpointer.save.tmp_written",
        "checkpointer.save.arrays_written",
        "checkpointer.save.meta_written",
        "checkpointer.save.published",
    }
    assert set(MIGRATE_POINTS) == {
        "migrate.start", "migrate.batch.resketched",
        "migrate.batch.committed", "migrate.fold", "migrate.published",
    }
    assert "store.compact" in pts
    assert "shard.rebalance" in pts
    assert "merge.combine" in pts


def test_arm_fires_once_then_disarms():
    with pytest.raises(ValueError):
        faultinject.arm("no.such.point")
    faultinject.arm("store.compact")
    with pytest.raises(faultinject.InjectedCrash) as ei:
        faultinject.crash_point("store.compact")
    assert ei.value.point == "store.compact"
    faultinject.crash_point("store.compact")  # disarmed: no second crash
    # armed() always disarms, even when the point is never reached
    with faultinject.armed("store.compact"):
        pass
    faultinject.crash_point("store.compact")


def test_hit_recording_is_opt_in():
    faultinject.clear_hits()
    faultinject.crash_point("store.compact")
    assert faultinject.hits() == ()
    faultinject.record_hits(True)
    try:
        faultinject.crash_point("store.compact")
        faultinject.crash_point("migrate.start")
    finally:
        faultinject.record_hits(False)
    assert faultinject.hits() == ("store.compact", "migrate.start")
    faultinject.clear_hits()


# ---------------------------------------------------------------------------
# checkpointer: crash matrix + integrity + sweep
# ---------------------------------------------------------------------------


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": rng.random((5, 7)).astype(np.float32),
            "ids": np.arange(seed, seed + 4, dtype=np.int64)}


@pytest.mark.parametrize("point", SAVE_POINTS)
def test_save_crash_matrix_recovers_newest_intact(tmp_path, point):
    """Kill the save at every stage: recovery must see either the previous
    step (crash before publish) or the new one (crash after), never a torn
    mix — and a later Checkpointer must sweep the staging corpse."""
    d = str(tmp_path)
    ck = Checkpointer(d, async_save=False)
    ck.save(0, _tree(0), block=True)
    with faultinject.armed(point):
        try:
            ck.save(1, _tree(1), block=True)
            crashed = False
        except faultinject.InjectedCrash:
            crashed = True
    assert crashed
    # recover from disk only, as a fresh process would
    ck2 = Checkpointer(d, async_save=False)
    assert not any(n.startswith(".tmp_step_") for n in os.listdir(d))
    flat, step = ck2.restore()
    expect = 1 if point == "checkpointer.save.published" else 0
    assert step == expect
    ref = {k: np.asarray(v) for k, v in _tree(expect).items()}
    for k, v in ref.items():
        assert np.array_equal(flat[k], v)


def test_orphan_tmp_dirs_swept_on_init(tmp_path):
    d = str(tmp_path)
    ck = Checkpointer(d, async_save=False)
    ck.save(0, _tree(0), block=True)
    orphan = os.path.join(d, ".tmp_step_7")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "arrays.npz"), "w") as f:
        f.write("torn")
    Checkpointer(d, async_save=False)
    assert not os.path.exists(orphan)
    # published steps untouched
    _, step = Checkpointer(d, async_save=False).restore()
    assert step == 0


def _corrupt_array(directory, step, key, mutate):
    path = os.path.join(directory, f"step_{step}", "arrays.npz")
    with np.load(path) as data:
        flat = {k: data[k].copy() for k in data.files}
    flat[key] = mutate(flat[key])
    np.savez(path, **flat)


def test_corruption_detected_named_and_skipped(tmp_path):
    d = str(tmp_path)
    ck = Checkpointer(d, keep=10, async_save=False)
    ck.save(0, _tree(0), block=True)
    ck.save(1, _tree(1), block=True)

    # bit-flip: CRC mismatch, naming step and key
    _corrupt_array(d, 1, "w", lambda a: a + 1)
    with pytest.raises(CheckpointCorruptError) as ei:
        ck.verify(1)
    assert ei.value.step == 1 and ei.value.key == "w"
    assert "CRC32" in str(ei.value)
    # an explicit step that fails verification raises...
    with pytest.raises(CheckpointCorruptError):
        ck.restore(step=1)
    # ...but step=None falls back to the newest INTACT step
    flat, step = ck.restore()
    assert step == 0
    assert np.array_equal(flat["ids"], _tree(0)["ids"])
    assert ck.latest_intact_step() == 0

    # shape mismatch is its own named failure
    ck.save(2, _tree(2), block=True)
    _corrupt_array(d, 2, "ids", lambda a: a[:2])
    with pytest.raises(CheckpointCorruptError) as ei:
        ck.verify(2)
    assert ei.value.key == "ids" and "shape" in str(ei.value)

    # file-level truncation: key is None
    ck.save(3, _tree(3), block=True)
    npz = os.path.join(d, "step_3", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(CheckpointCorruptError) as ei:
        ck.verify(3)
    assert ei.value.step == 3 and ei.value.key is None

    # every step corrupt -> restore(step=None) raises, not loops
    _corrupt_array(d, 0, "w", lambda a: a * 2)
    with pytest.raises(CheckpointCorruptError, match="no intact step"):
        ck.restore()


def test_subprocess_kill_is_equivalent_to_injected_raise(tmp_path):
    """The honest crash: a child process dies at an armed point via
    os._exit (no atexit, no finally) mid-save; the parent recovers exactly
    as the in-process matrix predicts."""
    d = str(tmp_path)
    Checkpointer(d, async_save=False).save(0, _tree(0), block=True)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    child = (
        "import numpy as np\n"
        "from repro.checkpoint.checkpointer import Checkpointer\n"
        f"ck = Checkpointer({d!r}, async_save=False)\n"
        "ck.save(1, {'w': np.ones((5, 7), np.float32),\n"
        "            'ids': np.arange(1, 5)}, block=True)\n"
    )
    env = dict(os.environ, PYTHONPATH=src,
               REPRO_CRASH_POINT="checkpointer.save.arrays_written",
               REPRO_CRASH_MODE="exit")
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == faultinject.EXIT_CODE, proc.stderr
    ck = Checkpointer(d, async_save=False)   # sweeps the orphan
    assert not any(n.startswith(".tmp_step_") for n in os.listdir(d))
    flat, step = ck.restore()
    assert step == 0
    assert np.array_equal(flat["ids"], _tree(0)["ids"])


# ---------------------------------------------------------------------------
# index engine: crash matrix over compact + every migration phase
# ---------------------------------------------------------------------------


def _build_engine(metric, journal, x):
    eng = QueryEngine(P_OLD, metric=metric, cache_entries=0)
    ids = eng.add_dense(x)
    eng.remove(ids[1:3])
    eng.save(journal, step=0, keep=20)       # durability baseline
    return eng


def _reference_final(metric, x):
    """The never-crashed outcome: the same membership fresh-built at the
    new spec (the migration bit-identity contract)."""
    ref = QueryEngine(P_NEW, metric=metric, cache_entries=0)
    ids = ref.add_dense(x)
    ref.remove(ids[1:3])
    return ref


@pytest.mark.parametrize("metric", ["cham", "hamming"])
@pytest.mark.parametrize("point", MIGRATE_POINTS + ("store.compact",))
def test_engine_crash_matrix_no_acked_row_lost(tmp_path, point, metric):
    """Kill the engine at every migration/compaction crash point, recover
    from the journal directory only, finish the migration, and require the
    final answers bit-identical to the never-crashed run — for both
    metrics.  Every row acked before the baseline snapshot must survive
    every crash."""
    x = _rows(26, seed=hash(point) % 1000)
    journal = str(tmp_path / "journal")
    eng = _build_engine(metric, journal, x)
    expected_ids = eng.ids().copy()

    faultinject.record_hits(True)
    faultinject.clear_hits()
    try:
        with faultinject.armed(point):
            try:
                if point == "store.compact":
                    eng.compact()
                else:
                    eng.migrate(new_params=P_NEW, batch_rows=7,
                                drive="manual", journal_dir=journal,
                                journal_every=1, journal_keep=20)
                    eng.migrate_all()
                crashed = False
            except faultinject.InjectedCrash as e:
                assert e.point == point
                crashed = True
    finally:
        hits = faultinject.hits()
        faultinject.record_hits(False)
        faultinject.clear_hits()
    assert crashed, f"scenario never reached {point} (hits: {hits})"

    # recover FROM DISK ONLY — the in-memory engine is the dead process
    res = QueryEngine.restore(journal)
    assert np.array_equal(np.sort(res.ids()), np.sort(expected_ids)), \
        "acked rows lost across the crash"
    if res.migrating:
        res.migrate_all()
    elif res.spec.version == 0:
        res.migrate(new_params=P_NEW, drive="eager")
    assert res.spec.version == 1 and res.d == P_NEW.sketch_dim

    ref = _reference_final(metric, x)
    q = _rows(4, seed=77)
    a_ids, a_d = res.topk(q, 5)
    b_ids, b_d = ref.topk(q, 5)
    assert np.array_equal(a_ids, b_ids)
    assert np.array_equal(a_d, b_d)
    r = 30.0 if metric == "hamming" else 60.0
    for a, b in zip(res.radius(q, r), ref.radius(q, r)):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_crash_matrix_shard_rebalance(tmp_path, metric):
    """The sharded-layout row of the crash matrix: kill the engine inside
    its partition rebuild (shard.rebalance fires before any group is
    swapped), recover from the journal directory only, and require answers
    bit-identical to the never-crashed unsharded reference — the layout is
    derived state, so a rebalance crash can never lose an acked row."""
    x = _rows(26, seed=4)
    journal = str(tmp_path / "journal")
    eng = _build_engine(metric, journal, x)
    q = _rows(4, seed=77)
    want_ids, want_d = eng.topk(q, 5)

    eng.shard(n_shards=4)
    faultinject.record_hits(True)
    faultinject.clear_hits()
    try:
        with faultinject.armed("shard.rebalance"):
            with pytest.raises(faultinject.InjectedCrash) as ei:
                eng.topk(q, 5)  # first sharded query triggers the rebuild
        assert ei.value.point == "shard.rebalance"
    finally:
        faultinject.record_hits(False)
        faultinject.clear_hits()

    # recover FROM DISK ONLY — the in-memory engine is the dead process
    res = QueryEngine.restore(journal)
    a_ids, a_d = res.topk(q, 5)
    assert np.array_equal(a_ids, want_ids) and np.array_equal(a_d, want_d)
    # the crashed process itself can also just retry: nothing was mutated
    b_ids, b_d = eng.topk(q, 5)
    assert np.array_equal(b_ids, want_ids) and np.array_equal(b_d, want_d)
    r = 30.0 if metric == "hamming" else 60.0
    for a, b in zip(eng.radius(q, r), res.radius(q, r)):
        assert np.array_equal(a, b)


def test_mid_migration_acked_mutations_survive_crash(tmp_path):
    """Rows acked AND journaled mid-migration (they landed in the new-spec
    fresh tier, then a batch boundary journaled the whole engine) must
    survive a crash at the next batch — the lazy tier routing exists
    precisely so acked work never needs re-migration."""
    x = _rows(30, seed=5)
    late = _rows(4, seed=6)
    journal = str(tmp_path / "journal")
    eng = _build_engine("cham", journal, x)
    eng.migrate(new_params=P_NEW, batch_rows=6, drive="manual",
                journal_dir=journal, journal_every=1, journal_keep=20)
    eng.migration_step()
    late_ids = eng.add_dense(late)           # acked into the fresh tier
    eng.migration_step()                     # batch boundary -> journaled
    with faultinject.armed("migrate.batch.resketched"):
        with pytest.raises(faultinject.InjectedCrash):
            eng.migration_step()

    res = QueryEngine.restore(journal)
    assert set(late_ids.tolist()) <= set(res.ids().tolist()), \
        "journaled acked mutation lost"
    res.migrate_all()
    # and they are served under the new spec, identically to a fresh build
    ref = QueryEngine(P_NEW, metric="cham", cache_entries=0)
    ids = ref.add_dense(np.concatenate([x, late]))
    ref.remove(ids[1:3])
    a_ids, a_d = res.topk(late[:2], 3)
    b_ids, b_d = ref.topk(late[:2], 3)
    assert np.array_equal(a_ids, b_ids) and np.array_equal(a_d, b_d)


def test_compact_crash_leaves_serving_state_intact(tmp_path):
    """The in-process view after a compaction crash still serves correctly
    (the crash fires before any buffer is touched), and the on-disk
    snapshot is unaffected."""
    x = _rows(12, seed=8)
    journal = str(tmp_path / "journal")
    eng = _build_engine("cham", journal, x)
    before = eng.topk(x[:2], 3)
    with faultinject.armed("store.compact"):
        with pytest.raises(faultinject.InjectedCrash):
            eng.compact()
    after = eng.topk(x[:2], 3)
    assert np.array_equal(before[0], after[0])
    assert np.array_equal(before[1], after[1])
    res = QueryEngine.restore(journal)
    r_ids, r_d = res.topk(x[:2], 3)
    assert np.array_equal(before[0], r_ids)
    assert np.array_equal(before[1], r_d)


# ---------------------------------------------------------------------------
# heartbeat durability (the writer's own .tmp staging file)
# ---------------------------------------------------------------------------


def test_heartbeat_crash_orphans_tmp_and_init_sweeps_it(tmp_path):
    """A crash between writing the .tmp beat and os.replace leaves an
    orphan .tmp; the published beat stays intact (the detector keeps
    reading the LAST good beat), and the next incarnation's init sweeps
    its own orphan — but never a peer's in-flight staging file."""
    from repro.runtime.fault_tolerance import FailureDetector, HeartbeatWriter

    d = str(tmp_path)
    hb = HeartbeatWriter(d, host_id=0)
    hb.beat(1)
    with faultinject.armed("heartbeat.tmp_written"):
        with pytest.raises(faultinject.InjectedCrash):
            hb.beat(2)
    tmp = os.path.join(d, "heartbeat_0.json.tmp")
    assert os.path.exists(tmp), "crash should strand the staging file"
    det = FailureDetector(d)
    assert det.read_all()[0]["step"] == 1  # last PUBLISHED beat survives

    peer_tmp = os.path.join(d, "heartbeat_1.json.tmp")
    with open(peer_tmp, "w") as f:
        f.write("{")  # peer mid-beat on the shared directory
    hb2 = HeartbeatWriter(d, host_id=0)  # restart: sweeps only its own
    assert not os.path.exists(tmp)
    assert os.path.exists(peer_tmp)
    hb2.beat(3)
    assert det.read_all()[0]["step"] == 3


def test_heartbeat_point_registered():
    import repro.runtime.fault_tolerance  # noqa: F401 - declares on import

    assert "heartbeat.tmp_written" in faultinject.registered_points()


# ---------------------------------------------------------------------------
# front-door points + armed-point atomicity under real threads
# ---------------------------------------------------------------------------


def test_frontdoor_points_registered():
    import repro.serve.frontdoor  # noqa: F401 - declares on import

    pts = faultinject.registered_points()
    assert {"frontdoor.enqueue", "frontdoor.flush",
            "frontdoor.publish"} <= set(pts)


def test_one_arm_one_crash_is_atomic_across_threads():
    """With the front door's real threads, several callers can cross an
    armed point concurrently; exactly ONE must die."""
    import threading

    n = 16
    crashes = []
    barrier = threading.Barrier(n)

    def worker():
        barrier.wait()
        for _ in range(50):
            try:
                faultinject.crash_point("store.compact")
            except faultinject.InjectedCrash:
                crashes.append(1)

    for _ in range(20):  # repeat: the race needs opportunities
        faultinject.arm("store.compact")
        threads = [threading.Thread(target=worker) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(crashes) == 1, "one arm must mean exactly one crash"
        del crashes[:]
    faultinject.disarm()
