"""The Mergeable contract: merge == sequential build, bit for bit.

Everything in repro.index that holds derived state is Mergeable
(repro.index.mergeable, DESIGN.md section 14): an associative,
id-disjoint, spec-checked `merge(other)`.  These tests pin the property
that makes the merge-tree bulk loader exact — however the rows were
partitioned into shards and however the shard engines were folded
together, the merged engine is bit-identical to one sequential build of
the same rows: same store bits, same ids, same topk/radius answers, both
metrics, and the identity survives post-merge adds / removes / compacts.
The refusal paths (spec mismatch, id overlap, mid-migration) and the
merge.combine crash row (kill mid-merge leaves BOTH inputs intact and
re-runnable) are pinned here too.
"""

import itertools

import numpy as np
import pytest

from tests._hyp import given, st

from repro.core.cabin import CabinParams
from repro.data.pipeline import synthetic_documents
from repro.index import (MergeIncompatible, QueryEngine, SketchStore,
                         bulk_ingest, ingest_documents)
from repro.runtime import faultinject

N_DIMS = 500
D = 256
P = CabinParams.create(N_DIMS, D, seed=3)
P_OTHER = CabinParams.create(N_DIMS, D, seed=11)


def _rows(n, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for i in range(n):
        density = int(rng.integers(10, 80))
        idx = rng.choice(N_DIMS, size=density, replace=False)
        x[i, idx] = rng.integers(1, 8, size=density)
    return x


def _sequential(x, metric="cham", **kw):
    eng = QueryEngine(P, metric=metric, band_rows=16, **kw)
    eng.add_dense(x)
    return eng

def _shard_engines(x, cuts, metric="cham", **kw):
    """Split rows at `cuts` into per-shard engines whose id counters are
    pre-offset the way merge_tree._worker_engine offsets them, so the
    shard id ranges are disjoint and sequential-identical."""
    parts = np.split(x, cuts)
    engines = []
    base = 0
    for part in parts:
        e = QueryEngine(P, metric=metric, band_rows=16, **kw)
        e.spec = engines[0].spec if engines else e.spec
        e.store.spec = e.spec
        e.store._next_id = base
        if len(part):
            e.add_dense(part)
        base += len(part)
        engines.append(e)
    return engines


def _assert_same_answers(got, ref, queries):
    dg, ig = got.topk(queries, k=5)
    dr, ir = ref.topk(queries, k=5)
    np.testing.assert_array_equal(ig, ir)
    np.testing.assert_array_equal(dg, dr)
    r = 0.25 if got.metric == "cham" else 60.0
    for a, b in zip(got.radius(queries, r), ref.radius(queries, r)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def _assert_same_store(got, ref):
    np.testing.assert_array_equal(got.ids(), ref.ids())
    n_g, n_r = got.store.size, ref.store.size
    np.testing.assert_array_equal(np.asarray(got.store.sk_buf[:n_g]),
                                  np.asarray(ref.store.sk_buf[:n_r]))


# ---------------------------------------------------------------------------
# the property: merge == sequential, any shard split, any merge order
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**16))
def test_merge_equals_sequential_any_partition(seed):
    """Random k-way split, random fold order: the merged engine's store
    and answers are bit-identical to one sequential build."""
    rng = np.random.default_rng(seed)
    metric = ("cham", "hamming")[seed % 2]
    n = int(rng.integers(12, 48))
    x = _rows(n, seed)
    k = int(rng.integers(2, 6))
    cuts = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False))
    engines = _shard_engines(x, cuts, metric=metric)
    # fold in a random order — merges are associative and id-disjointness
    # is order-independent, so ANY order must land on the same bits (out-
    # of-order folds just take the interleave path instead of the append
    # fast path)
    order = rng.permutation(len(engines))
    acc = engines[order[0]]
    for j in order[1:]:
        acc = acc.merge(engines[j])
    ref = _sequential(x, metric=metric)
    _assert_same_store(acc, ref)
    _assert_same_answers(acc, ref, x[:4])


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_merge_survives_post_merge_mutations(metric):
    """add / remove / compact AFTER a merge behave exactly as on a
    sequentially built engine — the merged store is a first-class store,
    not a frozen union."""
    x = _rows(40, seed=5)
    a, b = _shard_engines(x, [23], metric=metric)
    a.merge(b)
    ref = _sequential(x, metric=metric)
    for eng in (a, ref):
        eng.remove(np.array([3, 17, 29]))
        eng.add_dense(_rows(6, seed=9))
        eng.compact()
        eng.add_dense(_rows(3, seed=12))
    _assert_same_store(a, ref)
    _assert_same_answers(a, ref, x[:4])


def test_interleaved_merge_takes_gather_path_exactly():
    """Folding out of id order (0+2 then +1) hits the interleave path
    (epoch bump) yet still lands bit-identical; in-order folding rides
    the append fast path with NO epoch bump."""
    x = _rows(30, seed=7)
    e0, e1, e2 = _shard_engines(x, [10, 20])
    epoch0 = e0.store.epoch
    e0.merge(e2)                      # gap: ids 20..29 after 0..9
    assert e0.store.epoch == epoch0   # still append fast path (ascending)
    e0.merge(e1)                      # 10..19 interleave into the middle
    assert e0.store.epoch == epoch0 + 1
    ref = _sequential(x)
    _assert_same_store(e0, ref)
    _assert_same_answers(e0, ref, x[:4])

    f0, f1, f2 = _shard_engines(x, [10, 20])
    f0.merge(f1).merge(f2)            # in order: fast path throughout
    assert f0.store.epoch == epoch0
    _assert_same_store(f0, ref)


def test_merge_empty_other_is_validated_noop():
    x = _rows(8, seed=1)
    a, b = _shard_engines(x, [8])     # b holds zero rows
    v = a.store.version
    a.merge(b)
    assert a.store.version == v       # nothing observable changed
    assert len(a) == 8
    assert a.store._next_id == 8      # but the watermark propagated


def test_sharded_engine_merge_parity():
    """A 3-shard engine absorbing a merge answers bit-identically to the
    unsharded sequential build — merged rows route by id % n_shards like
    any other add."""
    x = _rows(36, seed=21)
    a, b = _shard_engines(x, [20])
    a.shard(n_shards=3)
    a.topk(x[:2], k=3)                # force a sharded layout build
    a.merge(b)
    ref = _sequential(x)
    _assert_same_answers(a, ref, x[:4])


def test_partitionset_absorbs_append_merge_as_delta():
    """An in-id-order merge is an append (no epoch bump), so the serving
    layout absorbs it as a shard-routed DELTA: the base partition object
    survives, no rebuild."""
    x = _rows(32, seed=13)
    a, b = _shard_engines(x, [24], merge_ratio=None)
    a.topk(x[:2], k=3)                # build the layout
    base_before = a._tiered._groups[0].base
    a.merge(b)
    a.topk(x[:2], k=3)                # sync absorbs the tail
    assert a._tiered._groups[0].base is base_before
    assert a._tiered._groups[0].delta.n_rows == 8
    _assert_same_answers(a, _sequential(x, merge_ratio=None), x[:4])


# ---------------------------------------------------------------------------
# bulk_ingest: the merge tree vs one sequential ingest
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_bulk_ingest_bit_identical_to_sequential(metric):
    docs = list(itertools.islice(
        synthetic_documents(N_DIMS, seed=17, mean_len=64), 40))
    seq = QueryEngine(P, metric=metric, band_rows=16)
    ids_seq = ingest_documents(seq, docs, window=16)
    par = QueryEngine(P, metric=metric, band_rows=16)
    shards = [docs[:7], docs[7:19], docs[19:26], docs[26:]]
    ids_par = bulk_ingest(par, shards, workers=4, window=16)
    np.testing.assert_array_equal(ids_par, ids_seq)
    _assert_same_store(par, seq)
    _assert_same_answers(par, seq, _rows(4, seed=2))
    # the watermark is correct: post-bulk trickle ingest keeps assigning
    # the exact ids the sequential engine would
    more = list(itertools.islice(
        synthetic_documents(N_DIMS, seed=23, mean_len=64), 6))
    np.testing.assert_array_equal(ingest_documents(par, more, window=16),
                                  ingest_documents(seq, more, window=16))
    _assert_same_store(par, seq)


def test_bulk_ingest_empty_shards_typed_fast_path():
    eng = QueryEngine(P)
    out = bulk_ingest(eng, [[], []], workers=2)
    assert out.dtype == np.int64 and out.shape == (0,)
    assert len(eng) == 0


def test_ingest_empty_stream_no_device_work(monkeypatch):
    """An empty document stream returns a well-typed empty id array
    without touching the device: sketching is monkeypatched to explode,
    and the fast path must never reach it."""
    eng = QueryEngine(P)
    def boom(*a, **k):
        raise AssertionError("empty ingest must not sketch")
    monkeypatch.setattr(eng, "_sketch", boom)
    monkeypatch.setattr(eng, "add_sparse", boom)
    out = ingest_documents(eng, [], window=16)
    assert out.dtype == np.int64 and out.shape == (0,)
    out = ingest_documents(eng, iter([]), dedup_threshold=0.5)
    assert out.dtype == np.int64 and out.shape == (0,)


# ---------------------------------------------------------------------------
# refusal paths: wrong spec, overlapping ids, migration in flight
# ---------------------------------------------------------------------------


def test_add_packed_rejects_spec_mismatch():
    """A packed batch sketched under the wrong spec (different hash
    seeds) is refused with BOTH specs named — the silent-garbage path
    (same width, different hashes) is exactly the one that must fail
    loudly."""
    a = QueryEngine(P)
    b = QueryEngine(P_OTHER)
    a.add_dense(_rows(4, seed=1))
    b.add_dense(_rows(2, seed=2))
    sk = np.asarray(b.store.sk_buf[:2])
    with pytest.raises(MergeIncompatible) as ei:
        a.store.add_packed(sk, b.spec)
    msg = str(ei.value)
    assert f"psi_seed={P.psi_seed}" in msg
    assert f"psi_seed={P_OTHER.psi_seed}" in msg
    # the legacy spec-less call still works (caller vouches for the bits)
    n = len(a)
    a.store.add_packed(sk, None)
    assert len(a.store) == n + 2


def test_store_add_rejects_wrong_width_naming_spec():
    store = SketchStore(d=D)
    store.spec = QueryEngine(P).spec
    with pytest.raises(ValueError, match=r"d=256"):
        store.add(np.zeros((2, (D // 2) // 32), np.uint32))


def test_merge_rejects_spec_mismatch_naming_both():
    a = QueryEngine(P)
    b = QueryEngine(P_OTHER)
    a.add_dense(_rows(3, seed=1))
    b.store._next_id = 100
    b.add_dense(_rows(3, seed=2))
    with pytest.raises(MergeIncompatible) as ei:
        a.merge(b)
    msg = str(ei.value)
    assert f"psi_seed={P.psi_seed}" in msg
    assert f"psi_seed={P_OTHER.psi_seed}" in msg
    assert "migrate" in msg          # the fix is named, not just the fault
    assert len(a) == 3 and len(b) == 3


def test_merge_rejects_overlapping_ids():
    x = _rows(10, seed=3)
    a = _sequential(x[:6])
    b = _sequential(x[4:])           # ids 0..5 both sides: overlap {0..5}
    with pytest.raises(MergeIncompatible, match="id-disjoint"):
        a.store.merge(b.store)
    assert len(a) == 6 and len(b) == 6


def test_merge_refuses_mid_migration():
    x = _rows(12, seed=4)
    a, b = _shard_engines(x, [8])
    a.migrate(d=2 * D, drive="manual")
    with pytest.raises(RuntimeError, match="migration"):
        a.merge(b)
    with pytest.raises(RuntimeError, match="migration"):
        bulk_ingest(a, [[np.arange(5)]])
    a.migrate_all()
    # drained — but `a` now lives under the NEW spec, so the cross-spec
    # merge fails loudly through the same compatibility rail, naming the
    # migrate fix
    with pytest.raises(MergeIncompatible, match="migrate"):
        a.merge(b)


def test_merge_self_refuses():
    a = _sequential(_rows(3, seed=1))
    with pytest.raises(MergeIncompatible, match="itself"):
        a.merge(a)


# ---------------------------------------------------------------------------
# ClusterIndex: merged membership refits to the sequential clustering
# ---------------------------------------------------------------------------


def test_cluster_merge_equals_sequential_refit():
    x = _rows(48, seed=31)
    a, b = _shard_engines(x, [30])
    ca = a.cluster(4, seed=2)
    cb = b.cluster(4, seed=2)
    ca.merge(cb)
    ref = _sequential(x).cluster(4, seed=2)
    np.testing.assert_array_equal(ca.counts, ref.counts)
    np.testing.assert_array_equal(ca.centers, ref.centers)
    ids_a, lab_a = ca.labels()
    ids_r, lab_r = ref.labels()
    np.testing.assert_array_equal(ids_a, ids_r)
    np.testing.assert_array_equal(lab_a, lab_r)
    # weights fold as sums through the merge event
    np.testing.assert_array_equal(ca.weights, ref.weights)


def test_cluster_merge_rejects_config_mismatch():
    x = _rows(20, seed=31)
    a, b = _shard_engines(x, [12])
    with pytest.raises(MergeIncompatible, match="k/seed/n_iter"):
        a.cluster(4, seed=2).merge(b.cluster(5, seed=2))


# ---------------------------------------------------------------------------
# crash row: kill mid-merge, both inputs intact and re-runnable
# ---------------------------------------------------------------------------


def test_merge_crash_leaves_both_inputs_intact():
    """The merge.combine crash point fires after validation, before ANY
    mutation: a kill there leaves both stores exactly as they were, and
    simply re-running the merge lands on the never-killed bits."""
    assert "merge.combine" in faultinject.registered_points()
    x = _rows(24, seed=41)
    a, b = _shard_engines(x, [15])
    va, vb = a.store.version, b.store.version
    ids_a, ids_b = a.ids().copy(), b.ids().copy()
    with faultinject.armed("merge.combine"):
        with pytest.raises(faultinject.InjectedCrash):
            a.merge(b)
    assert a.store.version == va and b.store.version == vb
    np.testing.assert_array_equal(a.ids(), ids_a)
    np.testing.assert_array_equal(b.ids(), ids_b)
    a.merge(b)                       # re-run: nothing was half-applied
    ref = _sequential(x)
    _assert_same_store(a, ref)
    _assert_same_answers(a, ref, x[:4])
