"""Drift-tolerant serving: versioned specs, lazy re-sketch migration.

The load-bearing property (DESIGN.md section 10): a sketch is a PURE
function of (raw row, spec), so a COMPLETED migration must be bit-identical
to an engine freshly built at the new spec over the same membership — same
store buffers, same ids, same query answers, under both metrics, over any
add/remove/compact history, with mutations landing mid-flight.  While the
migration is in flight, serving answers must equal the (value, id)-lex
merge of per-store reference answers, each computed in its own sketch
space by the batch primitives.
"""

import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core import theory, topk_rows, threshold_pairs
from repro.core.cabin import CabinParams
from repro.core.cham import binhamming_from_stats
from repro.core.packing import np_popcount_rows, pad_rows_pow2
from repro.index import (Migration, QueryEngine, RawArchive, SketchSpec,
                         merge_topk_parts)

N_DIMS = 300
D_OLD = 64
D_NEW = 128
P_OLD = CabinParams(n_dims=N_DIMS, sketch_dim=D_OLD, psi_seed=11, pi_seed=12)
P_NEW = CabinParams(n_dims=N_DIMS, sketch_dim=D_NEW, psi_seed=11, pi_seed=12)


def _rows(n, seed, lo=8, hi=30):
    rng = np.random.default_rng(seed)
    x = np.zeros((n, N_DIMS), np.int32)
    for r in range(n):
        nnz = rng.integers(lo, hi + 1)
        cols = rng.choice(N_DIMS, size=nnz, replace=False)
        x[r, cols] = rng.integers(1, 6, size=nnz)
    return x


def _fresh_at_new_spec(x_by_id, metric):
    """Reference: batch-build an engine at the new spec holding exactly the
    rows in `x_by_id` (an id -> dense row dict), preserving ids via the
    add-then-remove trick (ids are assignment order)."""
    eng = QueryEngine(P_NEW, metric=metric, cache_entries=0)
    hi = max(x_by_id) + 1
    full = np.zeros((hi, N_DIMS), np.int32)
    for i, row in x_by_id.items():
        full[i] = row
    eng.add_dense(full)
    gone = sorted(set(range(hi)) - set(x_by_id))
    if gone:
        eng.remove(np.asarray(gone, np.int64))
    return eng


# ---------------------------------------------------------------------------
# SketchSpec / RawArchive units
# ---------------------------------------------------------------------------


def test_spec_successor_and_meta_roundtrip():
    spec = SketchSpec(0, P_OLD)
    nxt = spec.successor(P_NEW)
    assert nxt.version == 1 and nxt.d == D_NEW
    assert SketchSpec.from_meta(nxt.meta()) == nxt
    bad = CabinParams(n_dims=N_DIMS + 1, sketch_dim=D_NEW,
                      psi_seed=11, pi_seed=12)
    with pytest.raises(ValueError):
        spec.successor(bad)


def test_raw_archive_roundtrip_and_dense_coo_equivalence():
    x = _rows(9, seed=0)
    arc = RawArchive()
    arc.put_dense(np.arange(9, dtype=np.int64), x)
    # batch() returns trimmed padded-COO that sketches like the dense rows
    idx, val = arc.batch([3, 5])
    dense_back = np.zeros((2, N_DIMS), np.int32)
    np.put_along_axis(dense_back, idx, val, axis=1)
    assert np.array_equal(dense_back, x[[3, 5]])
    arc.drop([4])
    assert 4 not in arc and len(arc) == 8
    assert arc.missing([2, 4, 99]).tolist() == [4, 99]
    with pytest.raises(KeyError):
        arc.batch([4])
    # snapshot roundtrip preserves exactly the live rows
    arc2 = RawArchive.from_state(arc.state_tree())
    assert len(arc2) == 8 and 4 not in arc2
    i1, v1 = arc.batch([0, 8])
    i2, v2 = arc2.batch([0, 8])
    assert np.array_equal(i1, i2) and np.array_equal(v1, v2)


def test_merge_topk_parts_equals_single_partition():
    """Merging a split partition reproduces the unsplit answer — the rule
    that makes cross-store serving exact."""
    rng = np.random.default_rng(3)
    q, k = 4, 5
    vals = rng.random((q, 12)).astype(np.float32)
    ids = np.tile(np.arange(12, dtype=np.int64), (q, 1))
    order = np.argsort(vals, axis=1, kind="stable")
    ref_ids = np.take_along_axis(ids, order, axis=1)[:, :k]
    ref_vals = np.take_along_axis(vals, order, axis=1)[:, :k]
    parts = []
    for sl in (slice(0, 7), slice(7, 12)):  # per-partition exact k'-best
        o = np.argsort(vals[:, sl], axis=1, kind="stable")[:, :k]
        parts.append((np.take_along_axis(ids[:, sl], o, axis=1),
                      np.take_along_axis(vals[:, sl], o, axis=1)))
    got_ids, got_vals = merge_topk_parts(k, parts)
    assert np.array_equal(got_ids, ref_ids)
    assert np.array_equal(got_vals, ref_vals)


# ---------------------------------------------------------------------------
# Completed migration == fresh build (the tentpole bit-identity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_completed_migration_bit_identical_to_fresh_build(metric):
    x = _rows(40, seed=1)
    eng = QueryEngine(P_OLD, metric=metric, cache_entries=0)
    ids = eng.add_dense(x[:32])
    eng.remove(ids[5:9])
    eng.compact()
    eng.migrate(new_params=P_NEW, batch_rows=7, drive="manual")
    mid_adds = eng.add_dense(x[32:])          # land in the new-spec tier
    eng.remove([int(mid_adds[0])])
    eng.migrate_all()
    assert not eng.migrating and eng.d == D_NEW and eng.spec.version == 1

    alive = {int(i): x[i] for i in eng.ids()}
    ref = _fresh_at_new_spec(alive, metric)
    # store-level identity: same packed bits in the same slots
    m1, n1, i1 = eng.store.gather_alive()
    m2, n2, i2 = ref.store.gather_alive()
    assert n1 == n2 and np.array_equal(i1, i2)
    assert np.array_equal(np.asarray(m1[:n1]), np.asarray(m2[:n2]))
    # query-level identity
    q = _rows(5, seed=2)
    for k in (1, 4, 50):
        a_ids, a_d = eng.topk(q, k)
        b_ids, b_d = ref.topk(q, k)
        assert np.array_equal(a_ids, b_ids)
        assert np.array_equal(a_d, b_d)
    r = 30.0 if metric == "hamming" else 60.0
    for a, b in zip(eng.radius(q, r), ref.radius(q, r)):
        assert np.array_equal(a, b)


@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=3, max_size=10),
       st.integers(0, 1))
def test_migration_identity_under_arbitrary_history(ops, metric_pick):
    """Any interleaving of add / remove / compact / migration batches still
    lands bit-identical to the fresh build — including histories where
    mutations race the migration itself."""
    metric = ("cham", "hamming")[metric_pick]
    rng = np.random.default_rng(sum(ops) + metric_pick)
    eng = QueryEngine(P_OLD, metric=metric, cache_entries=0)
    x_by_id: dict[int, np.ndarray] = {}
    next_seed = 100

    def add(n):
        nonlocal next_seed
        rows = _rows(n, seed=next_seed)
        next_seed += 1
        for i, row in zip(eng.add_dense(rows), rows):
            x_by_id[int(i)] = row

    add(12)
    eng.migrate(new_params=P_NEW, batch_rows=3, drive="manual")
    for op in ops:
        which = op % 4
        if which == 0:
            add(int(rng.integers(1, 5)))
        elif which == 1 and len(x_by_id) > 2:
            gone = rng.choice(sorted(x_by_id), size=2, replace=False)
            eng.remove(np.sort(gone))
            for g in gone:
                del x_by_id[int(g)]
        elif which == 2:
            eng.compact()
        else:
            eng.migration_step()
    eng.migrate_all()
    ref = _fresh_at_new_spec(x_by_id, metric)
    assert np.array_equal(eng.ids(), ref.ids())
    q = _rows(3, seed=99)
    a_ids, a_d = eng.topk(q, 5)
    b_ids, b_d = ref.topk(q, 5)
    assert np.array_equal(a_ids, b_ids) and np.array_equal(a_d, b_d)


# ---------------------------------------------------------------------------
# Mid-migration serving: exact w.r.t. per-store references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_mid_migration_topk_and_radius_exact(metric):
    """Mid-flight answers equal the (value, id)-lex merge of per-store
    reference answers computed by the BATCH primitives, each store in its
    own sketch space — the defined exactness contract while rows live
    under two specs (for "cham" both spaces estimate original-space HD, so
    the merged ranking is also semantically coherent)."""
    x = _rows(36, seed=4)
    eng = QueryEngine(P_OLD, metric=metric, cache_entries=0)
    ids = eng.add_dense(x[:28])
    eng.remove(ids[2:5])
    eng.migrate(new_params=P_NEW, batch_rows=6, drive="manual")
    eng.migration_step()                       # src + dst both non-empty
    eng.add_dense(x[28:])                      # fresh tier non-empty too
    mig = eng.migration
    assert len(mig.src) and len(mig.dst) and len(mig.fresh)

    q = _rows(4, seed=5)
    k = 6
    parts = []
    stores = [(mig.src, P_OLD), (mig.dst, P_NEW), (mig.fresh, P_NEW)]
    probe = QueryEngine(P_OLD, metric=metric, cache_entries=0)
    for store, params in stores:
        sk, nq = probe._sketch(q, params=params)
        mat, n, sids = store.gather_alive()
        # gather_alive rows are in id order, so topk_rows' lower-column
        # tie-break IS the (value, id)-lex rule the merge expects
        t_idx, t_vals = topk_rows(
            pad_rows_pow2(sk), mat, min(k, n), d=params.sketch_dim,
            metric=metric, m_valid=n)
        parts.append((sids[np.asarray(t_idx[:nq])].astype(np.int64),
                      np.asarray(t_vals[:nq])))
    ref_ids, ref_vals = merge_topk_parts(min(k, len(eng)), parts)

    got_ids, got_vals = eng.topk(q, k)
    assert np.array_equal(got_ids, ref_ids)
    assert np.array_equal(got_vals, ref_vals)

    r = 30.0 if metric == "hamming" else 60.0
    got_r = eng.radius(q, r)
    for qi in range(len(q)):
        ref_hits = []
        for store, params in stores:
            sk, nq = probe._sketch(q, params=params)
            mat, n, sids = store.gather_alive()
            pairs = threshold_pairs(
                pad_rows_pow2(sk), mat, d=params.sketch_dim, threshold=r,
                metric=metric, n_valid=nq, m_valid=n)
            ref_hits.append(sids[pairs[pairs[:, 0] == qi, 1]])
        ref_union = np.sort(np.concatenate(ref_hits))
        assert np.array_equal(got_r[qi], ref_union)


def test_mid_migration_packed_and_pairwise_guarded():
    eng = QueryEngine(P_OLD, cache_entries=0)
    eng.add_dense(_rows(10, seed=6))
    sk, _ = eng._sketch(_rows(2, seed=7))
    eng.migrate(new_params=P_NEW, batch_rows=4, drive="manual")
    with pytest.raises(RuntimeError, match="spec-ambiguous"):
        eng.topk_packed(sk, 3)
    with pytest.raises(RuntimeError, match="spec-ambiguous"):
        eng.radius_packed(sk, 10.0)
    with pytest.raises(RuntimeError, match="mid-migration"):
        eng.pairwise(_rows(2, seed=7))
    with pytest.raises(RuntimeError, match="raw"):
        eng.add_packed(np.asarray(sk))
    with pytest.raises(RuntimeError, match="already in flight"):
        eng.migrate(new_params=P_NEW)


def test_migrate_requires_raw_archive():
    eng = QueryEngine(P_OLD, keep_raw=False)
    eng.add_dense(_rows(4, seed=8))
    with pytest.raises(RuntimeError, match="keep_raw"):
        eng.migrate(new_params=P_NEW)
    # rows ingested packed without raw strand the migration too
    eng2 = QueryEngine(P_OLD, cache_entries=0)
    sk, _ = eng2._sketch(_rows(3, seed=8))
    eng2.add_packed(np.asarray(sk))
    with pytest.raises(RuntimeError, match="no raw archive entry"):
        eng2.migrate(new_params=P_NEW)


# ---------------------------------------------------------------------------
# Journal / resume, drift auto-trigger
# ---------------------------------------------------------------------------


def test_journaled_migration_resumes_identically(tmp_path):
    x = _rows(30, seed=9)
    journal = str(tmp_path / "journal")

    eng = QueryEngine(P_OLD, metric="cham", cache_entries=0)
    eng.add_dense(x)
    eng.save(journal, step=0)
    eng.migrate(new_params=P_NEW, batch_rows=8, drive="manual",
                journal_dir=journal, journal_every=1, journal_keep=10)
    eng.migration_step()
    eng.migration_step()
    # abandon the in-memory engine; resume purely from disk
    res = QueryEngine.restore(journal)
    assert res.migrating and res.migration.rows_migrated == 16
    assert np.array_equal(res.ids(), eng.ids())
    res.migrate_all()

    ref = _fresh_at_new_spec({int(i): x[i] for i in range(30)}, "cham")
    q = _rows(3, seed=10)
    a, av = res.topk(q, 5)
    b, bv = ref.topk(q, 5)
    assert np.array_equal(a, b) and np.array_equal(av, bv)


def test_drift_auto_trigger_and_auto_publish():
    """Dense rows whose nnz percentile exceeds the Theorem-1 bound for the
    current dim must auto-start a lazy migration to theory.sketch_dim of
    the observed percentile — and traffic alone must drive it to done."""
    p_small = CabinParams(n_dims=N_DIMS, sketch_dim=32,
                          psi_seed=11, pi_seed=12)
    eng = QueryEngine(p_small, auto_migrate=True, drift_delta=0.2,
                      drift_window=64, drift_pct=95.0, cache_entries=0)
    bound = theory.max_density_for_dim(32, 0.2)
    dense = _rows(80, seed=12, lo=bound + 4, hi=bound + 8)
    eng.add_dense(dense[:64])
    assert eng.migrating, "density over the bound must trigger a migration"
    target = eng.migration.new_spec.d
    assert target > 32
    # lazy drive: ordinary traffic advances it to publication
    for i in range(80):
        if not eng.migrating:
            break
        eng.topk(dense[:1], 1)
    assert not eng.migrating and eng.d == target
    # the published engine answers identically to a fresh build at the
    # auto-chosen params
    ref = QueryEngine(eng.params, metric="cham", cache_entries=0)
    ref.add_dense(dense[:64])
    a, av = eng.topk(dense[64:67], 4)
    b, bv = ref.topk(dense[64:67], 4)
    assert np.array_equal(a, b) and np.array_equal(av, bv)


def test_auto_migrate_requires_keep_raw():
    with pytest.raises(ValueError, match="keep_raw"):
        QueryEngine(P_OLD, keep_raw=False, auto_migrate=True)


def test_max_density_for_dim_inverts_sketch_dim():
    for d in (32, 64, 256, 1024):
        s = theory.max_density_for_dim(d, 0.1)
        assert theory.sketch_dim(s, 0.1) <= d
        assert theory.sketch_dim(s + 1, 0.1) > d


# ---------------------------------------------------------------------------
# Cham missing-category mask
# ---------------------------------------------------------------------------


def test_cham_mask_inactive_is_bit_identical():
    """When the estimates already sit inside the feasible polytope (exact
    synthetic stats) and the observed counts don't bind, the masked path
    returns the same float bits as the unmasked one — serving paths that
    opt in but never see misses pay nothing."""
    d = 64
    rng = np.random.default_rng(13)
    a = rng.uniform(2, 10, 16)
    b = rng.uniform(2, 10, 16)
    ip = rng.uniform(0, 1, 16) * np.minimum(a, b)
    big_d = 1.0 - 1.0 / d
    wu = d * (1.0 - big_d ** a)
    wv = d * (1.0 - big_d ** b)
    inner = wu + wv - d * (1.0 - big_d ** (a + b - ip))
    base = np.asarray(binhamming_from_stats(wu, wv, inner, d))
    huge = np.full(16, 10_000.0)
    masked = np.asarray(binhamming_from_stats(wu, wv, inner, d,
                                              obs_u=huge, obs_v=huge))
    assert np.array_equal(base, masked)


def test_cham_mask_bounds_saturated_rows():
    """A saturated sketch (weight ~ d) of a heavily truncated row explodes
    the unmasked density estimate through the log; the observed-dimension
    clamp keeps every estimate inside the feasible polytope, so the
    distance is bounded by the observable support."""
    d = 64
    wu = np.asarray([d - 1.0])
    wv = np.asarray([5.0])
    inner = np.asarray([3.0])
    obs_u = np.asarray([10.0])   # only 10 dims were observed for u
    obs_v = np.asarray([8.0])
    unmasked = float(np.asarray(binhamming_from_stats(wu, wv, inner, d))[0])
    masked = float(np.asarray(binhamming_from_stats(
        wu, wv, inner, d, obs_u=obs_u, obs_v=obs_v))[0])
    # h = 2u - a - b with a <= obs_u, b <= obs_v, u <= a + b
    assert masked <= float(obs_u[0] + obs_v[0]) + 1e-5
    assert masked <= unmasked
    assert masked >= 0.0
