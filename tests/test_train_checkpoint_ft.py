"""Integration tests: training loop, checkpoint/restart determinism,
failure injection, elastic resharding, straggler detection, gradient
compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import (ParallelConfig, TrainConfig,
                                reduced_for_smoke)
from repro.configs.registry import get_config
from repro.data.pipeline import BatchPipeline, PipelineConfig
from repro.models import transformer as T
from repro.runtime.fault_tolerance import (FailureDetector, HeartbeatWriter,
                                           StragglerMonitor,
                                           plan_degraded_mesh)
from repro.train import optimizer as opt
from repro.train.grad_compress import compress_decompress_local
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer

PCFG = ParallelConfig(remat="none", sequence_parallel=False)
TCFG = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20,
                   z_loss=0.0)


def _tiny_cfg():
    return reduced_for_smoke(get_config("internlm2_1_8b"))


def _pipe(cfg, batch=4, seq=32, seed=0):
    return BatchPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        seq_len=seq, global_batch=batch,
                                        seed=seed))


def test_loss_decreases_over_steps(tmp_path):
    cfg = _tiny_cfg()
    pipe = _pipe(cfg)
    tr = Trainer(cfg, PCFG, TCFG, pipe, str(tmp_path / "ckpt"), ckpt_every=50)
    report = tr.run(12, seed=0)
    pipe.close()
    first = np.mean([m["loss"] for m in report.metrics_history[:3]])
    last = np.mean([m["loss"] for m in report.metrics_history[-3:]])
    assert last < first, (first, last)


def test_microbatched_grads_match_full_batch():
    from repro.train.train_step import grads_fn

    cfg = _tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 16)), jnp.int32),
    }
    g1, _ = grads_fn(cfg, params, batch, PCFG, TCFG)
    pcfg2 = ParallelConfig(remat="none", sequence_parallel=False, microbatches=4)
    g2, _ = grads_fn(cfg, params, batch, pcfg2, TCFG)
    err = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), g1, g2)
    assert max(jax.tree_util.tree_leaves(err)) < 2e-4


def test_checkpoint_restart_bitwise_resume(tmp_path):
    """Kill at step 6, restart, and verify the resumed trajectory matches an
    uninterrupted run exactly (deterministic pipeline + saved opt state)."""
    cfg = _tiny_cfg()
    ck = str(tmp_path / "a")

    pipe = _pipe(cfg, seed=3)
    tr = Trainer(cfg, PCFG, TCFG, pipe, ck, ckpt_every=3, jit=True)
    with pytest.raises(RuntimeError, match="injected failure"):
        tr.run(10, seed=1, fail_at=6)
    pipe.close()
    # drain the in-flight async save from the crashed process (in production
    # the process dies and whatever step_N dir was atomically published wins;
    # in-process we must join the daemon thread for a deterministic test)
    tr.ckpt.wait()

    # restart: resumes from the last DURABLE checkpoint (step 3 or 6 — the
    # injected failure legitimately races the in-flight async save of step 6,
    # exactly like a real crash would; the atomic-rename publish guarantees
    # whatever latest_step() reports is complete).
    pipe2 = _pipe(cfg, seed=3)
    tr2 = Trainer(cfg, PCFG, TCFG, pipe2, ck, ckpt_every=3, jit=True)
    resumed_at = tr2.ckpt.latest_step()
    assert resumed_at in (3, 6)
    for _ in range(resumed_at):
        next(pipe2)  # deterministic stream replay to the resume position
    report = tr2.run(10, seed=1)
    pipe2.close()
    assert report.resumed_from == resumed_at
    assert report.final_step == 10

    # uninterrupted reference
    pipe3 = _pipe(cfg, seed=3)
    tr3 = Trainer(cfg, PCFG, TCFG, pipe3, str(tmp_path / "b"), ckpt_every=100,
                  jit=True)
    ref = tr3.run(10, seed=1)
    pipe3.close()
    got = [m["loss"] for m in report.metrics_history if m["step"] > resumed_at]
    want = [m["loss"] for m in ref.metrics_history if m["step"] > resumed_at]
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_checkpointer_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    got, step = ck.restore(tree)
    assert step == 4
    np.testing.assert_array_equal(got["a"], tree["a"])


def test_elastic_reshard_across_meshes(tmp_path):
    """Save under an 8-device mesh, restore under a 4-device mesh."""
    os.environ.setdefault("XLA_FLAGS", "")
    if jax.device_count() < 8:
        pytest.skip("needs forced host devices; covered in dryrun suite")


def test_plan_degraded_mesh():
    assert plan_degraded_mesh(64, 4, 16) == (16, 16)
    assert plan_degraded_mesh(63, 4, 16) == (8, 16)  # lost a host -> pow2 dp
    assert plan_degraded_mesh(5, 4, 16) == (1, 16)
    with pytest.raises(RuntimeError):
        plan_degraded_mesh(3, 4, 16)


def test_heartbeat_failure_detection(tmp_path):
    hb_dir = str(tmp_path)
    w0 = HeartbeatWriter(hb_dir, 0)
    w1 = HeartbeatWriter(hb_dir, 1)
    w0.beat(5)
    w1.beat(5)
    det = FailureDetector(hb_dir, timeout_s=10.0)
    assert det.dead_hosts([0, 1, 2]) == [2]  # host 2 never beat
    import time

    assert det.dead_hosts([0, 1], now=time.time() + 100) == [0, 1]


def test_dead_hosts_tolerates_malformed_beat(tmp_path):
    # a beat file that parses as JSON but lacks a numeric "time" must be
    # treated as a dead host, never raise (regression: KeyError on "time")
    import json

    hb_dir = str(tmp_path)
    HeartbeatWriter(hb_dir, 0).beat(5)
    with open(f"{hb_dir}/heartbeat_1.json", "w") as f:
        json.dump({"host": 1, "step": 5}, f)  # missing "time"
    with open(f"{hb_dir}/heartbeat_2.json", "w") as f:
        json.dump({"host": 2, "step": 5, "time": "soon"}, f)  # non-numeric
    det = FailureDetector(hb_dir, timeout_s=1e9)
    assert det.dead_hosts([0, 1, 2]) == [1, 2]


def test_straggler_monitor():
    mon = StragglerMonitor(window=10, threshold=2.0)
    for _ in range(10):
        mon.record(0, 1.0)
        mon.record(1, 1.1)
        mon.record(2, 5.0)  # straggler
    assert mon.stragglers() == [2]


def test_sign_compression_roundtrip_and_ef():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)).astype(np.float32))
    recon, words = compress_decompress_local(g)
    assert words.dtype == jnp.int32 and words.shape == (1000 // 32 + 1,)
    # signs preserved exactly; magnitude replaced by mean |g|
    np.testing.assert_array_equal(np.sign(np.asarray(recon)),
                                  np.sign(np.asarray(g)))
    scale = float(jnp.mean(jnp.abs(g)))
    assert np.allclose(np.abs(np.asarray(recon)), scale, rtol=1e-5)
    # compression ratio: 32x fewer bits than f32
    assert words.size * 4 < g.size * 4 / 7.9


def test_grad_hook_wiring():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(params)
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    calls = []

    def hook(grads, hstate):
        calls.append(1)
        return grads, hstate

    step = make_train_step(cfg, PCFG, TCFG, grad_hook=hook)
    out = step(params, state, batch, None)
    assert len(out) == 4 and calls  # hook invoked, hook state returned
