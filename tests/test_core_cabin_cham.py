"""System-behaviour tests for Cabin + Cham: the paper's Lemmas 1, 2, 4 and
Theorem 2, plus estimator internals, on controlled synthetic data."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis when installed, fallback otherwise

from repro.core import CabinParams, packing
from repro.core.cabin import binem, binsketch, sketch_dense, sketch_sparse
from repro.core.cham import (
    binhamming_from_stats,
    cham,
    cham_matrix,
    density_estimate,
    inner_estimate,
)
from repro.core.theory import sketch_dim, theorem2_bound


def make_categorical(rng, n_rows, n, c, density):
    x = np.zeros((n_rows, n), dtype=np.int32)
    for i in range(n_rows):
        idx = rng.choice(n, size=density, replace=False)
        x[i, idx] = rng.integers(1, c + 1, size=density)
    return x


# ---------------------------------------------------------------------------
# Lemma 1: BinEm density a' satisfies a' <= a, E[a'] = a/2, concentrated.
# ---------------------------------------------------------------------------


def test_lemma1_binem_density():
    rng = np.random.default_rng(0)
    n, c, density, trials = 2000, 20, 200, 64
    x = make_categorical(rng, 1, n, c, density)
    densities = []
    for seed in range(trials):
        p = CabinParams.create(n, 512, seed=seed)
        u1 = np.asarray(binem(p, jnp.asarray(x[0])))
        a_prime = int(u1.sum())
        assert a_prime <= density  # claim (a)
        densities.append(a_prime)
    mean = np.mean(densities)
    # claim (b): E[a'] = a/2; 64 trials of Binomial(200, .5) -> se ~ 0.9
    assert abs(mean - density / 2) < 5.0
    # claim (c): concentration — all samples within 5 sigma
    assert np.max(np.abs(np.asarray(densities) - density / 2)) < 5 * np.sqrt(density / 4) + 1


# ---------------------------------------------------------------------------
# Lemma 2: HD(u, v) = 2 E[HD(u', v')].
# ---------------------------------------------------------------------------


def test_lemma2_binem_preserves_hamming():
    rng = np.random.default_rng(1)
    n, c, density = 2000, 20, 250
    x = make_categorical(rng, 2, n, c, density)
    hd = int((x[0] != x[1]).sum())
    ests = []
    for seed in range(64):
        p = CabinParams.create(n, 512, seed=seed)
        u1 = np.asarray(binem(p, jnp.asarray(x)))
        ests.append(2 * int((u1[0] != u1[1]).sum()))
    mean = np.mean(ests)
    # var of one estimate = 4 * hd/4 = hd; se of mean over 64 trials
    se = np.sqrt(hd / 64)
    assert abs(mean - hd) < 6 * se + 2


# ---------------------------------------------------------------------------
# Lemma 4: sketch retains (improves) sparsity: E[ones(Cabin(u))] <= T/2.
# ---------------------------------------------------------------------------


def test_lemma4_sketch_sparsity():
    rng = np.random.default_rng(2)
    n, c, density = 3000, 30, 400
    x = make_categorical(rng, 1, n, c, density)
    d = sketch_dim(density, 0.1)
    ones = []
    for seed in range(32):
        p = CabinParams.create(n, d, seed=seed)
        sk = sketch_dense(p, jnp.asarray(x[0]))
        ones.append(int(packing.popcount_rows(sk)))
    # mean within sampling noise of <= T/2 (se of Binomial(400,.5)/sqrt 32 ~ 1.8)
    assert np.mean(ones) <= density / 2 + 6.0


# ---------------------------------------------------------------------------
# Theorem 2: |Cham - HD| <= 11 sqrt(s ln(7/delta)) w.p. >= 1 - delta.
# ---------------------------------------------------------------------------


def test_theorem2_error_bound():
    rng = np.random.default_rng(3)
    n, c, density, rows = 4000, 25, 300, 48
    delta = 0.1
    x = make_categorical(rng, rows, n, c, density)
    d = sketch_dim(density, delta)
    p = CabinParams.create(n, d, seed=11)
    sk = sketch_dense(p, jnp.asarray(x))
    hd = (x[:, None, :] != x[None, :, :]).sum(-1)
    est = np.asarray(cham_matrix(sk, sk, d))
    iu = np.triu_indices(rows, 1)
    errors = np.abs(est - hd)[iu]
    bound = theorem2_bound(density, delta)
    frac_within = float((errors <= bound).mean())
    assert frac_within >= 1 - delta  # empirically ~1.0 (bound is loose)
    # and the estimator is far better than the bound in practice:
    assert errors.mean() < bound / 3


def test_cham_identical_vectors_is_zero():
    rng = np.random.default_rng(4)
    x = make_categorical(rng, 1, 1000, 10, 100)
    p = CabinParams.create(1000, 512, seed=0)
    sk = sketch_dense(p, jnp.asarray(x[0]))
    assert float(cham(sk, sk, 512)) == pytest.approx(0.0, abs=1e-3)


# ---------------------------------------------------------------------------
# Estimator internals
# ---------------------------------------------------------------------------


@given(st.integers(16, 4096), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_density_estimate_inverts_expectation(d, seed):
    # For a known pre-sketch density a << d, sketch weight w concentrates at
    # d(1 - (1-1/d)^a) and density_estimate(w) recovers ~a.
    rng = np.random.default_rng(seed)
    a = max(1, d // 8)
    buckets = rng.integers(0, d, size=a)
    w = len(np.unique(buckets))
    a_hat = float(density_estimate(jnp.asarray(w), d))
    assert abs(a_hat - a) < 6 * np.sqrt(a) + 2


def test_binhamming_from_stats_matches_expectation_regime():
    # Closed-form check: if sketches don't collide (w == density), the
    # estimator reduces to (approximately) the raw Hamming distance.
    d = 1 << 14
    wu = wv = jnp.asarray(64.0)
    inner = jnp.asarray(32.0)
    est = float(binhamming_from_stats(wu, wv, inner, d))
    assert est == pytest.approx(64.0, rel=0.02)  # |u|+|v|-2<uv> = 64


def test_inner_estimate_accuracy():
    rng = np.random.default_rng(5)
    n, density = 3000, 200
    bits = np.zeros((2, n), np.int32)
    common = rng.choice(n, size=density // 2, replace=False)
    bits[:, common] = 1
    for r in range(2):
        extra = rng.choice(n, size=density // 2, replace=False)
        bits[r, extra] = 1
    true_inner = int((bits[0] & bits[1]).sum())
    d = sketch_dim(density, 0.1)
    p = CabinParams.create(n, d, seed=3)
    sk = binsketch(p, jnp.asarray(bits))
    est = float(inner_estimate(sk[0], sk[1], d))
    assert abs(est - true_inner) < 3 * np.sqrt(density * np.log(10)) + 2


# ---------------------------------------------------------------------------
# Layout invariances
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sparse_dense_equivalence(seed):
    rng = np.random.default_rng(seed)
    n, c, density, rows = 800, 12, 60, 4
    x = make_categorical(rng, rows, n, c, density)
    p = CabinParams.create(n, 256, seed=seed & 0xFFFF)
    dense_sk = sketch_dense(p, jnp.asarray(x))
    idxs = np.zeros((rows, density), np.int32)
    vals = np.zeros((rows, density), np.int32)
    for i in range(rows):
        nz = np.nonzero(x[i])[0]
        idxs[i], vals[i] = nz, x[i, nz]
    sparse_sk = sketch_sparse(p, jnp.asarray(idxs), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(dense_sk), np.asarray(sparse_sk))


def test_sketch_deterministic_across_calls():
    rng = np.random.default_rng(6)
    x = make_categorical(rng, 3, 500, 8, 40)
    p = CabinParams.create(500, 128, seed=9)
    a = np.asarray(sketch_dense(p, jnp.asarray(x)))
    b = np.asarray(sketch_dense(p, jnp.asarray(x)))
    np.testing.assert_array_equal(a, b)
