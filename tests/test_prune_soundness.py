"""Prune soundness at the knife edge.

The weight-band prune (radius) and the band-expansion certificate (top-k)
both rest on one inequality: dist >= prune_factor * |s_i - s_j| up to
PRUNE_MARGIN of float noise.  These property tests attack the margin with
adversarial weight distributions — every row AT a band boundary, duplicated
weights straddling the cut, near-saturated sketches where the cham
estimator clamps — and radii/k choices that park distances within a float
ulp of the prune threshold.  The property is always the same: the banded
answer equals the brute-force batch answer, bit for bit, under both
metrics.  A dropped true neighbour here means the margin (or a certificate
inequality) went unsound.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from tests._hyp import given, settings, st

from repro.core import CabinParams, threshold_pairs, topk_rows
from repro.core.packing import np_pack_bits
from repro.index import QueryEngine

D = 256
P = CabinParams.create(500, D, seed=11)  # seeds only; rows enter pre-packed


def _packed_rows_with_weights(weights, rng):
    """One packed row per requested Hamming weight (exact, random support)."""
    bits = np.zeros((len(weights), D), np.uint8)
    for i, w in enumerate(weights):
        bits[i, rng.choice(D, size=int(w), replace=False)] = 1
    return np_pack_bits(bits)


def _adversarial_weights(seed: int, n: int) -> np.ndarray:
    """Weight multisets chosen to break band cuts: heavy ties, clustered
    runs straddling boundaries, and near-saturation (cham's log clamp)."""
    rng = np.random.default_rng(seed)
    family = seed % 4
    if family == 0:  # all rows at ONE weight: every band interval is a point
        w = np.full(n, int(rng.integers(4, D - 4)))
    elif family == 1:  # two tight clusters: the cut lands inside a tie run
        a, b = sorted(rng.integers(2, D - 2, size=2))
        w = np.where(rng.random(n) < 0.5, a, b)
    elif family == 2:  # arithmetic run: adjacent weights in every band
        lo = int(rng.integers(1, D // 2))
        w = lo + np.arange(n) % (D - lo - 1)
    else:  # near-saturation: density_estimate clamps, scores go nonlinear
        w = D - 1 - rng.integers(0, 6, size=n)
    return np.sort(w.astype(np.int64))


def _brute_radius(q_sk, data_sk, r, metric):
    pairs = threshold_pairs(jnp.asarray(q_sk), jnp.asarray(data_sk), d=D,
                            threshold=r, metric=metric)
    return [np.sort(pairs[pairs[:, 0] == qi, 1]) for qi in range(len(q_sk))]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**16))
def test_banded_queries_never_drop_neighbours_at_the_knife_edge(seed):
    rng = np.random.default_rng(seed)
    n = 48
    sk = _packed_rows_with_weights(_adversarial_weights(seed, n), rng)
    q_sk = sk[rng.choice(n, size=3, replace=False)]
    for metric in ("cham", "hamming"):
        eng = QueryEngine(P, metric=metric, band_rows=4, cache_entries=0)
        ids = eng.add_packed(sk)
        assert np.array_equal(ids, np.arange(n))

        # knife-edge radii: exact distance values (strict < excludes the
        # pair), one ulp above (includes it), and a mid-percentile value
        dists = np.asarray(topk_rows(q_sk, sk, n, d=D, metric=metric)[1])
        finite = np.unique(dists[np.isfinite(dists) & (dists > 0)])
        radii = []
        if len(finite):
            edge = float(finite[rng.integers(0, len(finite))])
            radii += [edge, float(np.nextafter(np.float32(edge),
                                               np.float32(np.inf)))]
            radii.append(float(np.percentile(finite, 60)))
        for r in radii:
            got = eng.radius_packed(jnp.asarray(q_sk), r,
                                    n_valid=len(q_sk))
            want = _brute_radius(q_sk, sk, r, metric)
            for a, b in zip(got, want):
                np.testing.assert_array_equal(a, b)

        # top-k across the tie boundary: k chosen so the cut can land inside
        # an equal-distance run of same-weight rows
        for k in (1, int(rng.integers(2, 8)), n):
            gi, gv = eng.topk_packed(jnp.asarray(q_sk), k,
                                     n_valid=len(q_sk))
            ri, rv = topk_rows(q_sk, sk, k, d=D, metric=metric)
            np.testing.assert_array_equal(gi, ri)
            np.testing.assert_array_equal(gv, rv)


@pytest.mark.parametrize("metric", ["cham", "hamming"])
def test_duplicate_rows_at_band_cuts_keep_lowest_ids(metric):
    """Every row duplicated 4x with band_rows=4: each band is one tie run,
    every cut splits equal distances.  Ties must resolve to ascending ids —
    the batch engine's stable order — through the banded path."""
    rng = np.random.default_rng(0)
    base = _packed_rows_with_weights([30, 30, 90, 90, 200, 200], rng)
    sk = np.repeat(base, 4, axis=0)  # ids 4i..4i+3 share a sketch
    eng = QueryEngine(P, metric=metric, band_rows=4, cache_entries=0)
    eng.add_packed(sk)
    gi, gv = eng.topk_packed(jnp.asarray(base), 4, n_valid=len(base))
    for j in range(6):
        np.testing.assert_array_equal(gi[j], 4 * j + np.arange(4))
        assert gv[j, 0] == gv[j, 3]  # genuinely tied, not just near
    ri, rv = topk_rows(base, sk, 4, d=D, metric=metric)
    np.testing.assert_array_equal(gi, ri)
    np.testing.assert_array_equal(gv, rv)
