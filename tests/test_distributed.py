"""Distribution-layer tests that need multiple devices: run in a SUBPROCESS
with forced host devices so the main pytest process keeps 1 device (the
dry-run contract).  Covers: sharding rules, mesh-lowered train step,
elastic checkpoint resharding, cross-pod sign compression, pipeline
parallelism, and a miniature dry-run."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_param_specs_follow_rules():
    out = run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import reduced_for_smoke
        from repro.configs.registry import get_config
        from repro.distributed import sharding as shd
        from repro.launch.specs import abstract_params

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced_for_smoke(get_config("llama3_8b"))
        with shd.set_mesh(mesh):
            params = abstract_params(cfg)
            specs = shd.param_specs(params)
        # embed table (512, 64): vocab over model, d over data
        assert specs["embed"]["table"] == P("model", "data"), specs["embed"]
        # layer params carry a leading stacked-scan dim (always None)
        l0 = specs["stages"][0]["l0"]
        assert l0["attn"]["wq"] == P(None, "data", "model")
        assert l0["attn"]["wo"] == P(None, "model", "data")
        assert l0["mlp"]["w_down"] == P(None, "model", "data")
        assert l0["norm1"]["scale"] == P(None, None)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_on_mesh():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import (ParallelConfig, TrainConfig,
                                        reduced_for_smoke)
        from repro.configs.registry import get_config
        from repro.distributed import sharding as shd
        from repro.models import transformer as T
        from repro.train import optimizer as opt
        from repro.train.train_step import make_train_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced_for_smoke(get_config("internlm2_1_8b"))
        pcfg = ParallelConfig(remat="block", sequence_parallel=True)
        tcfg = TrainConfig(z_loss=0.0)
        with shd.set_mesh(mesh):
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            psh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), shd.param_specs(params),
                is_leaf=lambda x: isinstance(x, P))
            params = jax.tree_util.tree_map(jax.device_put, params, psh)
            state = opt.init_state(params)
            rng = np.random.default_rng(0)
            batch = {
                "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 32))),
                "labels": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 32))),
            }
            step = jax.jit(make_train_step(cfg, pcfg, tcfg))
            p2, s2, metrics = step(params, state, batch)
            loss1 = float(metrics["loss"])
            # single-device reference: same math, no mesh
        print("LOSS", loss1)
        assert np.isfinite(loss1)
        print("OK")
    """)
    assert "OK" in out


def test_train_step_mesh_matches_single_device():
    """Distribution must not change the math: loss on a 2x4 mesh equals the
    unsharded single-device loss for identical params/batch."""
    code_tpl = """
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs.base import (ParallelConfig, TrainConfig,
                                        reduced_for_smoke)
        from repro.configs.registry import get_config
        from repro.distributed import sharding as shd
        from repro.models import transformer as T
        from repro.train.train_step import loss_fn

        cfg = reduced_for_smoke(get_config("qwen2_7b"))
        pcfg = ParallelConfig(remat="none", sequence_parallel={SP})
        tcfg = TrainConfig(z_loss=0.0)
        params = T.init_params(cfg, jax.random.PRNGKey(7))
        rng = np.random.default_rng(3)
        batch = {{
            "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 32))),
            "labels": jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 32))),
        }}
        {MESH}
        print("LOSS=%.6f" % float(loss))
    """
    single = run_with_devices(code_tpl.format(SP="False", MESH="""
        loss, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, pcfg, tcfg))(params, batch)
    """), n_devices=1)
    meshed = run_with_devices(code_tpl.format(SP="True", MESH="""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.set_mesh(mesh):
            loss, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, pcfg, tcfg))(params, batch)
    """), n_devices=8)
    l1 = float(single.split("LOSS=")[1].strip().split()[0])
    l2 = float(meshed.split("LOSS=")[1].strip().split()[0])
    assert abs(l1 - l2) < 5e-3, (l1, l2)


def test_elastic_checkpoint_reshard():
    out = run_with_devices("""
        import os, tempfile
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpointer import Checkpointer

        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "b": np.ones(8, np.float32)}
        d = tempfile.mkdtemp()
        # save under mesh A (8 devices, 8-way model)
        mesh_a = jax.make_mesh((8,), ("model",))
        sh_a = {"w": NamedSharding(mesh_a, P("model", None)),
                "b": NamedSharding(mesh_a, P("model"))}
        tree_a = jax.tree_util.tree_map(jax.device_put, tree, sh_a)
        ck = Checkpointer(d, async_save=False)
        ck.save(1, tree_a)
        # restore under mesh B (2x4): the elastic/degraded path
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh_b = {"w": NamedSharding(mesh_b, P("model", "data")),
                "b": NamedSharding(mesh_b, P("model"))}
        got, step = ck.restore(tree, shardings=sh_b)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
        assert got["w"].sharding == sh_b["w"]
        print("OK")
    """)
    assert "OK" in out


def test_cross_pod_sign_compression_semantics():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.grad_compress import cross_pod_sign_allreduce

        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        # per-pod gradients: pod 0 and pod 1 disagree on some signs
        g0 = rng.standard_normal(256).astype(np.float32)
        g1 = g0.copy(); g1[:64] = -g1[:64]
        stacked = jnp.asarray(np.stack([g0, g0, g1, g1]))  # (pod*data, n)

        def f(g):
            return cross_pod_sign_allreduce(g[0], "pod")[None]

        from repro.distributed.sharding import shard_map
        out = shard_map(
            f, mesh=mesh, in_specs=P(("pod", "data")),
            out_specs=P(("pod", "data")), check_vma=False)(stacked)
        out = np.asarray(out)
        # ties (majority 1 vs 1) resolve to +; where both pods agree the sign
        # must match; magnitude = pod-mean of mean|g|
        agree = np.sign(g0[64:])
        np.testing.assert_array_equal(np.sign(out[0][64:]), agree)
        scale = (np.abs(g0).mean() + np.abs(g1).mean()) / 2
        assert np.allclose(np.abs(out[0]), scale, rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply, bubble_fraction

        mesh = jax.make_mesh((4,), ("stage",))
        S, M, mb, dim = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((S, dim, dim)).astype(np.float32) * 0.3)
        xs = jnp.asarray(rng.standard_normal((M, mb, dim)).astype(np.float32))

        def stage_fn(params, x):
            return jnp.tanh(x @ params)

        got = pipeline_apply(mesh, stage_fn, w, xs, axis="stage")
        want = xs
        for s in range(S):
            want = jnp.tanh(want @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_mini_dryrun_both_meshes():
    """Miniature end-to-end dry-run: 16 forced devices, (2,2,4) multi-pod
    mesh over a reduced arch — validates the dryrun driver logic without the
    512-device production run (which runs via python -m repro.launch.dryrun)."""
    out = run_with_devices("""
        import jax, dataclasses
        import jax.numpy as jnp
        from repro.configs.base import SHAPES, ParallelConfig, reduced_for_smoke
        from repro.configs.registry import get_config
        from repro.launch.dryrun import lower_cell
        from repro.launch import roofline as rl

        mesh = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
        cfg = reduced_for_smoke(get_config("llama3_8b"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                                    global_batch=8)
        pcfg = ParallelConfig(remat="block", sequence_parallel=True)
        lowered = lower_cell(cfg, shape, mesh, pcfg)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list): cost = cost[0]
        assert cost.get("flops", 0) > 0
        coll = rl.parse_collectives(compiled.as_text(), default_group=16)
        assert coll.count > 0  # sharded program must communicate
        shape_d = dataclasses.replace(SHAPES["decode_32k"], seq_len=256,
                                      global_batch=8)
        lowered = lower_cell(cfg, shape_d, mesh, pcfg)
        compiled = lowered.compile()
        print("OK")
    """, n_devices=16, timeout=900)
    assert "OK" in out


def test_index_sharded_engine_matches_unsharded():
    """QueryEngine.shard places the store rows across a data mesh; results
    (ids and float bits) must match the unsharded engine exactly, including
    for rows added AFTER sharding."""
    out = run_with_devices("""
        import numpy as np
        import jax
        from repro.core import CabinParams
        from repro.index import QueryEngine

        n, d = 400, 256
        rng = np.random.default_rng(0)
        x = np.zeros((48, n), np.int32)
        for i in range(48):
            density = int(rng.integers(10, 60))
            idx = rng.choice(n, size=density, replace=False)
            x[i, idx] = rng.integers(1, 8, size=density)
        params = CabinParams.create(n, d, seed=2)

        plain = QueryEngine(params)
        plain.add_dense(x)

        mesh = jax.make_mesh((4,), ("data",))
        sharded = QueryEngine(params)
        sharded.add_dense(x[:24])
        sharded.shard(mesh)
        sharded.add_dense(x[24:])
        assert len(jax.devices()) == 4

        pi, pv = plain.topk(x[:6], 5)
        si, sv = sharded.topk(x[:6], 5)
        np.testing.assert_array_equal(pi, si)
        np.testing.assert_array_equal(pv, sv)
        pr = plain.radius(x[:6], 30.0)
        sr = sharded.radius(x[:6], 30.0)
        for a, b in zip(pr, sr):
            np.testing.assert_array_equal(a, b)
        sharded.remove(np.arange(5, 15))
        sharded.compact()
        plain.remove(np.arange(5, 15))
        plain.compact()
        np.testing.assert_array_equal(plain.topk(x[:6], 5)[1],
                                      sharded.topk(x[:6], 5)[1])
        print("OK")
    """, n_devices=4)
    assert "OK" in out


def test_index_sharded_eight_devices_any_history_bit_identical():
    """The acceptance run: 8 real (virtual CPU) devices, one partition
    group per device, interleaved add/remove/compact plus a spec migration
    — topk, radius AND pairwise bit-identical to the unsharded engine at
    every step, both metrics, including queries served mid-migration."""
    out = run_with_devices("""
        import numpy as np
        import jax
        from repro.core import CabinParams
        from repro.index import QueryEngine

        assert len(jax.devices()) == 8
        n, d = 400, 256
        rng = np.random.default_rng(1)
        x = np.zeros((80, n), np.int32)
        for i in range(80):
            density = int(rng.integers(10, 60))
            idx = rng.choice(n, size=density, replace=False)
            x[i, idx] = rng.integers(1, 8, size=density)
        params = CabinParams.create(n, d, seed=2)
        mesh = jax.make_mesh((8,), ("data",))

        for metric in ("cham", "hamming"):
            r = 60.0 if metric == "cham" else 30.0
            kw = dict(metric=metric, band_rows=8, merge_ratio=0.5,
                      cache_entries=0)
            plain = QueryEngine(params, **kw)
            sharded = QueryEngine(params, **kw)
            sharded.shard(mesh)

            def parity(q):
                pi, pv = plain.topk(q, 5)
                si, sv = sharded.topk(q, 5)
                np.testing.assert_array_equal(pi, si)
                np.testing.assert_array_equal(pv, sv)
                for a, b in zip(plain.radius(q, r), sharded.radius(q, r)):
                    np.testing.assert_array_equal(a, b)
                if not plain.migrating:
                    pp = plain.pairwise(q[:2])
                    sp = sharded.pairwise(q[:2])
                    np.testing.assert_array_equal(pp[0], sp[0])
                    np.testing.assert_array_equal(pp[1], sp[1])

            for eng in (plain, sharded):
                eng.add_dense(x[:40])
            parity(x[:6])
            for eng in (plain, sharded):
                eng.remove(np.arange(3, 21, 2))
            parity(x[:6])
            for eng in (plain, sharded):
                eng.compact()
                eng.add_dense(x[40:64])
            parity(x[:6])
            for eng in (plain, sharded):
                eng.migrate(d=320, drive="manual", batch_rows=16)
                eng.migration_step()
            parity(x[:6])               # mid-migration, across spec tiers
            for eng in (plain, sharded):
                eng.add_dense(x[64:])   # acked ingest lands in fresh tier
                eng.migrate_all()
            parity(x[:6])
            assert sharded.stats()["n_shards"] == 8
        print("OK")
    """, n_devices=8)
    assert "OK" in out
