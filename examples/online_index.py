"""Online index serving: stream documents in, query, mutate, snapshot.

    PYTHONPATH=src python examples/online_index.py

The full serving loop of repro.index on a synthetic document stream:
ingest with in-window near-dedup, batched top-k and radius queries, live
deletes + compaction, and a checkpoint round-trip that proves the restored
index answers bit-identically.
"""

import tempfile

import numpy as np

from repro.core import CabinParams
from repro.data.dedup import docs_to_categorical
from repro.data.pipeline import synthetic_documents
from repro.index import QueryEngine, ingest_documents


def main() -> None:
    vocab, d = 8192, 1024
    params = CabinParams.create(vocab, d, seed=7)
    engine = QueryEngine(params, metric="cham")

    # -- streaming ingest with near-duplicate filtering --------------------
    gen = synthetic_documents(vocab, seed=3, dup_fraction=0.25)
    docs = [next(gen) for _ in range(600)]
    ids = ingest_documents(engine, docs, window=128, dedup_threshold=40.0)
    dropped = int((ids == -1).sum())
    print(f"ingested {len(docs)} docs -> {len(engine)} kept "
          f"({dropped} near-duplicates dropped in-window)")

    # -- batched queries ---------------------------------------------------
    q_idx, q_val = docs_to_categorical(docs[:8], vocab)
    top_ids, top_d = engine.topk((q_idx, q_val), k=5)
    print(f"topk(8 queries, k=5): self-distance {top_d[:, 0].max():.2f}, "
          f"next-nearest mean {top_d[:, 1].mean():.1f}")
    hits = engine.radius((q_idx, q_val), r=60.0)
    print(f"radius(r=60): {[len(h) for h in hits]} matches per query "
          f"({engine.stats()['n_bands']} weight bands, pruned per query)")

    # -- live mutation -----------------------------------------------------
    stale = engine.ids()[:100]
    engine.remove(stale)
    engine.compact()
    top_ids2, _ = engine.topk((q_idx, q_val), k=5)
    assert not np.isin(top_ids2, stale).any()
    print(f"removed+compacted 100 stale rows -> {len(engine)} alive; "
          f"queries never see them")

    # -- snapshot / restore ------------------------------------------------
    with tempfile.TemporaryDirectory() as ckdir:
        engine.save(ckdir, step=1)
        restored = QueryEngine.restore(ckdir)
        r_ids, r_d = restored.topk((q_idx, q_val), k=5)
        np.testing.assert_array_equal(r_ids, top_ids2)
        print(f"checkpoint round-trip OK: restored {len(restored)} rows, "
              f"bit-identical answers")

    print("cache:", engine.stats()["cache_hits"], "hits /",
          engine.stats()["cache_misses"], "misses")


if __name__ == "__main__":
    main()
