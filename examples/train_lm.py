"""End-to-end training driver: data pipeline (with Cabin/Cham dedup) ->
model -> AdamW -> checkpoints, on any assigned --arch at a chosen width.

    PYTHONPATH=src python examples/train_lm.py --steps 30            # ~2 min CPU demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (llama-family, ~100M
params); the default demo preset shrinks width/depth so the example
completes in minutes on this 1-core CPU container — same code path,
production path selected by flags.  On TPU the same driver jits under
make_production_mesh() (see repro/launch/train.py).
"""

import argparse
import dataclasses
import tempfile

from repro.configs.base import (LayerSpec, ModelConfig, ParallelConfig,
                                TrainConfig)
from repro.configs.registry import get_config
from repro.data.pipeline import BatchPipeline, PipelineConfig
from repro.train.trainer import Trainer

PRESETS = {
    # ~100M params: 12L x 768 (GPT-2-small-ish geometry, llama-style blocks)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 head_dim=64, d_ff=2048, vocab_size=32768),
    # CPU demo: ~8M params
    "demo": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                 head_dim=64, d_ff=683, vocab_size=4096),
}


def build_config(args) -> ModelConfig:
    base = get_config(args.arch)
    p = PRESETS[args.preset]
    return dataclasses.replace(
        base, name=f"{base.name}-{args.preset}", frontend=None,
        n_frontend_tokens=0, kind="decoder", n_encoder_layers=0,
        moe=None, mla=None,
        layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
        first_k_dense=0, tie_embeddings=True,
        precision=dataclasses.replace(base.precision, param_dtype="float32",
                                      compute_dtype="float32"),
        **p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--dedup", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_config(args)
    pcfg = ParallelConfig(remat="none", sequence_parallel=False)
    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=10,
                       total_steps=args.steps, z_loss=1e-4)
    pipe = BatchPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
        dedup=args.dedup, dedup_window=128, dedup_sketch_dim=512,
        dedup_threshold=10.0))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(cfg, pcfg, tcfg, pipe, ckpt_dir,
                      ckpt_every=max(args.steps // 3, 10),
                      heartbeat_dir=ckpt_dir)
    from repro.models.transformer import count_params
    import jax

    n = count_params(jax.eval_shape(
        lambda k: __import__("repro.models.transformer",
                             fromlist=["init_params"]).init_params(cfg, k),
        jax.random.PRNGKey(0)))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}, "
          f"dedup={'on' if args.dedup else 'off'}, ckpt={ckpt_dir}")

    def log(step, metrics):
        if step % 5 == 0 or step == args.steps:
            print(f"  step {step:4d}  loss={metrics['loss']:.4f}  "
                  f"acc={metrics['accuracy']:.3f}  lr={metrics['lr']:.2e}")

    report = trainer.run(args.steps, seed=0, on_metrics=log)
    pipe.close()
    first = report.metrics_history[0]["loss"]
    last = report.metrics_history[-1]["loss"]
    print(f"done: loss {first:.3f} -> {last:.3f} over {report.steps_run} steps"
          f" (resume point: {report.final_step}; checkpoints in {ckpt_dir})")
    assert last < first


if __name__ == "__main__":
    main()
