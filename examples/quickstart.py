"""Quickstart: Cabin + Cham on a synthetic high-dimensional categorical set.

    PYTHONPATH=src python examples/quickstart.py

Builds a sparse categorical dataset (KOS-like stats), sketches it to d bits,
estimates pairwise Hamming distances with Cham, and compares against the
exact distances + the Theorem-2 bound.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix
from repro.core.theory import sketch_dim, theorem2_bound
from repro.data.synthetic import TABLE1, sample_dense, scaled_spec


def main() -> None:
    spec = scaled_spec(TABLE1["kos"], 0.25)  # ~1700 dims, density ~114
    x, _ = sample_dense(spec, n_rows=64, seed=0)
    s = int((x != 0).sum(1).max())
    delta = 0.1
    d = sketch_dim(s, delta)
    print(f"dataset: n={spec.n_dims} dims, {spec.n_categories} categories, "
          f"density<= {s}")
    print(f"sketch dim d = {d}  ({d / spec.n_dims:.1%} of original; "
          f"1 bit/feature vs ~{np.ceil(np.log2(spec.n_categories)):.0f} bits)")

    params = CabinParams.create(spec.n_dims, d, seed=42)
    sketches = sketch_dense(params, jnp.asarray(x))
    print(f"packed sketches: {sketches.shape} int32 "
          f"({sketches.nbytes} bytes vs {x.nbytes} original)")

    est = np.asarray(cham_matrix(sketches, sketches, d))
    true = (x[:, None, :] != x[None, :, :]).sum(-1)
    iu = np.triu_indices(len(x), 1)
    err = np.abs(est - true)[iu]
    bound = theorem2_bound(s, delta)
    print(f"Cham estimation: mean|err|={err.mean():.2f}  max={err.max():.2f}  "
          f"thm2 bound={bound:.1f}  within-bound={np.mean(err <= bound):.1%}")
    assert np.mean(err <= bound) >= 1 - delta
    print("OK: Theorem 2 holds empirically.")


if __name__ == "__main__":
    main()
