"""Corpus dedup with Cabin sketches vs exact Hamming — the paper's technique
deployed in the LM data pipeline.

The sketch pass streams: sketching dispatches to the fused sparse-Cabin
kernel (repro.kernels.cabin_build_sparse) on TPU, and the pairwise pass
extracts candidate pairs on device via repro.core.allpairs — the host only
ever sees the compact candidate list, never an (N, N) distance matrix.

    PYTHONPATH=src python examples/corpus_dedup.py
"""

import time

import numpy as np

from repro.data.dedup import (dedup_by_sketch, dedup_exact,
                              docs_to_categorical, sketch_corpus)
from repro.data.pipeline import synthetic_documents


def main() -> None:
    vocab, n_docs = 65536, 400
    gen = synthetic_documents(vocab, seed=5, dup_fraction=0.3)
    docs = [next(gen) for _ in range(n_docs)]
    idx, val = docs_to_categorical(docs, vocab)
    print(f"{n_docs} documents over a {vocab}-token vocab "
          f"(~30% near-duplicates injected)")

    t0 = time.perf_counter()
    _, sk = sketch_corpus(idx, val, vocab, sketch_dim=1024, seed=0)
    res = dedup_by_sketch(sk, 1024, threshold=40.0)
    t_sketch = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = dedup_exact(idx, val, vocab, threshold=40.0)
    t_exact = time.perf_counter() - t0

    agree = float((res.keep_mask == ref.keep_mask).mean())
    print(f"sketch dedup : {res.n_removed} removed in {t_sketch:.2f}s "
          f"(32-bit-packed 1024-bit sketches, streaming candidate pass)")
    print(f"exact dedup  : {ref.n_removed} removed in {t_exact:.2f}s "
          f"(full {vocab}-dim count vectors)")
    print(f"agreement    : {agree:.1%}   speedup: {t_exact/t_sketch:.1f}x")
    assert agree > 0.95


if __name__ == "__main__":
    main()
