"""Serve a small LM with batched requests (prefill + cached decode).

    PYTHONPATH=src python examples/serve_lm.py [--int8-kv]

Untrained weights => random text; the point is the serving path: batched
prefill seeding per-layer caches, then jitted one-token decode steps (the
same serve_step the decode_32k/long_500k dry-run shapes lower at scale).
"""

import argparse
import dataclasses
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ParallelConfig, reduced_for_smoke
from repro.configs.registry import get_config
from repro.data import tokenizer
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = reduced_for_smoke(get_config(args.arch))
    pcfg = ParallelConfig(remat="none", sequence_parallel=False,
                          kv_cache_dtype="int8" if args.int8_kv else "bfloat16")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, pcfg, jit=True)

    prompts = ["hello world", "the paper say", "sketching is", "categorical"]
    ids = np.stack([tokenizer.pad_or_trim(tokenizer.encode(p, add_eos=False), 16)
                    for p in prompts[: args.batch]])
    t0 = time.perf_counter()
    result = engine.generate(jnp.asarray(ids), max_new=args.new_tokens,
                             max_len=64, temperature=1.0, seed=0)
    dt_gen = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} (reduced) kv={pcfg.kv_cache_dtype} "
          f"batch={args.batch}: {toks} tokens in {dt_gen:.2f}s "
          f"({toks / dt_gen:.1f} tok/s incl. compile)")
    for i, p in enumerate(prompts[: args.batch]):
        text = tokenizer.decode(result.tokens[i]).replace("\n", " ")
        print(f"  [{p!r}] -> {text[:60]!r}")


if __name__ == "__main__":
    main()
