"""Heatmap + clustering on sketches (paper Figures 6-12 at demo scale).

The clustering and neighbour queries run on the streaming all-pairs engine
(repro.core.allpairs): k-mode assignment is a device-resident row-argmin
over the packed sketches and the k-NN demo streams top-k per row — neither
materialises an (N, N) matrix on host.  Only the heatmap MAE check builds
the full matrix, because the heatmap IS the matrix.

    PYTHONPATH=src python examples/heatmap_clustering.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.allpairs import topk_rows
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix
from repro.core.kmode import kmode, kmode_precomputed
from repro.core.metrics import ari, nmi, purity
from repro.data.synthetic import TABLE1, sample_dense, scaled_spec


def main() -> None:
    import jax

    spec = scaled_spec(TABLE1["nytimes"], 0.2)
    k, d = 4, 512
    x, _ = sample_dense(spec, n_rows=400, seed=2, cluster_centers=k)
    print(f"dataset: {x.shape[0]} pts x {spec.n_dims} dims "
          f"({spec.n_categories} categories)")

    # --- heatmap ---
    t0 = time.perf_counter()
    true = (x[:, None, :] != x[None, :, :]).sum(-1)
    t_exact = time.perf_counter() - t0
    params = CabinParams.create(spec.n_dims, d, seed=0)
    sk = sketch_dense(params, jnp.asarray(x))
    cham_jit = jax.jit(cham_matrix, static_argnums=2)
    cham_jit(sk, sk, d).block_until_ready()  # compile once, like production
    t0 = time.perf_counter()
    est = np.asarray(cham_jit(sk, sk, d))
    t_est = time.perf_counter() - t0
    iu = np.triu_indices(len(x), 1)
    print(f"heatmap: MAE={np.abs(est - true)[iu].mean():.2f} "
          f"(mean HD {true[iu].mean():.0f}); "
          f"exact {t_exact:.2f}s vs sketch {t_est:.4f}s "
          f"-> {t_exact / t_est:.0f}x")

    # --- clustering: streaming k-medoids on PACKED sketches ---
    truth, _ = kmode(x, k, seed=0, n_categories=spec.n_categories)
    sk_np = np.asarray(sk)
    t0 = time.perf_counter()
    pred = kmode_precomputed(None, sk_np, k=k, seed=0, sketch_dim=d)
    t_cluster = time.perf_counter() - t0
    print(f"k-mode on packed sketches (streaming engine, {t_cluster:.2f}s) "
          f"vs full data: purity={purity(truth, pred):.3f}"
          f" NMI={nmi(truth, pred):.3f} ARI={ari(truth, pred):.3f}")

    # --- neighbour queries: streaming top-k, no (N, N) matrix ---
    t0 = time.perf_counter()
    nn_idx, nn_dist = topk_rows(sk_np, sk_np, 6, d=d)
    t_knn = time.perf_counter() - t0
    # column 0 is the point itself (distance 0); check 5-NN label agreement
    same = (truth[nn_idx[:, 1:]] == truth[:, None]).mean()
    print(f"5-NN via streaming top-k ({t_knn:.2f}s): "
          f"{same:.1%} of neighbours share the k-mode label "
          f"(mean NN dist {nn_dist[:, 1].mean():.1f})")


if __name__ == "__main__":
    main()
