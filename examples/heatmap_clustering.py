"""Heatmap + clustering on sketches (paper Figures 6-12 at demo scale).

Clustering runs on the device k-mode engine (repro.core.kmode.kmode_packed,
DESIGN.md section 9): assignment is a device-resident row-argmin over the
packed sketches, medoid updates are streaming row-sums, and the mini-batch
mode shows the large-N configuration.  The online half attaches a
ClusterIndex to a live QueryEngine: rows are labelled as they are ingested
and the centres refit on demand.  Only the heatmap MAE check builds the
full matrix, because the heatmap IS the matrix.

    PYTHONPATH=src python examples/heatmap_clustering.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.allpairs import topk_rows
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix
from repro.core.kmode import kmode, kmode_packed
from repro.core.metrics import ari, nmi, purity
from repro.data.synthetic import TABLE1, sample_dense, scaled_spec


def main() -> None:
    import jax

    spec = scaled_spec(TABLE1["nytimes"], 0.2)
    k, d = 4, 512
    x, _ = sample_dense(spec, n_rows=400, seed=2, cluster_centers=k)
    print(f"dataset: {x.shape[0]} pts x {spec.n_dims} dims "
          f"({spec.n_categories} categories)")

    # --- heatmap ---
    t0 = time.perf_counter()
    true = (x[:, None, :] != x[None, :, :]).sum(-1)
    t_exact = time.perf_counter() - t0
    params = CabinParams.create(spec.n_dims, d, seed=0)
    sk = sketch_dense(params, jnp.asarray(x))
    cham_jit = jax.jit(cham_matrix, static_argnums=2)
    cham_jit(sk, sk, d).block_until_ready()  # compile once, like production
    t0 = time.perf_counter()
    est = np.asarray(cham_jit(sk, sk, d))
    t_est = time.perf_counter() - t0
    iu = np.triu_indices(len(x), 1)
    print(f"heatmap: MAE={np.abs(est - true)[iu].mean():.2f} "
          f"(mean HD {true[iu].mean():.0f}); "
          f"exact {t_exact:.2f}s vs sketch {t_est:.4f}s "
          f"-> {t_exact / t_est:.0f}x")

    # --- clustering: the device k-mode engine on PACKED sketches ---
    truth, _ = kmode(x, k, seed=0, n_categories=spec.n_categories)
    sk_np = np.asarray(sk)
    t0 = time.perf_counter()
    res = kmode_packed(sk_np, k, d=d, seed=0)
    t_cluster = time.perf_counter() - t0
    print(f"k-mode on packed sketches (device engine, {t_cluster:.2f}s) "
          f"vs full data: purity={purity(truth, res.labels):.3f}"
          f" NMI={nmi(truth, res.labels):.3f}"
          f" ARI={ari(truth, res.labels):.3f}")
    t0 = time.perf_counter()
    mb = kmode_packed(sk_np, k, d=d, seed=0, batch_rows=128)
    t_mb = time.perf_counter() - t0
    print(f"mini-batch mode (batch_rows=128, {t_mb:.2f}s — the large-N "
          f"config): NMI vs full-batch={nmi(res.labels, mb.labels):.3f}")

    # --- online: centres maintained over a live index ---
    from repro.index import QueryEngine

    eng = QueryEngine(params)
    clusters = eng.cluster(k, seed=0)
    eng.add_dense(x[:300])           # bootstrap fit on first ingest
    eng.add_dense(x[300:])           # fresh rows labelled on arrival
    ids, labels = clusters.labels()
    print(f"online ClusterIndex: {len(ids)} rows labelled through ingest "
          f"(NMI vs ground truth={nmi(truth[ids], labels):.3f}), "
          f"counts={clusters.counts.tolist()}")
    labels_refit = clusters.refit()
    print(f"after refit: NMI vs ground truth="
          f"{nmi(truth[ids], labels_refit):.3f} "
          f"(incremental labels were assigned against the bootstrap-time "
          f"centres; refit re-elects them from the full membership)")

    # --- neighbour queries: streaming top-k, no (N, N) matrix ---
    t0 = time.perf_counter()
    nn_idx, nn_dist = topk_rows(sk_np, sk_np, 6, d=d)
    t_knn = time.perf_counter() - t0
    # column 0 is the point itself (distance 0); check 5-NN label agreement
    same = (truth[nn_idx[:, 1:]] == truth[:, None]).mean()
    print(f"5-NN via streaming top-k ({t_knn:.2f}s): "
          f"{same:.1%} of neighbours share the k-mode label "
          f"(mean NN dist {nn_dist[:, 1].mean():.1f})")


if __name__ == "__main__":
    main()
