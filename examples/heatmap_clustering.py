"""Heatmap + clustering on sketches (paper Figures 6-12 at demo scale).

    PYTHONPATH=src python examples/heatmap_clustering.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix
from repro.core.kmode import kmode
from repro.core.metrics import ari, nmi, purity
from repro.core.packing import unpack_bits
from repro.data.synthetic import TABLE1, sample_dense, scaled_spec


def main() -> None:
    import jax

    spec = scaled_spec(TABLE1["nytimes"], 0.2)
    k, d = 4, 512
    x, _ = sample_dense(spec, n_rows=400, seed=2, cluster_centers=k)
    print(f"dataset: {x.shape[0]} pts x {spec.n_dims} dims "
          f"({spec.n_categories} categories)")

    # --- heatmap ---
    t0 = time.perf_counter()
    true = (x[:, None, :] != x[None, :, :]).sum(-1)
    t_exact = time.perf_counter() - t0
    params = CabinParams.create(spec.n_dims, d, seed=0)
    sk = sketch_dense(params, jnp.asarray(x))
    cham_jit = jax.jit(cham_matrix, static_argnums=2)
    cham_jit(sk, sk, d).block_until_ready()  # compile once, like production
    t0 = time.perf_counter()
    est = np.asarray(cham_jit(sk, sk, d))
    t_est = time.perf_counter() - t0
    iu = np.triu_indices(len(x), 1)
    print(f"heatmap: MAE={np.abs(est - true)[iu].mean():.2f} "
          f"(mean HD {true[iu].mean():.0f}); "
          f"exact {t_exact:.2f}s vs sketch {t_est:.4f}s "
          f"-> {t_exact / t_est:.0f}x")

    # --- clustering ---
    truth, _ = kmode(x, k, seed=0, n_categories=spec.n_categories)
    bits = np.asarray(unpack_bits(sk, d))
    pred, _ = kmode(bits, k, seed=0, n_categories=1)
    print(f"k-mode on sketches vs full data: purity={purity(truth, pred):.3f}"
          f" NMI={nmi(truth, pred):.3f} ARI={ari(truth, pred):.3f}")


if __name__ == "__main__":
    main()
