"""Open-loop Poisson load benchmark for the serving front door.

Every QPS number the index benchmarks report is a synchronous one-caller
loop — a closed-loop measurement that can never exhibit queueing
collapse, because the caller politely waits for each answer before
asking again.  Real traffic does not.  This suite measures what the
front door (repro/serve/frontdoor.py) actually promises under overload:

  * `sat_qps` — single-caller saturation throughput (the closed-loop
    number everything else is expressed against);
  * open-loop Poisson arrivals at 1x / 4x / 16x saturation, ~70%
    interactive / 30% bulk: per-class p50/p99 end-to-end latency,
    per-class shed rate, and answered counts.  Arrivals are submitted on
    schedule whether or not earlier answers came back — the overload is
    real, and the only reason p99 stays bounded is the bounded admission
    queue + shed-bulk-first policy;
  * exactness under load — every `partial=False` answer is compared
    bit-for-bit against the synchronous engine's answer for the same
    pooled query (the result cache is disabled: coalescing and slicing
    are what is under test, not memoization).

Asserted at >= 4x (disabled via `bars=False` at smoke sizes): shed-rate > 0,
bulk shed first, interactive p99 under the derived SLO, zero bit
mismatches, zero double answers.

`--soak` runs the chaos variant: 4x overload with faultinject arming the
front-door crash points on a cadence while traffic flows — the CI
overload-soak job's entry point (no acked-request loss, no duplicate
answers, shed > 0, p99 under SLO, bit-identity on non-partial answers).
"""

from __future__ import annotations

import sys
import threading
import time

import numpy as np

from benchmarks.bench_index import _build, _sparse_rows
from benchmarks.common import emit
from repro.runtime import faultinject
from repro.serve import (CLASS_BULK, CLASS_INTERACTIVE, FrontDoor,
                         RejectedError)

N_POOL = 64  # distinct single-row queries cycled through by the load


def _percentiles(lat_ms: list) -> tuple[float, float]:
    if not lat_ms:
        return float("nan"), float("nan")
    a = np.asarray(lat_ms)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _measure_saturation(eng, pool, k: int, calls: int = 32) -> float:
    """Closed-loop single-caller throughput: one 1-row request at a
    time, synchronous — the denominator for the overload multipliers."""
    eng.topk(pool[0], k)  # warm the compile cache for the 1-row shape
    t0 = time.perf_counter()
    for i in range(calls):
        eng.topk(pool[i % len(pool)], k)
    return calls / (time.perf_counter() - t0)


def _run_level(fd, pool, want, *, offered_qps: float, duration_s: float,
               k: int, bulk_frac: float, seed: int, max_requests: int,
               result_timeout_s: float = 120.0) -> dict:
    """One open-loop level: Poisson arrivals at `offered_qps` for
    `duration_s`, then drain.  Returns per-class latency/shed stats and
    the bit-identity mismatch count."""
    rng = np.random.default_rng(seed)
    n_req = min(int(offered_qps * duration_s), max_requests)
    handles: list = []  # (handle, class, pool index)
    offered = {CLASS_INTERACTIVE: 0, CLASS_BULK: 0}
    shed = {CLASS_INTERACTIVE: 0, CLASS_BULK: 0}
    t_next = time.monotonic()
    for i in range(n_req):
        t_next += rng.exponential(1.0 / offered_qps)
        now = time.monotonic()
        if t_next > now:
            time.sleep(t_next - now)
        # (an arrival finding itself behind schedule submits immediately:
        # open loop — the backlog is the load, not a measurement skip)
        cls = CLASS_BULK if rng.random() < bulk_frac else CLASS_INTERACTIVE
        qi = int(rng.integers(len(pool)))
        offered[cls] += 1
        try:
            handles.append((fd.submit("topk", pool[qi], k=k, cls=cls), cls,
                            qi))
        except RejectedError:
            shed[cls] += 1
    lat = {CLASS_INTERACTIVE: [], CLASS_BULK: []}
    mismatches = 0
    partials = 0
    errors = 0
    for h, cls, qi in handles:
        res = h.result(timeout=result_timeout_s)
        lat[cls].append(res.latency_ms)
        if res.error is not None:
            errors += 1
        elif res.partial:
            partials += 1
        else:
            ids_x, d_x = want[qi]
            if not (np.array_equal(res.ids, ids_x)
                    and np.array_equal(res.dists, d_x)):
                mismatches += 1
    out = {"offered_qps": offered_qps, "n_offered": n_req,
           "n_answered": len(handles), "mismatches": mismatches,
           "partials": partials, "errors": errors}
    for cls in (CLASS_INTERACTIVE, CLASS_BULK):
        p50, p99 = _percentiles(lat[cls])
        denom = max(1, offered[cls])
        out[f"p50_ms_{cls}"] = p50
        out[f"p99_ms_{cls}"] = p99
        out[f"shed_rate_{cls}"] = shed[cls] / denom
    return out


def bench_serve(n: int = 65536, k: int = 10, duration_s: float = 3.0,
                levels: tuple = (1, 4, 16), bulk_frac: float = 0.3,
                interactive_limit: int = 64, bulk_limit: int = 64,
                max_batch_rows: int = 64, max_requests: int = 8000,
                slo_factor: float = 5.0, bars: bool = True,
                seed: int = 0) -> dict:
    idx, val = _sparse_rows(n)
    eng = _build(idx, val)  # cache_entries=0: no memoization under test
    q_idx, q_val = _sparse_rows(N_POOL, seed=777)
    pool = [(q_idx[i:i + 1], q_val[i:i + 1]) for i in range(N_POOL)]
    want = [eng.topk(q, k) for q in pool]  # synchronous ground truth

    sat = _measure_saturation(eng, pool, k)
    emit("serve.sat_qps", 1e6 / sat, f"{sat:.0f} qps closed-loop")
    summary: dict = {"sat_qps": sat}
    # bounded queue + drain at >= sat implies a worst-case wait of
    # (queue + one batch in flight) / sat; slo_factor covers batching
    # jitter and the estimator warming up.  THIS is the bounded-p99 claim:
    # the SLO does not grow with the offered rate.
    slo_ms = slo_factor * 1e3 * (interactive_limit + max_batch_rows) / sat
    summary["interactive_slo_ms"] = slo_ms

    for level in levels:
        fd = FrontDoor(eng, interactive_limit=interactive_limit,
                       bulk_limit=bulk_limit, max_batch_rows=max_batch_rows,
                       max_wait_ms=1.0)
        try:
            stats = _run_level(
                fd, pool, want, offered_qps=sat * level,
                duration_s=duration_s, k=k, bulk_frac=bulk_frac,
                seed=seed + level, max_requests=max_requests)
            assert fd.double_answers == 0, "request answered twice"
        finally:
            fd.close()
        for key, v in stats.items():
            summary[f"x{level}_{key}"] = v
        emit(f"serve.x{level}", 0.0,
             f"p99i={stats['p99_ms_interactive']:.1f}ms;"
             f"p99b={stats['p99_ms_bulk']:.1f}ms;"
             f"shed_i={stats['shed_rate_interactive']:.3f};"
             f"shed_b={stats['shed_rate_bulk']:.3f}")
        assert stats["mismatches"] == 0, \
            "non-partial answer differed from the synchronous engine"
        assert stats["errors"] == 0
        if bars and level >= 4:
            assert stats["shed_rate_bulk"] > 0, \
                f"{level}x overload shed nothing — queue is not bounded?"
            assert (stats["shed_rate_bulk"]
                    >= stats["shed_rate_interactive"]), \
                "bulk must be shed before interactive"
            assert stats["p99_ms_interactive"] <= slo_ms, (
                f"interactive p99 {stats['p99_ms_interactive']:.1f}ms "
                f"breached the {slo_ms:.1f}ms SLO at {level}x")
    return summary


def soak(n: int = 8192, k: int = 10, duration_s: float = 4.0,
         level: float = 4.0, chaos_period_s: float = 0.1) -> dict:
    """Overload + chaos: 4x Poisson load while faultinject arms the
    front-door crash points on a cadence.  Asserts the full robustness
    contract; used by the CI overload-soak job."""
    idx, val = _sparse_rows(n)
    eng = _build(idx, val)
    q_idx, q_val = _sparse_rows(N_POOL, seed=777)
    pool = [(q_idx[i:i + 1], q_val[i:i + 1]) for i in range(N_POOL)]
    want = [eng.topk(q, k) for q in pool]
    sat = _measure_saturation(eng, pool, k)
    slo_ms = 5.0 * 1e3 * (64 + 64) / sat

    stop = threading.Event()

    def chaos():
        points = ["frontdoor.flush", "frontdoor.publish"]
        i = 0
        while not stop.is_set():
            faultinject.arm(points[i % len(points)])
            i += 1
            stop.wait(chaos_period_s)
        faultinject.disarm()

    fd = FrontDoor(eng, interactive_limit=64, bulk_limit=64,
                   max_batch_rows=64, max_wait_ms=1.0, max_retries=5,
                   backoff_ms=0.5)
    chaos_thread = threading.Thread(target=chaos)
    chaos_thread.start()
    try:
        stats = _run_level(fd, pool, want, offered_qps=sat * level,
                           duration_s=duration_s, k=k, bulk_frac=0.3,
                           seed=3, max_requests=6000)
    finally:
        stop.set()
        chaos_thread.join()
        fd.close()
    stats["sat_qps"] = sat
    stats["answered"] = fd.answered
    stats["double_answers"] = fd.double_answers
    # the contract the chaos run must uphold:
    assert fd.double_answers == 0, "a request was answered twice"
    assert stats["mismatches"] == 0, \
        "non-partial answer differed from the synchronous engine"
    assert stats["errors"] == 0, \
        f"{stats['errors']} requests exhausted retries under chaos"
    assert stats["shed_rate_bulk"] > 0, "4x overload must shed bulk"
    assert (stats["shed_rate_bulk"] >= stats["shed_rate_interactive"]), \
        "bulk must be shed before interactive"
    assert stats["p99_ms_interactive"] <= slo_ms, (
        f"interactive p99 {stats['p99_ms_interactive']:.1f}ms breached "
        f"the {slo_ms:.1f}ms SLO under chaos")
    emit("serve.soak", 0.0,
         f"answered={stats['n_answered']};retriesOK;"
         f"p99i={stats['p99_ms_interactive']:.1f}ms;"
         f"shed_b={stats['shed_rate_bulk']:.3f}")
    return stats


if __name__ == "__main__":
    if "--soak" in sys.argv[1:]:
        out = soak()
        print("# soak passed:", {k: round(v, 3) if isinstance(v, float)
                                 else v for k, v in out.items()})
    else:
        out = bench_serve()
        print("# bench_serve:", {k: round(v, 3) if isinstance(v, float)
                                 else v for k, v in out.items()})
