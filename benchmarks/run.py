"""Benchmark driver: one function per paper table/figure + kernel/system
benches.  Prints ``name,us_per_call,derived`` CSV; writes a JSON summary to
experiments/bench_summary.json and the kernel/dedup perf-trajectory record
to BENCH_kernels.json (repo root, committed — one snapshot per PR); appends
the roofline table when dry-run records exist.

``--suites a,b,c`` filters by substring (e.g. ``--suites kernel,dedup``
re-records just the trajectory file)."""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

# suites whose results feed the BENCH_kernels.json perf trajectory
_TRAJECTORY_SUITES = ("kernel_packed", "kernel_cham", "kernel_sketch",
                      "kernel_sparse_sketch", "dedup", "dedup_streaming",
                      "index")


def main() -> None:
    from benchmarks import bench_dedup, bench_index, bench_kernels, \
        bench_paper

    suites = [
        ("fig2_table3", bench_paper.fig2_table3_reduction_speed),
        ("fig3_rmse", bench_paper.fig3_rmse),
        ("fig4_binem_variance", bench_paper.fig4_binem_variance),
        ("fig5_step2_variance", bench_paper.fig5_step2_variance),
        ("fig6to10_clustering", bench_paper.fig6to10_clustering),
        ("table4_heatmap", bench_paper.table4_heatmap),
        ("theorem2", bench_paper.theorem2_check),
        ("kernel_packed", bench_kernels.kernel_packed_vs_unpacked),
        ("kernel_cham", bench_kernels.kernel_cham_vs_exact_fulldim),
        ("kernel_sketch", bench_kernels.kernel_sketch_throughput),
        ("kernel_sparse_sketch", bench_kernels.bench_sparse_sketch),
        ("dedup", bench_dedup.dedup_sketch_vs_exact),
        ("dedup_streaming", bench_dedup.dedup_streaming_vs_blocked),
        ("index", bench_index.bench_index),
    ]
    only = None
    for i, arg in enumerate(sys.argv[1:]):
        if arg == "--suites":
            if 2 + i >= len(sys.argv):
                raise SystemExit("usage: run.py [--suites substr[,substr...]]")
            only = sys.argv[2 + i].split(",")
    if only:
        suites = [(n, f) for n, f in suites
                  if any(sel in n for sel in only)]
        if not suites:
            raise SystemExit(f"--suites {','.join(only)} matched no suite")
    print("name,us_per_call,derived")
    summary = {}
    failures = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            summary[name] = fn()
        except Exception as e:  # keep the suite running; report at the end
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# suite {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)

    # roofline summary from dry-run records, if present
    dr_dir = os.path.join("experiments", "dryrun")
    if os.path.isdir(dr_dir):
        from repro.launch.roofline import load_records

        recs = [r for r in load_records(dr_dir) if r.get("status") == "ok"]
        for r in recs:
            roof = r.get("roofline", {})
            print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},0.0,"
                  f"dom={roof.get('dominant')};"
                  f"c={roof.get('compute_s', 0):.3g}s;"
                  f"m={roof.get('memory_s', 0):.3g}s;"
                  f"n={roof.get('collective_s', 0):.3g}s")
        summary["dryrun_cells_ok"] = len(recs)

    os.makedirs("experiments", exist_ok=True)
    with open(os.path.join("experiments", "bench_summary.json"), "w") as f:
        json.dump(summary, f, indent=1, default=str)
    trajectory = {k: v for k, v in summary.items() if k in _TRAJECTORY_SUITES}
    if trajectory:
        import jax

        # merge into the committed record so filtered / partially-failed
        # runs refresh their suites without discarding the others
        record = {"backend": jax.default_backend(), "suites": {}}
        if os.path.exists("BENCH_kernels.json"):
            try:
                with open("BENCH_kernels.json") as f:
                    record["suites"] = json.load(f).get("suites", {})
            except (json.JSONDecodeError, OSError):
                pass
        record["suites"].update(trajectory)
        with open("BENCH_kernels.json", "w") as f:
            json.dump(record, f, indent=1, default=str)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("# all benchmark suites passed")


if __name__ == "__main__":
    main()
