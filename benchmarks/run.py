"""Benchmark driver: one function per paper table/figure + kernel/system
benches.  Prints ``name,us_per_call,derived`` CSV; writes a JSON summary to
experiments/bench_summary.json; appends the roofline table when dry-run
records exist."""

from __future__ import annotations

import json
import os
import time
import traceback


def main() -> None:
    from benchmarks import bench_dedup, bench_kernels, bench_paper

    suites = [
        ("fig2_table3", bench_paper.fig2_table3_reduction_speed),
        ("fig3_rmse", bench_paper.fig3_rmse),
        ("fig4_binem_variance", bench_paper.fig4_binem_variance),
        ("fig5_step2_variance", bench_paper.fig5_step2_variance),
        ("fig6to10_clustering", bench_paper.fig6to10_clustering),
        ("table4_heatmap", bench_paper.table4_heatmap),
        ("theorem2", bench_paper.theorem2_check),
        ("kernel_packed", bench_kernels.kernel_packed_vs_unpacked),
        ("kernel_cham", bench_kernels.kernel_cham_vs_exact_fulldim),
        ("kernel_sketch", bench_kernels.kernel_sketch_throughput),
        ("dedup", bench_dedup.dedup_sketch_vs_exact),
    ]
    print("name,us_per_call,derived")
    summary = {}
    failures = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            summary[name] = fn()
        except Exception as e:  # keep the suite running; report at the end
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# suite {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)

    # roofline summary from dry-run records, if present
    dr_dir = os.path.join("experiments", "dryrun")
    if os.path.isdir(dr_dir):
        from repro.launch.roofline import load_records

        recs = [r for r in load_records(dr_dir) if r.get("status") == "ok"]
        for r in recs:
            roof = r.get("roofline", {})
            print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},0.0,"
                  f"dom={roof.get('dominant')};"
                  f"c={roof.get('compute_s', 0):.3g}s;"
                  f"m={roof.get('memory_s', 0):.3g}s;"
                  f"n={roof.get('collective_s', 0):.3g}s")
        summary["dryrun_cells_ok"] = len(recs)

    os.makedirs("experiments", exist_ok=True)
    with open(os.path.join("experiments", "bench_summary.json"), "w") as f:
        json.dump(summary, f, indent=1, default=str)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("# all benchmark suites passed")


if __name__ == "__main__":
    main()
