"""Benchmark driver: one function per paper table/figure + kernel/system
benches.  Prints ``name,us_per_call,derived`` CSV; writes a JSON summary to
experiments/bench_summary.json and appends the kernel/dedup/index suites to
the perf trajectory in BENCH_kernels.json (repo root, committed — one
timestamped entry per run, so regressions across PRs stay visible in the
file itself, not just in its git history).

``--suites a,b,c`` filters by substring (e.g. ``--suites kernel,dedup``
re-records just those suites).  ``--smoke`` runs the trajectory suites at
tiny sizes as a wiring check — failures still abort loudly, but nothing is
written to BENCH_kernels.json (smoke numbers are not perf claims).
``--device-count N`` re-execs the driver with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` when the visible
device count differs, so many-device benches (index_sharded) are
reproducible from one flag on any single-host CPU box."""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

# suites whose results feed the BENCH_kernels.json perf trajectory
_TRAJECTORY_SUITES = ("kernel_packed", "kernel_cham", "kernel_sketch",
                      "kernel_sparse_sketch", "dedup", "dedup_streaming",
                      "index", "index_mixed", "index_migrate",
                      "index_sharded", "index_bulk", "cluster", "serve")

# tiny-size overrides for --smoke: exercise every trajectory suite's wiring
# (sketch -> kernels -> engine -> index) in seconds on a bare CPU runner
_SMOKE_KWARGS = {
    "kernel_packed": dict(n_rows=64, d=256),
    "kernel_cham": dict(scale=0.004, n_rows=48, d=256),
    "kernel_sketch": dict(scale=0.01, n_rows=64, d=256),
    "kernel_sparse_sketch": dict(n_rows=64, n_dims=1 << 16, nnz=50, d=256),
    "dedup": dict(n_docs=64),
    "dedup_streaming": dict(n_docs=256),
    "index": dict(n_small=256, n_large=2048, n_queries=8, chunk=256,
                  ratio_bar=None),
    "index_mixed": dict(n_small=256, n_large=1024, q_batch=4, rounds=3,
                        churn=16, speedup_bar=None),
    "index_migrate": dict(n=512, d_new=256, batch_rows=128, q_batch=4),
    "index_sharded": dict(n=1024, n_queries=8, n_shards=4),
    "index_bulk": dict(n_docs=256, n_shards=4, window=32, mean_len=48),
    "cluster": dict(n_small=256, n_large=1024, k=4, n_iter=2,
                    oracle_iters=1, batch_rows=256, speedup_bar=None),
    "serve": dict(n=2048, duration_s=0.4, levels=(1, 4), max_requests=400,
                  bars=False),
}


def _git_rev() -> str | None:
    """Short commit hash of the tree the numbers were measured on, so a
    trajectory regression points at a PR, not a date range.  None outside
    a git checkout (e.g. a source tarball) — absence is honest there."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _record_trajectory(trajectory: dict) -> None:
    """Merge this run's suites into the committed record and append a
    timestamped entry to its `trajectory` list (older single-snapshot files
    are upgraded in place; their snapshot seeds the history).  Each entry
    carries the measurement context — backend, device count, git rev —
    so a number can be attributed before it is compared."""
    import jax

    backend = jax.default_backend()
    record = {"backend": backend, "suites": {}, "trajectory": []}
    if os.path.exists("BENCH_kernels.json"):
        try:
            with open("BENCH_kernels.json") as f:
                old = json.load(f)
            record["suites"] = old.get("suites", {})
            record["trajectory"] = old.get("trajectory", [])
            if not record["trajectory"] and record["suites"]:
                # upgrade a legacy single-snapshot file: its numbers become
                # the first trajectory entry instead of being overwritten
                record["trajectory"].append({
                    "ts": None,
                    "backend": old.get("backend", backend),
                    "suites": dict(record["suites"]),  # pre-update copy
                })
        except (json.JSONDecodeError, OSError):
            pass
    record["suites"].update(trajectory)
    record["trajectory"].append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
        "backend": backend,
        "device_count": jax.device_count(),
        "git_rev": _git_rev(),
        "suites": trajectory,
    })
    with open("BENCH_kernels.json", "w") as f:
        json.dump(record, f, indent=1, default=str)


def _ensure_device_count(argv: list[str]) -> None:
    """`--device-count N`: re-exec with XLA_FLAGS forcing N virtual host
    devices when the visible count differs.  Must run BEFORE anything
    imports jax for itself — the backend binds the device count at first
    import, so the only way to change it is a fresh process.  The env
    sentinel stops a re-exec loop when the platform ignores the flag
    (e.g. a real GPU backend): one attempt, then proceed honestly with
    whatever jax.device_count() says."""
    n = None
    for i, arg in enumerate(argv):
        if arg == "--device-count":
            if i + 1 >= len(argv):
                raise SystemExit("usage: run.py --device-count N")
            n = int(argv[i + 1])
        elif arg.startswith("--device-count="):
            n = int(arg.split("=", 1)[1])
    if n is None or n < 1:
        if n is not None:
            raise SystemExit(f"--device-count must be >= 1, got {n}")
        return
    if os.environ.get("_REPRO_BENCH_DEVICES") == str(n):
        return  # already re-exec'd once for this count
    import jax

    if jax.device_count() == n:
        return
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    env = dict(os.environ,
               XLA_FLAGS=" ".join(flags),
               _REPRO_BENCH_DEVICES=str(n))
    sys.stdout.flush()
    os.execve(sys.executable, [sys.executable] + sys.argv, env)


def main() -> None:
    _ensure_device_count(sys.argv[1:])
    from benchmarks import bench_cluster, bench_dedup, bench_index, \
        bench_kernels, bench_paper, bench_serve

    suites = [
        ("fig2_table3", bench_paper.fig2_table3_reduction_speed),
        ("fig3_rmse", bench_paper.fig3_rmse),
        ("fig4_binem_variance", bench_paper.fig4_binem_variance),
        ("fig5_step2_variance", bench_paper.fig5_step2_variance),
        ("fig6to10_clustering", bench_paper.fig6to10_clustering),
        ("table4_heatmap", bench_paper.table4_heatmap),
        ("theorem2", bench_paper.theorem2_check),
        ("kernel_packed", bench_kernels.kernel_packed_vs_unpacked),
        ("kernel_cham", bench_kernels.kernel_cham_vs_exact_fulldim),
        ("kernel_sketch", bench_kernels.kernel_sketch_throughput),
        ("kernel_sparse_sketch", bench_kernels.bench_sparse_sketch),
        ("dedup", bench_dedup.dedup_sketch_vs_exact),
        ("dedup_streaming", bench_dedup.dedup_streaming_vs_blocked),
        ("index", bench_index.bench_index),
        ("index_mixed", bench_index.bench_mixed_traffic),
        ("index_migrate", bench_index.bench_migration),
        ("index_sharded", bench_index.bench_sharded),
        ("index_bulk", bench_index.bench_bulk_ingest),
        ("cluster", bench_cluster.bench_cluster),
        ("serve", bench_serve.bench_serve),
    ]
    only = None
    smoke = "--smoke" in sys.argv[1:]
    for i, arg in enumerate(sys.argv[1:]):
        if arg == "--suites":
            if 2 + i >= len(sys.argv):
                raise SystemExit(
                    "usage: run.py [--smoke] [--suites substr[,substr...]]")
            only = sys.argv[2 + i].split(",")
    if smoke:
        suites = [(n, f) for n, f in suites if n in _SMOKE_KWARGS]
    if only:
        suites = [(n, f) for n, f in suites
                  if any(sel in n for sel in only)]
        if not suites:
            raise SystemExit(f"--suites {','.join(only)} matched no suite")
    print("name,us_per_call,derived")
    summary = {}
    failures = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            summary[name] = fn(**_SMOKE_KWARGS[name]) if smoke else fn()
        except Exception as e:  # keep the suite running; report at the end
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# suite {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)

    # roofline summary from dry-run records, if present
    dr_dir = os.path.join("experiments", "dryrun")
    if not smoke and os.path.isdir(dr_dir):
        from repro.launch.roofline import load_records

        recs = [r for r in load_records(dr_dir) if r.get("status") == "ok"]
        for r in recs:
            roof = r.get("roofline", {})
            print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},0.0,"
                  f"dom={roof.get('dominant')};"
                  f"c={roof.get('compute_s', 0):.3g}s;"
                  f"m={roof.get('memory_s', 0):.3g}s;"
                  f"n={roof.get('collective_s', 0):.3g}s")
        summary["dryrun_cells_ok"] = len(recs)

    # trajectory entries hold ONLY suites measured by THIS run — extracted
    # before the summary merge below, so a filtered or partially-failed run
    # can never stamp another run's numbers with a fresh timestamp
    trajectory = {k: v for k, v in summary.items() if k in _TRAJECTORY_SUITES}
    os.makedirs("experiments", exist_ok=True)
    out_name = "bench_summary_smoke.json" if smoke else "bench_summary.json"
    out_path = os.path.join("experiments", out_name)
    if not smoke and os.path.exists(out_path):
        # merge: a --suites-filtered run refreshes its suites without
        # discarding the others' results (same discipline as the
        # BENCH_kernels.json trajectory record)
        try:
            with open(out_path) as f:
                merged = json.load(f)
            merged.update(summary)
            summary = merged
        except (json.JSONDecodeError, OSError):
            pass
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=1, default=str)
    if trajectory and not smoke:
        _record_trajectory(trajectory)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("# all benchmark suites passed"
          + (" (smoke sizes)" if smoke else ""))


if __name__ == "__main__":
    main()
