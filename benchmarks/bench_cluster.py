"""Clustering benchmarks: what the device k-mode engine buys (DESIGN.md §9).

Two questions, measured on the same synthetic sparse categorical rows the
index benches use (vocab 32768, ~64 nnz/row):

  * parity-pair throughput at N = n_small — the full-batch device engine
    (`kmode_packed`) vs the legacy host-oracle path (`kmode_precomputed`
    with a dense NumPy/BLAS dist_fn): same algorithm, same rng sequence.
    The ratio is recorded, not asserted: at small N on CPU the oracle's
    BLAS GEMMs are genuinely competitive with the streamed tiles — the
    engine's case at this scale is memory shape (no dense (m, m) host
    matrices), not wall clock.

  * scale at N = n_large — the regime the subsystem exists for.  The host
    oracle pays O(N^2/k) dense host matrices per medoid pass; the device
    engine runs the documented mini-batch mode (`batch_rows` slices with
    per-batch centre refresh, DESIGN.md 9.2), whose medoid work is
    O(N * batch_rows / k) streamed on device.  Throughput is normalised to
    labels/s = N * iterations / wall, with the oracle timed over
    `oracle_iters` full iterations (one is ~a minute at 64k — that cost IS
    the finding).  `labels_per_s` ratio asserted >= `speedup_bar`.

--smoke passes speedup_bar=None: at wiring-check sizes both paths are
dispatch-dominated and the ratio is not a perf claim.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.cluster import ClusterIndex
from repro.core import CabinParams
from repro.core import packing
from repro.core.kmode import kmode_packed, kmode_precomputed
from repro.index import QueryEngine

VOCAB = 32768
D = 512
NNZ = 64


def _sketches(n: int, seed: int = 0) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.cabin import sketch_sparse

    rng = np.random.default_rng(seed)
    indices = rng.integers(1, VOCAB, size=(n, NNZ)).astype(np.int32)
    values = rng.integers(1, 16, size=(n, NNZ)).astype(np.int32)
    nnz = rng.integers(16, NNZ + 1, size=n)
    values[np.arange(NNZ)[None, :] >= nnz[:, None]] = 0
    params = CabinParams.create(VOCAB, D, seed=0)
    return np.asarray(sketch_sparse(params, jnp.asarray(indices),
                                    jnp.asarray(values)))


def _host_cham_dist_fn(d: int, chunk: int = 1024):
    """The legacy host oracle: dense Cham distance matrices computed with
    NumPy/BLAS on unpacked bits — the strongest honest host baseline (a
    popcount loop would only flatter the device engine)."""
    log_d = np.log1p(-1.0 / d)

    def unpack(x: np.ndarray) -> np.ndarray:
        return np.unpackbits(
            np.ascontiguousarray(x).view(np.uint8), axis=1).astype(np.float32)

    def est(w: np.ndarray) -> np.ndarray:
        return np.log(np.clip(1.0 - w / d, 1e-9, 1.0)) / log_d

    def dist(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        wa = packing.np_popcount_rows(a).astype(np.float64)
        wb = packing.np_popcount_rows(b).astype(np.float64)
        bb = unpack(b)
        b_hat = est(wb)[None, :]
        out = np.empty((len(a), len(b)), np.float32)
        for lo in range(0, len(a), chunk):
            hi = min(lo + chunk, len(a))
            inner = unpack(a[lo:hi]) @ bb.T
            u_hat = est(wa[lo:hi, None] + wb[None, :] - inner)
            out[lo:hi] = 2.0 * np.maximum(
                2.0 * u_hat - est(wa[lo:hi])[:, None] - b_hat, 0.0)
        return out

    return dist


def bench_cluster(n_small: int = 4096, n_large: int = 65536, k: int = 16,
                  n_iter: int = 2, oracle_iters: int = 1,
                  batch_rows: int = 4096,
                  speedup_bar: float | None = 10.0) -> dict:
    summary: dict = {"k": k, "n_small": n_small, "n_large": n_large}
    sk = _sketches(n_large)
    oracle = _host_cham_dist_fn(D)

    # --- parity pair at n_small: full-batch device vs host oracle ---------
    kmode_packed(sk[:n_small], k, d=D, n_iter=1, seed=0)  # warm the graphs
    t0 = time.perf_counter()
    res_small = kmode_packed(sk[:n_small], k, d=D, n_iter=n_iter, seed=0)
    t_dev = time.perf_counter() - t0
    assert len(np.unique(res_small.labels)) > 1  # a real clustering came out
    t0 = time.perf_counter()
    kmode_precomputed(oracle, sk[:n_small], k, n_iter=n_iter, seed=0)
    t_host = time.perf_counter() - t0
    dev_s = n_small * n_iter / t_dev
    host_s = n_small * n_iter / t_host
    summary[f"labels_per_s_device_n{n_small}"] = dev_s
    summary[f"labels_per_s_host_n{n_small}"] = host_s
    summary[f"full_batch_ratio_n{n_small}"] = dev_s / host_s
    emit(f"cluster.device_full_n{n_small}", t_dev * 1e6 / n_small,
         f"{dev_s:.0f} labels/s")
    emit(f"cluster.host_oracle_n{n_small}", t_host * 1e6 / n_small,
         f"{host_s:.0f} labels/s;ratio={dev_s / host_s:.2f}")

    # --- scale at n_large: device mini-batch vs host full-batch -----------
    # (mini-batch IS the serving configuration at this scale — DESIGN.md
    # 9.2; its per-sweep medoid work is N*batch/k streamed pairs instead of
    # the oracle's N^2/k dense host pairs)
    kmode_packed(sk, k, d=D, n_iter=1, seed=0, batch_rows=batch_rows)  # warm
    t0 = time.perf_counter()
    res_large = kmode_packed(sk, k, d=D, n_iter=n_iter, seed=0,
                             batch_rows=batch_rows)
    t_dev_l = time.perf_counter() - t0
    assert len(np.unique(res_large.labels)) > 1
    t0 = time.perf_counter()
    kmode_precomputed(oracle, sk, k, n_iter=oracle_iters, seed=0)
    t_host_l = time.perf_counter() - t0
    dev_ls = n_large * n_iter / t_dev_l
    host_ls = n_large * oracle_iters / t_host_l
    speedup = dev_ls / host_ls
    summary[f"labels_per_s_device_n{n_large}"] = dev_ls
    summary[f"labels_per_s_host_n{n_large}"] = host_ls
    summary["batch_rows"] = batch_rows
    summary["device_over_host"] = speedup
    emit(f"cluster.device_minibatch_n{n_large}", t_dev_l * 1e6 / n_large,
         f"{dev_ls:.0f} labels/s;batch={batch_rows}")
    emit(f"cluster.host_oracle_n{n_large}", t_host_l * 1e6 / n_large,
         f"{host_ls:.0f} labels/s")
    emit("cluster.device_over_host", 0.0, f"x{speedup:.1f}")
    # the acceptance bar: clustering a 64k collection through the device
    # subsystem must beat the legacy dense-host-matrix path outright
    if speedup_bar is not None:
        assert speedup >= speedup_bar, (
            f"device clustering only {speedup:.2f}x the host oracle at "
            f"N={n_large} (bar {speedup_bar}x)")

    # --- online assignment tail latency (the repro.cluster serving path) --
    # Classification via ClusterIndex.assign_packed is a query op like
    # topk/radius: its latency lands in the owning engine's flight recorder
    # under op="assign".  Distinct query slices each iteration keep the
    # centre engine's LRU out of the measurement.
    eng = QueryEngine(CabinParams.create(VOCAB, D, seed=0), cache_entries=0)
    eng.add_packed(sk[:n_small])
    cidx = ClusterIndex(eng, k, n_iter=n_iter, seed=0)
    qb = 64
    cidx.assign_packed(sk[:qb])  # warm the assign graphs
    h = eng.obs.histogram("engine_query_latency_ms", op="assign")
    h.reset()
    assign_iters = 12
    t0 = time.perf_counter()
    for i in range(assign_iters):
        lab = cidx.assign_packed(sk[i * qb: (i + 1) * qb])
    t_assign = time.perf_counter() - t0
    assert lab.shape == (qb,) and (lab >= 0).all() and (lab < k).all()
    summary["assign_rows_per_s"] = assign_iters * qb / t_assign
    emit("cluster.assign", t_assign * 1e6 / (assign_iters * qb),
         f"{assign_iters * qb / t_assign:.0f} rows/s;batch={qb}")
    if h.count:  # absent under REPRO_OBS=0 (null histogram, count 0)
        summary["p50_ms_assign"] = h.quantile(50)
        summary["p99_ms_assign"] = h.quantile(99)
        emit("cluster.assign_tail", 0.0,
             f"p50={h.quantile(50):.3f}ms;p99={h.quantile(99):.3f}ms")
    return summary
