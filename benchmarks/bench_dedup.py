"""LM-integration benchmark: sketch-based corpus dedup vs exact dedup.

This is the paper's technique where the framework actually deploys it (the
data pipeline).  Measures wall time and agreement of the duplicate sets.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.data.dedup import (dedup_by_sketch, dedup_by_sketch_blocked,
                              dedup_exact, docs_to_categorical, sketch_corpus)
from repro.data.pipeline import synthetic_documents


def dedup_sketch_vs_exact(n_docs=256, vocab=32768, dup_fraction=0.25):
    gen = synthetic_documents(vocab, seed=11, dup_fraction=dup_fraction)
    docs = [next(gen) for _ in range(n_docs)]
    idx, val = docs_to_categorical(docs, vocab)

    # warm the jitted paths once (a production pipeline compiles once and
    # streams windows through it), then measure steady state
    _, sk = sketch_corpus(idx, val, vocab, sketch_dim=1024, seed=0)
    dedup_by_sketch(sk, 1024, threshold=40.0)
    t_sketch, _ = timeit(
        lambda: sketch_corpus(idx, val, vocab, sketch_dim=1024, seed=0),
        repeat=1)
    t_est, res = timeit(
        lambda: dedup_by_sketch(sk, 1024, threshold=40.0), repeat=1)
    t_exact, ref = timeit(
        lambda: dedup_exact(idx, val, vocab, threshold=40.0), repeat=1)

    agree = float((res.keep_mask == ref.keep_mask).mean())
    emit("dedup.sketch_total", (t_sketch + t_est) * 1e6 / n_docs,
         f"removed={res.n_removed}")
    emit("dedup.exact_total", t_exact * 1e6 / n_docs,
         f"removed={ref.n_removed}")
    emit("dedup.speedup", (t_sketch + t_est) * 1e6 / n_docs,
         f"{t_exact / (t_sketch + t_est):.2f}x")
    emit("dedup.agreement", 0.0, f"{agree:.4f}")
    assert agree > 0.95
    return {"speedup": t_exact / (t_sketch + t_est), "agreement": agree}


def dedup_streaming_vs_blocked(n_docs=2048, vocab=32768, dup_fraction=0.25,
                               sketch_dim=1024, threshold=40.0):
    """The engine rewire measured head-to-head at N >= 2048: streaming
    device-resident candidate extraction (repro.core.allpairs) vs the seed
    blocked scan (per-block host sync + np.where + per-pair union feed).
    Both produce identical DedupResults; only the pairwise pass differs."""
    gen = synthetic_documents(vocab, seed=11, dup_fraction=dup_fraction)
    docs = [next(gen) for _ in range(n_docs)]
    idx, val = docs_to_categorical(docs, vocab)
    _, sk = sketch_corpus(idx, val, vocab, sketch_dim=sketch_dim, seed=0)

    # warm both jitted paths, then measure steady state
    res_s = dedup_by_sketch(sk, sketch_dim, threshold=threshold)
    res_b = dedup_by_sketch_blocked(sk, sketch_dim, threshold=threshold)
    assert np.array_equal(res_s.keep_mask, res_b.keep_mask)
    t_stream, _ = timeit(
        lambda: dedup_by_sketch(sk, sketch_dim, threshold=threshold),
        repeat=3)
    t_blocked, _ = timeit(
        lambda: dedup_by_sketch_blocked(sk, sketch_dim, threshold=threshold),
        repeat=3)
    emit("dedup.streaming_pass", t_stream * 1e6 / n_docs,
         f"n={n_docs};removed={res_s.n_removed}")
    emit("dedup.blocked_pass", t_blocked * 1e6 / n_docs, f"n={n_docs}")
    emit("dedup.streaming_speedup", t_stream * 1e6 / n_docs,
         f"{t_blocked / t_stream:.2f}x")
    return {"n_docs": n_docs, "t_streaming_s": t_stream,
            "t_blocked_s": t_blocked, "speedup": t_blocked / t_stream}
