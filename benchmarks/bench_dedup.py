"""LM-integration benchmark: sketch-based corpus dedup vs exact dedup.

This is the paper's technique where the framework actually deploys it (the
data pipeline).  Measures wall time and agreement of the duplicate sets.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.data.dedup import (dedup_by_sketch, dedup_exact,
                              docs_to_categorical, sketch_corpus)
from repro.data.pipeline import synthetic_documents


def dedup_sketch_vs_exact(n_docs=256, vocab=32768, dup_fraction=0.25):
    gen = synthetic_documents(vocab, seed=11, dup_fraction=dup_fraction)
    docs = [next(gen) for _ in range(n_docs)]
    idx, val = docs_to_categorical(docs, vocab)

    # warm the jitted paths once (a production pipeline compiles once and
    # streams windows through it), then measure steady state
    _, sk = sketch_corpus(idx, val, vocab, sketch_dim=1024, seed=0)
    dedup_by_sketch(sk, 1024, threshold=40.0)
    t_sketch, _ = timeit(
        lambda: sketch_corpus(idx, val, vocab, sketch_dim=1024, seed=0),
        repeat=1)
    t_est, res = timeit(
        lambda: dedup_by_sketch(sk, 1024, threshold=40.0), repeat=1)
    t_exact, ref = timeit(
        lambda: dedup_exact(idx, val, vocab, threshold=40.0), repeat=1)

    agree = float((res.keep_mask == ref.keep_mask).mean())
    emit("dedup.sketch_total", (t_sketch + t_est) * 1e6 / n_docs,
         f"removed={res.n_removed}")
    emit("dedup.exact_total", t_exact * 1e6 / n_docs,
         f"removed={ref.n_removed}")
    emit("dedup.speedup", (t_sketch + t_est) * 1e6 / n_docs,
         f"{t_exact / (t_sketch + t_est):.2f}x")
    emit("dedup.agreement", 0.0, f"{agree:.4f}")
    assert agree > 0.95
    return {"speedup": t_exact / (t_sketch + t_est), "agreement": agree}
