"""Paper-figure benchmarks: one function per table/figure of the paper.

Each function prints ``name,us_per_call,derived`` rows (common.emit) and
returns a dict of headline numbers that EXPERIMENTS.md cites.  Dataset twins
are scaled (DESIGN.md section 7) so the whole suite runs in minutes on one
CPU core; the paper's qualitative claims (orderings, trends) are asserted,
not eyeballed.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import (dataset, emit, exact_hd_matrix, mae,
                               make_methods, rmse, timeit)
from repro.core import CabinParams
from repro.core.cabin import binem, sketch_dense
from repro.core.cham import cham_matrix
from repro.core.kmode import kmode
from repro.core.metrics import ari, nmi, purity
from repro.core.theory import sketch_dim, theorem2_bound


# ---------------------------------------------------------------------------
# Figure 2 + Table 3: dimensionality-reduction speed / speedups
# ---------------------------------------------------------------------------


def fig2_table3_reduction_speed(scale=0.08, n_rows=256):
    """Sketching-phase speed, all methods jitted (steady-state timing after
    a warmup call, matching the paper's repeated-use deployment)."""
    import jax

    from benchmarks.common import BaselineParams
    from repro.core import baselines as B
    from repro.core.cabin import sketch_dense

    results = {}
    spec, x, _ = dataset("enron", scale, n_rows)
    d = 512
    cp = CabinParams.create(spec.n_dims, d, seed=0)
    bp = BaselineParams(spec.n_dims, d, 0)
    xj = jnp.asarray(x)

    sketchers = {
        "cabin": jax.jit(lambda v: sketch_dense(cp, v)),
        "bcs": jax.jit(lambda v: B.bcs_sketch(bp, binem(cp, v))),
        "hlsh": jax.jit(lambda v: B.hlsh_sketch(bp, binem(cp, v))),
        "fh": jax.jit(lambda v: B.fh_sketch(bp, binem(cp, v))),
        "sh": jax.jit(lambda v: B.simhash_sketch(bp, binem(cp, v))),
    }
    times = {}
    for name, fn in sketchers.items():
        jax.block_until_ready(fn(xj))  # warmup/compile
        sec, _ = timeit(lambda fn=fn: jax.block_until_ready(fn(xj)), repeat=3)
        times[name] = sec
        emit(f"fig2.reduce.{name}", sec * 1e6 / n_rows,
             f"d={d};n={spec.n_dims}")
    for name in ("bcs", "hlsh", "fh", "sh"):
        speedup = times[name] / times["cabin"]
        emit(f"table3.speedup_vs_{name}", times["cabin"] * 1e6,
             f"{speedup:.2f}x")
        results[f"speedup_{name}"] = speedup
    return results


# ---------------------------------------------------------------------------
# Figure 3: RMSE vs embedding dimension
# ---------------------------------------------------------------------------


def fig3_rmse(scale=0.06, n_rows=192, dims=(128, 256, 512, 1024)):
    results = {}
    for ds in ("kos", "enron"):
        spec, x, _ = dataset(ds, scale, n_rows, seed=1)
        true = exact_hd_matrix(x)
        for d in dims:
            methods = make_methods(spec.n_dims, d, seed=2)
            for name, fn in methods.items():
                sec, est = timeit(fn, x, repeat=1)
                r = rmse(est, true)
                emit(f"fig3.rmse.{ds}.{name}.d{d}", sec * 1e6, f"{r:.2f}")
                results[(ds, name, d)] = r
        # paper claim: Cabin's RMSE is lowest (or within noise of lowest)
        # at moderate dims and decreases with d
        best = min(results[(ds, m, dims[-1])] for m in
                   ("bcs", "hlsh", "fh", "sh"))
        assert results[(ds, "cabin", dims[-1])] <= best * 1.25, \
            f"cabin not competitive on {ds}"
        assert results[(ds, "cabin", dims[-1])] < results[(ds, "cabin", dims[0])]
    return {f"{k[0]}.{k[1]}.d{k[2]}": v for k, v in results.items()}


# ---------------------------------------------------------------------------
# Figure 4: BinEm variance analysis
# ---------------------------------------------------------------------------


def fig4_binem_variance(scale=0.06, trials=200):
    spec, x, _ = dataset("nips", scale, 2, seed=3)
    hd = int((x[0] != x[1]).sum())
    errors = []
    t0 = time.perf_counter()
    for t in range(trials):
        p = CabinParams.create(spec.n_dims, 256, seed=t)
        u = np.asarray(binem(p, jnp.asarray(x)))
        errors.append(hd - 2 * int((u[0] != u[1]).sum()))
    sec = (time.perf_counter() - t0) / trials
    errors = np.asarray(errors)
    q = np.percentile(errors, [25, 50, 75])
    emit("fig4.binem_err.median", sec * 1e6, f"{q[1]:.1f}")
    emit("fig4.binem_err.iqr", sec * 1e6, f"[{q[0]:.1f},{q[2]:.1f}]")
    # claim: unbiased (2*HD(u',v') centred on HD(u,v)) and concentrated
    assert abs(errors.mean()) < 4 * errors.std() / np.sqrt(trials) + 2
    assert errors.std() < 2 * np.sqrt(hd) + 2
    return {"mean": float(errors.mean()), "std": float(errors.std()),
            "hd": hd}


# ---------------------------------------------------------------------------
# Figure 5: step-2 (BinSketch vs alternatives) variance on one pair
# ---------------------------------------------------------------------------


def fig5_step2_variance(scale=0.06, trials=64, d=512):
    spec, x, _ = dataset("enron", scale, 2, seed=4)
    true = int((x[0] != x[1]).sum())
    errs: dict[str, list] = {m: [] for m in ("cabin", "bcs", "hlsh", "fh", "sh")}
    for t in range(trials):
        # jit=False: each trial reseeds the hash maps -> fresh compile
        # otherwise; eager is faster at 2-row scale
        methods = make_methods(spec.n_dims, d, seed=100 + t, jit=False)
        for name, fn in methods.items():
            est = fn(x)
            errs[name].append(float(est[0, 1]) - true)
    out = {}
    for name, e in errs.items():
        e = np.asarray(e)
        emit(f"fig5.err_mean.{name}", 0.0, f"{e.mean():.2f}")
        emit(f"fig5.err_std.{name}", 0.0, f"{e.std():.2f}")
        out[name] = (float(e.mean()), float(e.std()))
    # claim: BinSketch-based Cabin estimator is ~unbiased with lowest-group
    # variance among discrete alternatives
    assert abs(out["cabin"][0]) <= max(8.0, abs(out["sh"][0]))
    assert out["cabin"][1] <= 2.0 * min(v[1] for k, v in out.items()
                                        if k != "cabin")
    return {k: {"mean": v[0], "std": v[1]} for k, v in out.items()}


# ---------------------------------------------------------------------------
# Figures 6-9 + 10: clustering quality + speedup
# ---------------------------------------------------------------------------


def fig6to10_clustering(scale=0.05, n_rows=180, k=4, dims=(256, 512)):
    """Clustering quality of sketch-space k-mode vs the full-data ground
    truth, for Cabin AND the discrete baselines (the paper's claim is
    RELATIVE: Cabin is among the top approaches at moderate dims)."""
    from repro.core.baselines import (BaselineParams, bcs_sketch, hlsh_sketch)
    from repro.core.packing import unpack_bits

    spec, x, _ = dataset("nytimes", scale, n_rows, seed=5, clusters=k)
    t_full, (truth, _) = timeit(
        lambda: kmode(x, k, seed=0, n_categories=spec.n_categories), repeat=1)
    emit("fig10.kmode_full", t_full * 1e6, f"n={spec.n_dims}")
    out = {}
    for d in dims:
        cp = CabinParams.create(spec.n_dims, d, seed=6)
        bp = BaselineParams(spec.n_dims, d, 6)
        u_bits = binem(cp, jnp.asarray(x))
        reprs = {
            "cabin": np.asarray(unpack_bits(sketch_dense(cp, jnp.asarray(x)), d)),
            "bcs": np.asarray(bcs_sketch(bp, u_bits)),
            "hlsh": np.asarray(hlsh_sketch(bp, u_bits)),
        }
        scores_d = {}
        for name, bits in reprs.items():
            t_sk, (pred, _) = timeit(
                lambda b=bits: kmode(b, k, seed=0, n_categories=1), repeat=1)
            scores = {"purity": purity(truth, pred), "nmi": nmi(truth, pred),
                      "ari": ari(truth, pred)}
            emit(f"fig6.purity.{name}.d{d}", t_sk * 1e6,
                 f"{scores['purity']:.3f}")
            emit(f"fig7.nmi.{name}.d{d}", t_sk * 1e6, f"{scores['nmi']:.3f}")
            emit(f"fig8.ari.{name}.d{d}", t_sk * 1e6, f"{scores['ari']:.3f}")
            if name == "cabin":
                emit(f"fig10.kmode_speedup.d{d}", t_sk * 1e6,
                     f"{t_full / t_sk:.2f}x")
            scores_d[name] = scores
        out[d] = scores_d
    # paper claims: (i) sketch clustering is meaningful (NMI well above
    # chance), (ii) Cabin is among the top approaches at the larger dim.
    top = out[max(dims)]
    assert top["cabin"]["nmi"] > 0.6, top
    best_base = max(top[m]["purity"] for m in ("bcs", "hlsh"))
    assert top["cabin"]["purity"] >= best_base - 0.05, top
    return out


# ---------------------------------------------------------------------------
# Table 4 + Figures 11/12: all-pairs heatmap MAE + speedup
# ---------------------------------------------------------------------------


def table4_heatmap(scale=0.02, n_rows=256, d=1024):
    spec, x, _ = dataset("braincell", scale, n_rows, seed=7)
    t_exact, true = timeit(lambda: exact_hd_matrix(x), repeat=1)
    emit("fig11.heatmap_exact", t_exact * 1e6 / (n_rows**2),
         f"n={spec.n_dims}")
    maes = {}
    times = {}
    for name, fn in make_methods(spec.n_dims, d, seed=8).items():
        sec, est = timeit(fn, x, repeat=1)
        maes[name] = mae(est, true)
        times[name] = sec
        emit(f"table4.mae.{name}", sec * 1e6 / (n_rows**2),
             f"{maes[name]:.2f}")
    emit("fig11.heatmap_speedup", times["cabin"] * 1e6 / (n_rows**2),
         f"{t_exact / times['cabin']:.1f}x")
    # paper claim: Cabin MAE is best (the paper's <1/10-of-baselines margin
    # appears at the full 1.3M-dim regime; at CPU-budget scale the n/d ratio
    # is ~25x instead of ~1300x, so FH-with-exact-norms closes the gap —
    # we assert best-or-statistically-tied and report all MAEs).
    others = min(v for k2, v in maes.items() if k2 != "cabin")
    assert maes["cabin"] <= others * 1.1, maes
    return {"mae": maes, "speedup": t_exact / times["cabin"]}


# ---------------------------------------------------------------------------
# Theorem 2 empirical check (theory table)
# ---------------------------------------------------------------------------


def theorem2_check(scale=0.06, n_rows=96, delta=0.1):
    spec, x, _ = dataset("kos", scale, n_rows, seed=9)
    s = int((x != 0).sum(1).max())
    d = sketch_dim(s, delta)
    cp = CabinParams.create(spec.n_dims, d, seed=10)
    sk = sketch_dense(cp, jnp.asarray(x))
    est = np.asarray(cham_matrix(sk, sk, d))
    true = exact_hd_matrix(x)
    iu = np.triu_indices(n_rows, 1)
    errors = np.abs(est - true)[iu]
    bound = theorem2_bound(s, delta)
    frac = float((errors <= bound).mean())
    emit("thm2.frac_within_bound", 0.0, f"{frac:.4f}")
    emit("thm2.mean_abs_err", 0.0, f"{errors.mean():.2f}")
    emit("thm2.bound", 0.0, f"{bound:.2f}")
    assert frac >= 1 - delta
    return {"frac_within": frac, "bound": bound,
            "mean_err": float(errors.mean()), "d": d, "s": s}
