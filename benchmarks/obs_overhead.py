"""Observability overhead guard: flight recorder on vs REPRO_OBS=0.

The repro.obs contract (DESIGN.md section 11) is that instrumentation is
cheap enough to leave on in production — under 5% on the steady-state
query path — and that REPRO_OBS=0 buys the rest back exactly (same compiled
graphs, null instruments).  This script turns that claim into a CI gate:

  * each mode runs in its OWN subprocess (REPRO_OBS is read at import
    time; toggling it in-process would test the configure() path, not the
    deployment switch), measuring steady-state topk throughput against a
    live 4k-row store after warmup;
  * each mode runs `repeats` times and the BEST run counts — the guard
    compares the modes' speed-of-light, not their scheduler noise;
  * overhead = (t_on / t_off - 1); fail above `--bar` percent (default 5).

Usage: python benchmarks/obs_overhead.py [--bar 5.0] [--repeats 3]
(The child mode `--measure` is internal: it prints one JSON line.)
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_ROWS = 4096
N_QUERIES = 64
K = 10
LOOP = 200


def _measure() -> None:
    """Child: steady-state us/query for the current REPRO_OBS setting."""
    from benchmarks.bench_index import _build, _sparse_rows
    from repro import obs

    idx, val = _sparse_rows(N_ROWS)
    q_idx, q_val = idx[:N_QUERIES], val[:N_QUERIES]
    eng = _build(idx, val)
    for _ in range(5):  # warm: compile + first-touch caches
        eng.topk((q_idx, q_val), k=K)
    t0 = time.perf_counter()
    for _ in range(LOOP):
        ids, _ = eng.topk((q_idx, q_val), k=K)
    t = time.perf_counter() - t0
    assert ids.shape == (N_QUERIES, K)
    h = eng.obs.histogram("engine_query_latency_ms", op="topk")
    # prove the switch took: instruments live iff obs is enabled
    assert (h.count > 0) == obs.enabled(), (h.count, obs.enabled())
    print(json.dumps({"us_per_query": t * 1e6 / (LOOP * N_QUERIES),
                      "obs_enabled": obs.enabled()}))


def _run_child(obs_on: bool) -> float:
    env = dict(os.environ)
    env["REPRO_OBS"] = "1" if obs_on else "0"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--measure"],
        capture_output=True, text=True, env=env, cwd=_ROOT)
    if out.returncode != 0:
        raise SystemExit(
            f"measurement child (REPRO_OBS={env['REPRO_OBS']}) failed:\n"
            f"{out.stdout}\n{out.stderr}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["obs_enabled"] == obs_on
    return float(rec["us_per_query"])


def main() -> None:
    args = sys.argv[1:]
    if "--measure" in args:
        _measure()
        return
    bar = 5.0
    repeats = 3
    if "--bar" in args:
        bar = float(args[args.index("--bar") + 1])
    if "--repeats" in args:
        repeats = int(args[args.index("--repeats") + 1])
    t_on = min(_run_child(True) for _ in range(repeats))
    t_off = min(_run_child(False) for _ in range(repeats))
    overhead = (t_on / t_off - 1.0) * 100.0
    print(f"obs on:  {t_on:.2f} us/query")
    print(f"obs off: {t_off:.2f} us/query  (REPRO_OBS=0)")
    print(f"overhead: {overhead:+.2f}%  (bar: {bar:.1f}%)")
    if overhead > bar:
        raise SystemExit(
            f"observability overhead {overhead:.2f}% exceeds the "
            f"{bar:.1f}% bar — the flight recorder is no longer "
            "always-on cheap")
    print("OK")


if __name__ == "__main__":
    main()
