"""Index-subsystem benchmarks: what the serving layer costs.

Three questions a deployment actually asks, measured on synthetic sparse
categorical rows (vocab 32768, ~64 nnz/row — BoW-document-shaped):

  * build throughput — rows/s to ingest a corpus from raw COO rows into a
    queryable store (sketching + packed append), at N = 4k and 64k;
  * query QPS — batched topk(k=10) against the live store (result cache
    disabled: every query pays the full gather + streaming reduction);
  * incremental add vs full rebuild — the reason the store exists: when a
    chunk of new rows arrives, appending to the live index must cost a
    small fraction of re-sketching the whole corpus.  The emitted ratio
    (amortized per-chunk add time / full rebuild time) is asserted <= 0.25
    at N = 64k; in practice it tracks chunk/N plus buffer-doubling noise.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import CabinParams
from repro.index import QueryEngine

VOCAB = 32768
D = 512
NNZ = 64


def _sparse_rows(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Padded-COO rows with varied density (16..NNZ nnz, Zipf-ish ids)."""
    rng = np.random.default_rng(seed)
    indices = rng.integers(1, VOCAB, size=(n, NNZ)).astype(np.int32)
    values = rng.integers(1, 16, size=(n, NNZ)).astype(np.int32)
    nnz = rng.integers(16, NNZ + 1, size=n)
    values[np.arange(NNZ)[None, :] >= nnz[:, None]] = 0
    return indices, values


def _build(idx: np.ndarray, val: np.ndarray) -> QueryEngine:
    params = CabinParams.create(VOCAB, D, seed=0)
    eng = QueryEngine(params, cache_entries=0)
    eng.add_sparse(idx, val)
    return eng


def bench_index(n_small: int = 4096, n_large: int = 65536, k: int = 10,
                n_queries: int = 64, chunk: int = 4096,
                ratio_bar: float | None = 0.25) -> dict:
    summary: dict = {}
    idx_l, val_l = _sparse_rows(n_large)
    q_idx, q_val = idx_l[:n_queries], val_l[:n_queries]

    # --- build throughput + query QPS at both scales ----------------------
    for n in (n_small, n_large):
        idx, val = idx_l[:n], val_l[:n]
        _build(idx, val)  # warm the sketch/append graphs for this shape
        t_build, eng = timeit(lambda: _build(idx, val), repeat=1)
        summary[f"build_rows_per_s_n{n}"] = n / t_build
        emit(f"index.build_n{n}", t_build * 1e6 / n, f"{n / t_build:.0f} rows/s")

        eng.topk((q_idx, q_val), k)  # warm the query graphs
        t_q, (ids, dists) = timeit(lambda: eng.topk((q_idx, q_val), k),
                                   repeat=3)
        assert ids.shape == (n_queries, k)
        # every query row is in the store: nearest neighbour is itself at 0
        assert (ids[:, 0] == np.arange(n_queries)).all()
        summary[f"qps_k{k}_n{n}"] = n_queries / t_q
        emit(f"index.query_n{n}", t_q * 1e6 / n_queries,
             f"qps={n_queries / t_q:.1f};k={k}")

    # --- incremental add vs full rebuild at n_large -----------------------
    t_rebuild, _ = timeit(lambda: _build(idx_l, val_l), repeat=1)
    params = CabinParams.create(VOCAB, D, seed=0)
    eng = QueryEngine(params, cache_entries=0)
    add_times = []
    for lo in range(0, n_large, chunk):
        t, _ = timeit(lambda: eng.add_sparse(idx_l[lo: lo + chunk],
                                             val_l[lo: lo + chunk]),
                      repeat=1)
        add_times.append(t)
    assert len(eng) == n_large
    t_incr = float(np.mean(add_times))
    ratio = t_incr / t_rebuild
    summary.update({
        "n_large": n_large,
        "chunk": chunk,
        "t_rebuild_s": t_rebuild,
        "t_incr_chunk_amortized_s": t_incr,
        "incr_over_rebuild": ratio,
    })
    emit("index.rebuild_full", t_rebuild * 1e6 / n_large, f"n={n_large}")
    emit("index.incr_add_chunk", t_incr * 1e6 / chunk,
         f"chunk={chunk};ratio={ratio:.3f}")
    # the acceptance bar: appending a chunk costs a small fraction of a
    # rebuild (it re-sketches only the chunk, not the corpus).  --smoke runs
    # pass ratio_bar=None: at wiring-check sizes per-call dispatch overhead
    # dominates the chunk adds and the ratio is not a perf claim.
    if ratio_bar is not None:
        assert ratio <= ratio_bar, f"incremental add not amortized: {ratio:.3f}"
    return summary
