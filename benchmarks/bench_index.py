"""Index-subsystem benchmarks: what the serving layer costs.

Four questions a deployment actually asks, measured on synthetic sparse
categorical rows (vocab 32768, ~64 nnz/row — BoW-document-shaped):

  * build throughput — rows/s to ingest a corpus from raw COO rows into a
    queryable store (sketching + packed append), at N = 4k and 64k;
  * query QPS — batched topk(k=10) against the live store (result cache
    disabled: every query pays the full gather + streaming reduction);
  * incremental add vs full rebuild — the reason the store exists: when a
    chunk of new rows arrives, appending to the live index must cost a
    small fraction of re-sketching the whole corpus.  The emitted ratio
    (amortized per-chunk add time / full rebuild time) is asserted <= 0.25
    at N = 64k; in practice it tracks chunk/N plus buffer-doubling noise;
  * mixed read/write traffic (`bench_mixed_traffic`) — the regime the
    tiered layout exists for (DESIGN.md 8.5): queries interleaved with
    adds and removes, where every mutation used to force the next query
    through a full O(N log N) layout rebuild.  Reports `qps_mixed` at both
    scales plus the query-after-single-add latency under the tiered layout
    vs the rebuild-per-mutation baseline (merge_ratio=0); the speedup is
    asserted >= 50x at N = 64k;
  * spec migration (`bench_migration`) — what a drift-triggered lazy
    re-sketch costs (DESIGN.md section 10): `migration_rows_per_s` for
    draining the whole corpus to a wider sketch, and topk QPS measured
    mid-flight (cross-version serving over src/dst/fresh tiers) vs after
    publish, so the serving tax of an in-flight migration is a recorded
    number rather than folklore;
  * sharded serving (`bench_sharded`) — topk QPS with the engine's
    partition layer spread across every visible device (one shard per
    device; `run.py --device-count N` makes N virtual CPU devices for
    reproducible many-device numbers on one host), with the sharded
    answer asserted bit-identical to the unsharded engine's.  Emits
    `qps_sharded` + `device_count` into the trajectory;
  * merge-tree bulk load (`bench_bulk_ingest`) — the parallel corpus
    load path (DESIGN.md section 14): N workers sketch document shards
    concurrently, log-depth merge combines them, asserted bit-identical
    to one sequential `ingest_documents`.  Emits `ingest_rows_per_s_seq`
    vs `ingest_rows_per_s_tree` (+ worker count) into the trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import CabinParams
from repro.index import QueryEngine

VOCAB = 32768
D = 512
NNZ = 64


def _sparse_rows(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Padded-COO rows with varied density (16..NNZ nnz, Zipf-ish ids)."""
    rng = np.random.default_rng(seed)
    indices = rng.integers(1, VOCAB, size=(n, NNZ)).astype(np.int32)
    values = rng.integers(1, 16, size=(n, NNZ)).astype(np.int32)
    nnz = rng.integers(16, NNZ + 1, size=n)
    values[np.arange(NNZ)[None, :] >= nnz[:, None]] = 0
    return indices, values


def _build(idx: np.ndarray, val: np.ndarray, **engine_kwargs) -> QueryEngine:
    params = CabinParams.create(VOCAB, D, seed=0)
    eng = QueryEngine(params, cache_entries=0, **engine_kwargs)
    eng.add_sparse(idx, val)
    return eng


def bench_index(n_small: int = 4096, n_large: int = 65536, k: int = 10,
                n_queries: int = 64, chunk: int = 4096,
                ratio_bar: float | None = 0.25) -> dict:
    summary: dict = {}
    idx_l, val_l = _sparse_rows(n_large)
    q_idx, q_val = idx_l[:n_queries], val_l[:n_queries]

    # --- build throughput + query QPS at both scales ----------------------
    for n in (n_small, n_large):
        idx, val = idx_l[:n], val_l[:n]
        _build(idx, val)  # warm the sketch/append graphs for this shape
        t_build, eng = timeit(lambda: _build(idx, val), repeat=1)
        summary[f"build_rows_per_s_n{n}"] = n / t_build
        emit(f"index.build_n{n}", t_build * 1e6 / n, f"{n / t_build:.0f} rows/s")

        eng.topk((q_idx, q_val), k)  # warm the query graphs
        t_q, (ids, dists) = timeit(lambda: eng.topk((q_idx, q_val), k),
                                   repeat=3)
        assert ids.shape == (n_queries, k)
        # every query row is in the store: nearest neighbour is itself at 0
        assert (ids[:, 0] == np.arange(n_queries)).all()
        summary[f"qps_k{k}_n{n}"] = n_queries / t_q
        emit(f"index.query_n{n}", t_q * 1e6 / n_queries,
             f"qps={n_queries / t_q:.1f};k={k}")

        # tail latency from the engine's own flight recorder: reset the
        # per-op histogram AFTER warmup (compile-time outliers are not a
        # serving claim) and run a measured window.  Under REPRO_OBS=0 the
        # null histogram stays at count 0 and the keys are simply absent.
        h = eng.obs.histogram("engine_query_latency_ms", op="topk")
        h.reset()
        for _ in range(12):
            eng.topk((q_idx, q_val), k)
        if h.count:
            summary[f"p50_ms_topk_n{n}"] = h.quantile(50)
            summary[f"p99_ms_topk_n{n}"] = h.quantile(99)
            emit(f"index.query_tail_n{n}", 0.0,
                 f"p50={h.quantile(50):.3f}ms;p99={h.quantile(99):.3f}ms")

    # --- incremental add vs full rebuild at n_large -----------------------
    t_rebuild, _ = timeit(lambda: _build(idx_l, val_l), repeat=1)
    params = CabinParams.create(VOCAB, D, seed=0)
    eng = QueryEngine(params, cache_entries=0)
    add_times = []
    for lo in range(0, n_large, chunk):
        t, _ = timeit(lambda: eng.add_sparse(idx_l[lo: lo + chunk],
                                             val_l[lo: lo + chunk]),
                      repeat=1)
        add_times.append(t)
    assert len(eng) == n_large
    t_incr = float(np.mean(add_times))
    ratio = t_incr / t_rebuild
    summary.update({
        "n_large": n_large,
        "chunk": chunk,
        "t_rebuild_s": t_rebuild,
        "t_incr_chunk_amortized_s": t_incr,
        "incr_over_rebuild": ratio,
    })
    emit("index.rebuild_full", t_rebuild * 1e6 / n_large, f"n={n_large}")
    emit("index.incr_add_chunk", t_incr * 1e6 / chunk,
         f"chunk={chunk};ratio={ratio:.3f}")
    # the acceptance bar: appending a chunk costs a small fraction of a
    # rebuild (it re-sketches only the chunk, not the corpus).  --smoke runs
    # pass ratio_bar=None: at wiring-check sizes per-call dispatch overhead
    # dominates the chunk adds and the ratio is not a perf claim.
    if ratio_bar is not None:
        assert ratio <= ratio_bar, f"incremental add not amortized: {ratio:.3f}"
    return summary


def bench_mixed_traffic(n_small: int = 4096, n_large: int = 65536,
                        k: int = 10, q_batch: int = 8, rounds: int = 24,
                        churn: int = 32,
                        speedup_bar: float | None = 50.0) -> dict:
    """Interleaved add/remove/query traffic against a live index.

    Per round: ingest `churn` fresh COO rows, tombstone `churn` of the
    oldest alive ids, then answer a `q_batch`-query topk(k) — so EVERY
    query lands one mutation after the last, the worst case for any layout
    tied to version equality.  `qps_mixed` is queries/s over the whole
    loop (mutation cost included — it is traffic, not overhead).

    The second half isolates the tentpole claim: the layout maintenance a
    query pays immediately after a SINGLE add (`QueryEngine.sync_layout`),
    under the tiered layout (the delta absorbs the row — O(delta) host
    bookkeeping) vs the rebuild-per-mutation baseline (`merge_ratio=0`,
    the pre-tiered serving path: O(N log N) host sort + O(N) gather).
    `after_add_speedup` at N = 64k is the acceptance bar (>= 50x).  The
    bar sits on the sync metric and not on end-to-end add+query latency
    because the distance compute of the query itself is IDENTICAL (and
    bit-identical) in both paths and dominates wall time; what the tiered
    layout removes is exactly the mutation-induced maintenance in front of
    it, reported separately.  End-to-end `t_after_add_*` rides along for
    context.  --smoke passes speedup_bar=None: at wiring-check sizes the
    rebuild is only a few hundred rows and dispatch overhead dominates.
    """
    summary: dict = {}
    # the delta folds back into the base every ~8 rounds: the timed window
    # then spans full grow -> fold lifecycles, and one untimed warm cycle
    # has already compiled every pow2 delta-bucket graph steady-state
    # serving uses (same O(log) compile discipline as the store's appends)
    merge_rows = 8 * churn
    warm_rounds = -(-merge_rows // churn) + 1
    idx_l, val_l = _sparse_rows(
        n_large + churn * (rounds + warm_rounds + 1), seed=1)

    def mixed_loop(n: int, **engine_kwargs) -> tuple[float, object]:
        """(queries/s, topk latency histogram) over `rounds` of (add churn,
        remove churn, query), after one untimed merge cycle of warmup.  The
        histogram covers only the timed rounds (reset after warmup); under
        REPRO_OBS=0 it is the null instrument with count 0."""
        engine_kwargs.setdefault("merge_ratio", merge_rows / n)
        eng = _build(idx_l[:n], val_l[:n], **engine_kwargs)
        fresh_lo, remove_lo = n, 0
        q_idx, q_val = idx_l[:q_batch], val_l[:q_batch]

        def one_round():
            nonlocal fresh_lo, remove_lo
            eng.add_sparse(idx_l[fresh_lo: fresh_lo + churn],
                           val_l[fresh_lo: fresh_lo + churn])
            fresh_lo += churn
            eng.remove(np.arange(remove_lo, remove_lo + churn))
            remove_lo += churn
            ids, _ = eng.topk((q_idx, q_val), k)
            assert ids.shape == (q_batch, k)

        for _ in range(warm_rounds):
            one_round()
        h = eng.obs.histogram("engine_query_latency_ms", op="topk")
        h.reset()
        t0 = time.perf_counter()
        for _ in range(rounds):
            one_round()
        return rounds * q_batch / (time.perf_counter() - t0), h

    for n in (n_small, n_large):
        qps, h = mixed_loop(n)
        summary[f"qps_mixed_n{n}"] = qps
        if h.count:
            summary[f"p50_ms_topk_mixed_n{n}"] = h.quantile(50)
            summary[f"p99_ms_topk_mixed_n{n}"] = h.quantile(99)
        emit(f"index.mixed_n{n}", 1e6 / qps,
             f"qps_mixed={qps:.1f};churn={churn};k={k}")
    # same traffic under the pre-tiered policy: the end-to-end cost of
    # putting a layout rebuild in front of every post-mutation query
    qps_rb, _ = mixed_loop(n_large, merge_ratio=0.0)
    summary[f"qps_mixed_rebuild_n{n_large}"] = qps_rb
    emit(f"index.mixed_rebuild_n{n_large}", 1e6 / qps_rb,
         f"qps_mixed={qps_rb:.1f}")

    # --- layout maintenance after a single add: tiered vs rebuild ---------
    one_idx = idx_l[n_large: n_large + 1]
    one_val = val_l[n_large: n_large + 1]
    q_idx, q_val = idx_l[:q_batch], val_l[:q_batch]
    for label, ratio in (("tiered", 0.125), ("rebuild", 0.0)):
        eng = _build(idx_l[:n_large], val_l[:n_large], merge_ratio=ratio)
        # warm: the capacity-doubling append, the sync, the query graphs
        eng.add_sparse(one_idx, one_val)
        eng.topk((q_idx, q_val), k)
        sync_times = []
        for _ in range(5):
            eng.add_sparse(one_idx, one_val)
            t0 = time.perf_counter()
            eng.sync_layout()
            sync_times.append(time.perf_counter() - t0)
        summary[f"t_sync_after_add_{label}_s"] = min(sync_times)
        emit(f"index.sync_after_add_{label}", min(sync_times) * 1e6,
             f"n={n_large}")

        def add_then_query(e=eng):
            e.add_sparse(one_idx, one_val)
            return e.topk((q_idx, q_val), k)

        t, _ = timeit(add_then_query, repeat=3)
        summary[f"t_after_add_{label}_s"] = t
        emit(f"index.after_add_{label}", t * 1e6, f"n={n_large}")
    speedup = (summary["t_sync_after_add_rebuild_s"]
               / summary["t_sync_after_add_tiered_s"])
    summary["after_add_speedup"] = speedup
    summary["n_large"] = n_large
    emit("index.after_add_speedup", 0.0, f"x{speedup:.1f}")
    # the acceptance bar: a single add must not put an O(N log N) layout
    # rebuild in front of the next query (ISSUE 4 tentpole, >= 50x)
    if speedup_bar is not None:
        assert speedup >= speedup_bar, (
            f"layout sync after add only {speedup:.1f}x faster than the "
            f"rebuild path (bar {speedup_bar}x)")
    return summary


def bench_sharded(n: int = 65536, k: int = 10, n_queries: int = 64,
                  n_shards: int | None = None) -> dict:
    """Sharded topk QPS vs the unsharded engine on the same corpus.

    With > 1 visible device the engine shards one partition group per
    device of a 1-D data mesh; on a single device it falls back to
    `n_shards` logical shards (default 8) so the cross-shard merge path is
    always exercised.  The sharded answer must be bit-identical to the
    unsharded one — the partition layer's core contract — so this bench is
    also a parity check at bench scale."""
    import jax

    devs = jax.devices()
    summary: dict = {"n": n, "device_count": len(devs)}
    idx, val = _sparse_rows(n, seed=3)
    q = (idx[:n_queries], val[:n_queries])

    eng = _build(idx, val)
    eng.topk(q, k)  # warm the query graphs
    t_un, (ids_ref, d_ref) = timeit(lambda: eng.topk(q, k), repeat=3)
    summary["qps_unsharded"] = n_queries / t_un

    sh = _build(idx, val)
    if len(devs) > 1:
        sh.shard(jax.make_mesh((len(devs),), ("data",)))
    else:
        sh.shard(n_shards=n_shards or 8)
    sh.topk(q, k)  # warm: builds the per-shard layouts + merge graphs
    t_sh, (ids_sh, d_sh) = timeit(lambda: sh.topk(q, k), repeat=3)
    assert np.array_equal(ids_ref, ids_sh) and np.array_equal(d_ref, d_sh), \
        "sharded topk diverged from the unsharded engine"
    summary["n_shards"] = sh.stats()["n_shards"]
    summary["qps_sharded"] = n_queries / t_sh
    summary["sharded_over_unsharded"] = t_sh / t_un
    emit("index.query_sharded", t_sh * 1e6 / n_queries,
         f"qps={n_queries / t_sh:.1f};shards={summary['n_shards']};"
         f"devices={len(devs)}")
    return summary


def bench_migration(n: int = 32768, d_new: int = 1024,
                    batch_rows: int = 4096, q_batch: int = 8,
                    k: int = 10) -> dict:
    """Lazy re-sketch migration throughput + mid-flight serving cost.

    Builds an N-row index at the base spec (keep_raw=True: migration needs
    the raw archive), then drives a manual migration to `d_new` and times
    the batch drain — `migration_rows_per_s` is the headline number the
    trajectory tracks.  A second engine is parked mid-migration (src, dst
    and fresh tiers all populated) to measure the cross-version topk QPS
    against the post-publish QPS on the same membership: the ratio is the
    price of querying DURING a migration instead of after it.
    """
    summary: dict = {"n": n, "d_new": d_new, "batch_rows": batch_rows}
    idx, val = _sparse_rows(n, seed=2)
    q_idx, q_val = idx[:q_batch], val[:q_batch]

    eng = _build(idx, val, keep_raw=True)
    eng.migrate(d=d_new, drive="manual", batch_rows=batch_rows)
    eng.migration_step()  # untimed: compiles the per-batch re-sketch graph
    t0 = time.perf_counter()
    while eng.migration_step():
        pass
    t_mig = time.perf_counter() - t0
    assert not eng.migrating and eng.d == d_new and len(eng) == n
    rows_timed = n - batch_rows
    summary["migration_rows_per_s"] = rows_timed / t_mig
    emit("index.migrate", t_mig * 1e6 / max(rows_timed, 1),
         f"{rows_timed / t_mig:.0f} rows/s;d={d_new}")

    # --- serving mid-flight vs post-publish -------------------------------
    eng2 = _build(idx, val, keep_raw=True)
    eng2.migrate(d=d_new, drive="manual", batch_rows=batch_rows)
    eng2.migration_step()
    eng2.add_sparse(idx[:4], val[:4])  # populate the fresh tier too
    eng2.topk((q_idx, q_val), k)  # warm the three-tier merge graphs
    h = eng2.obs.histogram("engine_query_latency_ms", op="topk")
    h.reset()
    t_mid, (ids, _) = timeit(lambda: eng2.topk((q_idx, q_val), k), repeat=3)
    assert ids.shape == (q_batch, k)
    summary["qps_mid_migration"] = q_batch / t_mid
    if h.count:
        summary["p50_ms_topk_mid_migration"] = h.quantile(50)
        summary["p99_ms_topk_mid_migration"] = h.quantile(99)
    emit("index.query_mid_migration", t_mid * 1e6 / q_batch,
         f"qps={q_batch / t_mid:.1f};k={k}")

    eng2.migrate_all()
    eng2.topk((q_idx, q_val), k)
    t_post, _ = timeit(lambda: eng2.topk((q_idx, q_val), k), repeat=3)
    summary["qps_post_migration"] = q_batch / t_post
    summary["mid_over_post_query_cost"] = t_mid / t_post
    emit("index.query_post_migration", t_post * 1e6 / q_batch,
         f"qps={q_batch / t_post:.1f};mid_cost_ratio={t_mid / t_post:.2f}")
    return summary


def bench_bulk_ingest(n_docs: int = 16384, n_shards: int = 8,
                      window: int = 512, mean_len: int = 96) -> dict:
    """Merge-tree bulk load (DESIGN.md section 14) vs one sequential
    ingest of the same documents.  Emits `ingest_rows_per_s_seq` and
    `ingest_rows_per_s_tree` (with the worker count) into the trajectory;
    the tree's aggregate-throughput target (>= 1M rows/s) is an
    accelerator-scale number — on the 1-core CPU container the recorded
    pair is the honest baseline the trajectory tracks, and the result is
    asserted bit-identical to the sequential build either way."""
    import itertools

    from repro.data.pipeline import synthetic_documents
    from repro.index import bulk_ingest, ingest_documents

    summary: dict = {}
    params = CabinParams.create(VOCAB, D, seed=0)
    docs = list(itertools.islice(
        synthetic_documents(VOCAB, seed=5, mean_len=mean_len), n_docs))
    bounds = np.linspace(0, n_docs, n_shards + 1).astype(int)
    shards = [docs[lo:hi] for lo, hi in zip(bounds[:-1], bounds[1:])]

    warm = QueryEngine(params, cache_entries=0)
    ingest_documents(warm, docs[:window], window=window)  # compile graphs

    seq = QueryEngine(params, cache_entries=0)
    t0 = time.perf_counter()
    ids_seq = ingest_documents(seq, docs, window=window)
    t_seq = time.perf_counter() - t0
    summary["ingest_rows_per_s_seq"] = n_docs / t_seq
    emit("index.bulk_seq", t_seq * 1e6 / n_docs,
         f"{n_docs / t_seq:.0f} rows/s;n={n_docs}")

    par = QueryEngine(params, cache_entries=0)
    t0 = time.perf_counter()
    ids_par = bulk_ingest(par, shards, workers=n_shards, window=window)
    t_tree = time.perf_counter() - t0
    summary["ingest_rows_per_s_tree"] = n_docs / t_tree
    summary["tree_workers"] = n_shards
    summary["tree_over_seq"] = t_seq / t_tree
    emit("index.bulk_tree", t_tree * 1e6 / n_docs,
         f"{n_docs / t_tree:.0f} rows/s;workers={n_shards};"
         f"speedup=x{t_seq / t_tree:.2f}")

    # the whole point: the parallel load is the sequential build, bit
    # for bit — ids, store contents, everything
    assert np.array_equal(ids_par, ids_seq)
    assert np.array_equal(np.asarray(par.store.sk_buf[:par.store.size]),
                          np.asarray(seq.store.sk_buf[:seq.store.size]))
    return summary
