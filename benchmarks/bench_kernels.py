"""Kernel-level benchmarks: packed-popcount vs naive dense distance math.

The Pallas kernels target TPU (validated in interpret mode by tests); what
can be MEASURED on this CPU container is the algorithmic win the packing
gives at the XLA level: a d-bit sketch distance costs d/32 int32 ops instead
of d byte ops, and Cham's all-pairs pass beats the full-dimension exact pass
by the paper's n/d factor.  TPU roofline numbers for the same ops come from
the dry-run (EXPERIMENTS.md section Roofline).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import dataset, emit, timeit
from repro.core import CabinParams
from repro.core.cabin import sketch_dense
from repro.core.cham import cham_matrix, hamming_matrix_exact
from repro.core.packing import pack_bits, unpack_bits


def kernel_packed_vs_unpacked(n_rows=512, d=1024):
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=(n_rows, d)).astype(np.int32)
    packed = pack_bits(jnp.asarray(bits))
    dense = jnp.asarray(bits)

    pair_packed = jax.jit(hamming_matrix_exact)
    pair_dense = jax.jit(
        lambda a: jnp.sum(a[:, None, :] != a[None, :, :], axis=-1))

    t_packed, _ = timeit(lambda: pair_packed(packed, packed), repeat=3)
    t_dense, _ = timeit(lambda: pair_dense(dense), repeat=3)
    emit("kernel.allpairs_packed", t_packed * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.allpairs_dense", t_dense * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.packing_speedup", t_packed * 1e6 / n_rows**2,
         f"{t_dense / t_packed:.2f}x")
    # byte footprint: 32x smaller sketches
    emit("kernel.bytes_ratio", 0.0,
         f"{dense.nbytes / packed.nbytes:.1f}x")
    return {"speedup": t_dense / t_packed}


def kernel_cham_vs_exact_fulldim(scale=0.008, n_rows=192, d=1024):
    """The 136x-heatmap-speedup analogue at CPU scale."""
    spec, x, _ = dataset("braincell", scale, n_rows, seed=1)
    cp = CabinParams.create(spec.n_dims, d, seed=0)
    xj = jnp.asarray(x)
    sk = sketch_dense(cp, xj)

    exact = jax.jit(lambda a: jnp.sum(a[:, None, :] != a[None, :, :], axis=-1))
    est = jax.jit(lambda s: cham_matrix(s, s, d))
    t_exact, _ = timeit(lambda: exact(xj), repeat=1)
    t_est, _ = timeit(lambda: est(sk), repeat=3)
    emit("kernel.cham_matrix", t_est * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.exact_fulldim", t_exact * 1e6 / n_rows**2,
         f"n={spec.n_dims}")
    emit("kernel.cham_speedup", t_est * 1e6 / n_rows**2,
         f"{t_exact / t_est:.1f}x")
    return {"speedup": t_exact / t_est}


def kernel_sketch_throughput(scale=0.05, n_rows=512, d=1024):
    spec, x, _ = dataset("pubmed", scale, n_rows, seed=2)
    cp = CabinParams.create(spec.n_dims, d, seed=0)
    from repro.core.cabin import sketch_dense_jit

    xj = jnp.asarray(x)
    t, _ = timeit(lambda: sketch_dense_jit(cp, xj), repeat=3)
    emit("kernel.cabin_sketch", t * 1e6 / n_rows,
         f"n={spec.n_dims};d={d}")
    return {"us_per_row": t * 1e6 / n_rows}
