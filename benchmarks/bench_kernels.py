"""Kernel-level benchmarks: packed-popcount vs naive dense distance math.

The Pallas kernels target TPU (validated in interpret mode by tests); what
can be MEASURED on this CPU container is the algorithmic win the packing
gives at the XLA level: a d-bit sketch distance costs d/32 int32 ops instead
of d byte ops, and Cham's all-pairs pass beats the full-dimension exact pass
by the paper's n/d factor.  TPU roofline numbers for the same ops come from
the dry-run (EXPERIMENTS.md section Roofline).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import dataset, emit, timeit
from repro.core import CabinParams
from repro.core.cabin import sketch_dense, sketch_sparse_jnp
from repro.core.cham import cham_matrix, hamming_matrix_exact
from repro.core.packing import pack_bits, unpack_bits


def kernel_packed_vs_unpacked(n_rows=512, d=1024):
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=(n_rows, d)).astype(np.int32)
    packed = pack_bits(jnp.asarray(bits))
    dense = jnp.asarray(bits)

    pair_packed = jax.jit(hamming_matrix_exact)
    pair_dense = jax.jit(
        lambda a: jnp.sum(a[:, None, :] != a[None, :, :], axis=-1))

    t_packed, _ = timeit(lambda: pair_packed(packed, packed), repeat=3)
    t_dense, _ = timeit(lambda: pair_dense(dense), repeat=3)
    emit("kernel.allpairs_packed", t_packed * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.allpairs_dense", t_dense * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.packing_speedup", t_packed * 1e6 / n_rows**2,
         f"{t_dense / t_packed:.2f}x")
    # byte footprint: 32x smaller sketches
    emit("kernel.bytes_ratio", 0.0,
         f"{dense.nbytes / packed.nbytes:.1f}x")
    return {"speedup": t_dense / t_packed}


def kernel_cham_vs_exact_fulldim(scale=0.008, n_rows=192, d=1024):
    """The 136x-heatmap-speedup analogue at CPU scale."""
    spec, x, _ = dataset("braincell", scale, n_rows, seed=1)
    cp = CabinParams.create(spec.n_dims, d, seed=0)
    xj = jnp.asarray(x)
    sk = sketch_dense(cp, xj)

    exact = jax.jit(lambda a: jnp.sum(a[:, None, :] != a[None, :, :], axis=-1))
    est = jax.jit(lambda s: cham_matrix(s, s, d))
    t_exact, _ = timeit(lambda: exact(xj), repeat=1)
    t_est, _ = timeit(lambda: est(sk), repeat=3)
    emit("kernel.cham_matrix", t_est * 1e6 / n_rows**2, f"d={d}")
    emit("kernel.exact_fulldim", t_exact * 1e6 / n_rows**2,
         f"n={spec.n_dims}")
    emit("kernel.cham_speedup", t_est * 1e6 / n_rows**2,
         f"{t_exact / t_est:.1f}x")
    return {"speedup": t_exact / t_est}


def kernel_sketch_throughput(scale=0.05, n_rows=512, d=1024):
    spec, x, _ = dataset("pubmed", scale, n_rows, seed=2)
    cp = CabinParams.create(spec.n_dims, d, seed=0)
    from repro.core.cabin import sketch_dense_jit

    xj = jnp.asarray(x)
    t, _ = timeit(lambda: sketch_dense_jit(cp, xj), repeat=3)
    emit("kernel.cabin_sketch", t * 1e6 / n_rows,
         f"n={spec.n_dims};d={d}")
    return {"us_per_row": t * 1e6 / n_rows}


def bench_sparse_sketch(n_rows=1024, n_dims=1 << 20, nnz=200, d=1024):
    """Sparse-Cabin path at Table-1 dimensionality (n ~ 1M).

    The padded-COO path is the only one that can even RUN here — a dense
    (n_rows, 1M) matrix would be 4 GB — so the comparison point is the dense
    path at the largest n that fits comfortably (16k), scaled per dimension.
    On TPU the fused cabin_build_sparse kernel replaces the scatter; what is
    measurable on CPU is the layout win itself: cost O(N*m) vs O(N*n).
    """
    rng = np.random.default_rng(0)
    idx = np.zeros((n_rows, nnz), np.int32)
    val = np.zeros((n_rows, nnz), np.int32)
    for i in range(n_rows):
        idx[i] = rng.choice(n_dims, size=nnz, replace=False)
        val[i] = rng.integers(1, 15, size=nnz)
    cp = CabinParams.create(n_dims, d, seed=0)
    sparse_jit = jax.jit(sketch_sparse_jnp, static_argnums=0)
    idx_j, val_j = jnp.asarray(idx), jnp.asarray(val)
    t_sparse, _ = timeit(lambda: sparse_jit(cp, idx_j, val_j), repeat=3)
    emit("kernel.sparse_sketch", t_sparse * 1e6 / n_rows,
         f"n={n_dims};nnz={nnz};d={d}")

    n_small = 1 << 14
    cp_s = CabinParams.create(n_small, d, seed=0)
    dense = np.zeros((n_rows, n_small), np.int32)
    dense[np.arange(n_rows)[:, None], idx % n_small] = val
    dense_jit = jax.jit(sketch_dense, static_argnums=0)
    xj = jnp.asarray(dense)
    t_dense, _ = timeit(lambda: dense_jit(cp_s, xj), repeat=3)
    emit("kernel.dense_sketch_16k", t_dense * 1e6 / n_rows, f"n={n_small}")
    # per-dimension cost ratio: how much the COO layout saves at 1M dims
    per_dim_ratio = (t_dense / n_small) / (t_sparse / n_dims)
    emit("kernel.sparse_layout_advantage", t_sparse * 1e6 / n_rows,
         f"{per_dim_ratio:.0f}x_per_dim")
    return {"us_per_row_sparse": t_sparse * 1e6 / n_rows,
            "us_per_row_dense_16k": t_dense * 1e6 / n_rows,
            "per_dim_advantage": per_dim_ratio}
