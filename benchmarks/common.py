"""Shared benchmark utilities: timing, dataset prep, method registry.

Benchmarks reproduce the paper's tables/figures on synthetic twins of the
Table-1 datasets (scaled for the 1-core CPU container; scaling keeps
sparsity structure — see DESIGN.md section 7).  Output convention:
``name,us_per_call,derived`` CSV rows via `emit`.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CabinParams
from repro.core.baselines import (BaselineParams, bcs_estimate, bcs_sketch,
                                  fh_estimate, fh_sketch, hlsh_estimate,
                                  hlsh_sketch, simhash_estimate,
                                  simhash_sketch)
from repro.core.cabin import binem, binsketch, sketch_dense
from repro.core.cham import cham_matrix
from repro.core.packing import pack_bits
from repro.data.synthetic import TABLE1, sample_dense, scaled_spec

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, *args, repeat: int = 3, **kwargs) -> tuple[float, object]:
    """Returns (seconds_per_call, last_result); blocks jax arrays."""
    out = None
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            or isinstance(out, jnp.ndarray) else out
        best = min(best, time.perf_counter() - t0)
    return best, out


def dataset(name: str, scale: float, n_rows: int, seed: int = 0,
            clusters: int = 0):
    spec = scaled_spec(TABLE1[name], scale)
    x, labels = sample_dense(spec, n_rows, seed=seed, cluster_centers=clusters)
    return spec, x, labels


def exact_hd_matrix(x: np.ndarray) -> np.ndarray:
    n = x.shape[0]
    out = np.empty((n, n), dtype=np.int32)
    for i in range(0, n, 256):
        out[i:i + 256] = (x[i:i + 256, None, :] != x[None, :, :]).sum(-1)
    return out


# ---------------------------------------------------------------------------
# method registry: name -> estimate_matrix_fn
# every method consumes the categorical matrix and produces an (N, N)
# estimated-HD matrix from its own sketches, exactly like the paper's RMSE
# protocol: baselines run on the BinEm embedding (Table 2 note) and get the
# SAME 2x Lemma-2 unbiasing that Cham applies (HD(u,v) = 2 E[HD(u',v')]),
# so all methods estimate the ORIGINAL categorical Hamming distance.
# All estimators are jitted so the speed comparison is apples-to-apples.
# ---------------------------------------------------------------------------


def make_methods(n_dims: int, d: int, seed: int = 0, jit: bool = True):
    """jit=False keeps estimators eager — used by the variance benchmark
    which re-seeds every trial (64 recompiles would dominate otherwise)."""
    import jax as _jax

    cp = CabinParams.create(n_dims, d, seed=seed)
    bp = BaselineParams(n_dims, d, seed)
    wrap = _jax.jit if jit else (lambda f: f)

    _cabin = wrap(lambda xj: cham_matrix(sketch_dense(cp, xj),
                                         sketch_dense(cp, xj), d))

    def cabin(x):
        return np.asarray(_cabin(jnp.asarray(x)))

    def with_binem(fn):
        jf = wrap(lambda xj: 2.0 * fn(binem(cp, xj)))

        def inner(x):
            return np.asarray(jf(jnp.asarray(x)))
        return inner

    def bcs(u):
        y = bcs_sketch(bp, u)
        return bcs_estimate(bp, y[:, None, :], y[None, :, :])

    def hlsh(u):
        y = hlsh_sketch(bp, u)
        return hlsh_estimate(bp, y[:, None, :], y[None, :, :])

    def fh(u):
        y = fh_sketch(bp, u)
        w = jnp.sum(u, axis=-1).astype(jnp.float32)
        return fh_estimate(bp, y[:, None, :], y[None, :, :],
                           w[:, None], w[None, :])

    def sh(u):
        y = simhash_sketch(bp, u)
        w = jnp.sum(u, axis=-1).astype(jnp.float32)
        return simhash_estimate(bp, y[:, None, :], y[None, :, :],
                                w[:, None], w[None, :])

    return {
        "cabin": cabin,
        "bcs": with_binem(bcs),
        "hlsh": with_binem(hlsh),
        "fh": with_binem(fh),
        "sh": with_binem(sh),
    }


def rmse(est: np.ndarray, true: np.ndarray) -> float:
    iu = np.triu_indices(true.shape[0], 1)
    err = est[iu].astype(np.float64) - true[iu]
    return float(np.sqrt((err**2).mean()))


def mae(est: np.ndarray, true: np.ndarray) -> float:
    iu = np.triu_indices(true.shape[0], 1)
    return float(np.abs(est[iu].astype(np.float64) - true[iu]).mean())
