from repro.kernels.topk_select.kernel import topk_select as topk_select_kernel  # noqa: F401
from repro.kernels.topk_select.ops import topk_select  # noqa: F401
from repro.kernels.topk_select.ref import topk_select_ref  # noqa: F401
