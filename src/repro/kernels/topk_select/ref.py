"""Pure-jnp oracle for the fused topk_select kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.cham import binhamming_from_stats
from repro.core.packing import popcount32


def topk_select_ref(q: jnp.ndarray, b: jnp.ndarray, k: int, *, d: int,
                    metric: str = "cham", m_valid: int | None = None):
    """(values (Q, k) f32, indices (Q, k) int32), ascending by (value,
    column) — the dense-matrix + stable-argsort twin of the kernel's
    running compare-exchange merge.  Requires k <= m_valid (the kernel's
    contract) for every slot to name a real column."""
    wa = jnp.sum(popcount32(q), axis=-1)
    wb = jnp.sum(popcount32(b), axis=-1)
    inner = jnp.sum(popcount32(q[:, None, :] & b[None, :, :]), axis=-1)
    if metric == "cham":
        dist = 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)
    elif metric == "hamming":
        dist = (wa[:, None] + wb[None, :] - 2 * inner).astype(jnp.float32)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    if m_valid is not None:
        col = jnp.arange(b.shape[0], dtype=jnp.int32)[None, :]
        dist = jnp.where(col < m_valid, dist, jnp.inf)
    order = jnp.argsort(dist, axis=1)[:, :k]  # stable: ties -> lower column
    vals = jnp.take_along_axis(dist, order, axis=1)
    return vals, order.astype(jnp.int32)
