"""Pallas TPU kernel: fused distance + running top-k select on packed rows.

The serving hot path (QueryEngine.topk -> core.allpairs.topk_rows) streams
store tiles past a query block and keeps the k best columns per query.  Run
as separate passes — a pair-stats kernel producing an f32 distance tile in
HBM, then a host/XLA select — every losing column (all but ~k of N) pays an
HBM round-trip for a value that is immediately discarded.  This kernel fuses
the two: the SWAR-popcount distance tile and the running k-best merge happen
in one VMEM pass, so the only HBM writes are the (Q, k) results.

VMEM carry layout: the (BQ, k) values and indices OUTPUT tiles double as the
carry — their index_map pins them to (i, 0) for every column step j, so with
the column grid innermost they stay resident in VMEM across the whole sweep
(same revisiting discipline as the hamming kernel's accumulator) and are
flushed to HBM once per query tile.  Both live as full (value, index)-sorted
rows; k is kept at its logical size (the store is sub-lane-width — Mosaic
pads the trailing dim internally), so carry VMEM is 8·BQ·k bytes on top of
the (BQ, W) + (BN, W) int32 input tiles.

Merge: per tile, k compare-exchange rounds against the tile minimum.  Each
round extracts the tile's lexicographic (distance, column) minimum — ties
resolve to the LOWER column via an iota-masked second min — knocks it out of
the tile, and inserts it into the sorted carry with a vectorised
compare-exchange shift (count strictly-smaller carry entries, shift the tail
right by one, place).  Equal-distance insertions land AFTER existing carry
entries, whose columns are always lower (earlier tiles), so the carry is the
exact (distance, column)-lexicographic k-best — bit-identical to
core.allpairs._topk_rows_impl's stable merge, which tests pin.

Grid: (Q/BQ, N/BN) with the column dimension innermost; `m` (the traced
valid-column count) rides in as a (1, 1) tile broadcast to every program so
varying the live store size never recompiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cham import binhamming_from_stats
from repro.core.packing import pad_to_multiple, popcount32


def _tile_distances(qt, bt, metric: str, d: int) -> jnp.ndarray:
    """(BQ, W) x (BN, W) packed -> (BQ, BN) f32, same formulas (and same
    elementwise ops) as core.allpairs._tile_dist on the popcount backend."""
    wa = jnp.sum(popcount32(qt), axis=-1)
    wb = jnp.sum(popcount32(bt), axis=-1)
    inner = jnp.sum(popcount32(qt[:, None, :] & bt[None, :, :]), axis=-1)
    if metric == "cham":
        return 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)
    if metric == "hamming":
        return (wa[:, None] + wb[None, :] - 2 * inner).astype(jnp.float32)
    raise ValueError(f"unknown metric {metric!r}")


def _topk_select_kernel(q_ref, b_ref, m_ref, vals_ref, idxs_ref, *,
                        k, bn, metric, d):
    """One (BQ, BN) column step of the running (BQ, k) select."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        idxs_ref[...] = jnp.full_like(idxs_ref, -1)

    dist = _tile_distances(q_ref[...], b_ref[...], metric, d)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(col < m_ref[0, 0], dist, jnp.inf)

    vals = vals_ref[...]  # (BQ, k) ascending by (value, index)
    idxs = idxs_ref[...]
    kiota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    big = jnp.int32(2**31 - 1)
    for _ in range(k):
        # lexicographic (value, column) tile minimum
        tmin = jnp.min(dist, axis=1)
        tidx = jnp.min(jnp.where(dist == tmin[:, None], col, big), axis=1)
        dist = jnp.where(col == tidx[:, None], jnp.inf, dist)
        # compare-exchange insertion: strictly-smaller carry entries stay,
        # the tail shifts right one slot, the extracted pair drops in.  An
        # insertion past the end (pos == k) leaves the carry untouched —
        # masked +inf extractions can never evict the (+inf, -1) fillers,
        # whose index -1 ranks them below every real column.
        smaller = (vals < tmin[:, None]) | (
            (vals == tmin[:, None]) & (idxs < tidx[:, None]))
        pos = jnp.sum(smaller.astype(jnp.int32), axis=1)
        shift_v = jnp.concatenate([vals[:, :1], vals[:, :-1]], axis=1)
        shift_i = jnp.concatenate([idxs[:, :1], idxs[:, :-1]], axis=1)
        keep = kiota < pos[:, None]
        here = kiota == pos[:, None]
        vals = jnp.where(keep, vals, jnp.where(here, tmin[:, None], shift_v))
        idxs = jnp.where(keep, idxs, jnp.where(here, tidx[:, None], shift_i))
    vals_ref[...] = vals
    idxs_ref[...] = idxs


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "d", "bq", "bn", "interpret"))
def topk_select(
    q: jnp.ndarray,
    b: jnp.ndarray,
    m,
    k: int,
    *,
    metric: str = "cham",
    d: int,
    bq: int = 128,
    bn: int = 1024,
    interpret: bool = False,
):
    """Fused k-nearest-columns: q (Q, W) x b (N, W) packed int32 ->
    (values (Q, k) f32, indices (Q, k) int32), ascending by (value, index).

    `m` is the TRACED count of valid leading rows of b (columns past it are
    masked to +inf); `k` must satisfy 1 <= k <= m for every result slot to
    be a real column (the ops wrapper validates).
    """
    assert q.ndim == 2 and b.ndim == 2 and q.shape[1] == b.shape[1]
    nq, w = q.shape
    bq_, bn_ = min(bq, nq), min(bn, b.shape[0])
    q_p = pad_to_multiple(q, bq_, 0)
    b_p = pad_to_multiple(b, bn_, 0)
    grid = (q_p.shape[0] // bq_, b_p.shape[0] // bn_)
    m_arr = jnp.asarray(m, jnp.int32).reshape(1, 1)

    vals, idxs = pl.pallas_call(
        functools.partial(_topk_select_kernel, k=k, bn=bn_, metric=metric,
                          d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq_, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq_, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_p.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((q_p.shape[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(q_p, b_p, m_arr)
    return vals[:nq], idxs[:nq]
