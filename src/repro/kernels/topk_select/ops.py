"""Jit'd public wrapper around the fused topk_select kernel.

Mirrors repro.kernels.hamming.ops: `use_pallas=None` auto-selects the
compiled kernel on real TPU and the jnp reference elsewhere (the interpreter
is for correctness tests, not production CPU use).  core.allpairs.topk_rows
routes its "pallas" mode here, so on TPU the serving top-k never writes a
distance tile to HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_select import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def topk_select(q, b, k: int, *, d: int, metric: str = "cham",
                m_valid: int | None = None, bq: int = 128, bn: int = 1024,
                use_pallas: bool | None = None,
                interpret: bool | None = None):
    """k nearest columns of b per row of q: (values (Q, k), indices (Q, k)),
    ascending by (distance, column) — bit-identical tie-break to
    core.allpairs.topk_rows.  `m_valid` masks padded trailing rows of b and
    is traced (varying it does not recompile); k is clamped to it so every
    result slot names a real column."""
    q = jnp.asarray(q)
    b = jnp.asarray(b)
    m = b.shape[0] if m_valid is None else m_valid
    if not 0 <= m <= b.shape[0]:
        raise ValueError(f"m_valid={m} outside the {b.shape[0]} supplied "
                         "rows")
    k = min(k, m)
    if k == 0 or q.shape[0] == 0:
        return (jnp.zeros((q.shape[0], 0), jnp.float32),
                jnp.zeros((q.shape[0], 0), jnp.int32))
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.topk_select(
            q, b, m, k, metric=metric, d=d, bq=bq, bn=bn,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
    return ref.topk_select_ref(q, b, k, d=d, metric=metric, m_valid=m)
