from repro.kernels.cabin_build_sparse.kernel import cabin_build_sparse  # noqa: F401
from repro.kernels.cabin_build_sparse.ops import cabin_sketch_sparse  # noqa: F401
from repro.kernels.cabin_build_sparse.ref import cabin_build_sparse_ref  # noqa: F401
