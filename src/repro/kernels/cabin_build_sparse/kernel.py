"""Pallas TPU kernel: fused Cabin sketch construction on padded-COO rows.

This is the sparse twin of repro.kernels.cabin_build — the path that matters
for the paper's Table-1 datasets, where n runs to millions of dimensions but
each row carries only a few hundred nonzeros.  The dense kernel's contraction
runs over ALL n attribute columns; here it runs over the m <= few-hundred
padded-COO slots, so the kernel is O(N * m * d) instead of O(N * n * d) with
the same output.

Derivation (DESIGN.md section 2 applied to the COO layout): the dense kernel
exploits that pi(j) is shared by every row in a column slab, turning the
OR-aggregation into one (BK, BD) one-hot matmul on the MXU.  In COO layout
the attribute index — and therefore the bucket — varies PER ELEMENT, so no
shared one-hot matrix exists.  We instead evaluate the OR-aggregation as a
VPU compare-reduce over a (BM, BK, BD) broadcast:

    hit[i, t] = OR_k ( psi(idx[i,k], val[i,k]) AND pi(idx[i,k]) == t )
    acc[i, t] += sum_k bits[i, k] * (local_bucket[i, k] == t)

with psi and pi evaluated INSIDE the kernel by the same stateless mixers as
repro.core.hashing (no tables, no gathers, no scatter/atomics).  Padding
slots carry value 0 and psi(., 0) = 0 by construction, so they contribute
nothing even though they alias attribute index 0.

Grid: (N/BM, d/BD, m/BK), contraction innermost; an int32 (BM, BD)
collision-count accumulator lives in VMEM scratch and is packed to int32
words (BD/32 per block) on the last k step — identical packing (LSB-first,
bit j -> word j//32) to the dense kernel and repro.core.packing.

Alignment contract (shared with cabin_build): d % BD == 0 and BD % 128 == 0;
callers round the sketch dimension up to a multiple of 128 (the theory gives
a MINIMUM d, so rounding up only tightens the estimate).  ops.py falls back
to the jnp reference path for unaligned d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing


def _cabin_sparse_kernel(idx_ref, val_ref, out_ref, acc_ref, *, psi_seed,
                         pi_seed, d, bd, k_steps):
    dblk = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[...]  # (BM, BK) int32 attribute positions
    val = val_ref[...]  # (BM, BK) int32 categories, 0 = padding
    # Stage 1 (BinEm): psi(idx, val) in {0,1}; psi(., 0) == 0 masks padding.
    bits = hashing.psi_bits(idx.astype(jnp.uint32), val, psi_seed)  # (BM, BK)
    # Stage 2 (BinSketch): per-ELEMENT buckets, restricted to this d-block.
    buckets = hashing.pi_buckets(idx.astype(jnp.uint32), d, pi_seed)
    local = buckets - dblk * bd  # (BM, BK)
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bd), 2)
    # (BM, BK, BD) compare-reduce: no shared one-hot exists in COO layout.
    hit = (local[:, :, None] == t_iota) & (bits[:, :, None] > 0)
    acc_ref[...] += jnp.sum(hit.astype(jnp.int32), axis=1)

    @pl.when(k == k_steps - 1)
    def _finalize():
        hit_bits = (acc_ref[...] > 0).astype(jnp.uint32)  # (BM, BD)
        bm = hit_bits.shape[0]
        lanes = hit_bits.reshape(bm, bd // 32, 32)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        out_ref[...] = jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32
                               ).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("d", "psi_seed", "pi_seed", "bm", "bd", "bk",
                              "interpret")
)
def cabin_build_sparse(
    indices: jnp.ndarray,
    values: jnp.ndarray,
    *,
    d: int,
    psi_seed: int,
    pi_seed: int,
    bm: int = 8,
    bd: int = 512,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused Cabin on padded-COO rows: (N, m) x2 int32 -> (N, d/32) int32.

    indices[i, k] is the attribute position of slot k of row i; values[i, k]
    its category, with 0 meaning padding/missing.  Requires d % 128 == 0
    (see module docstring).
    """
    if indices.shape != values.shape or indices.ndim != 2:
        raise ValueError("indices/values must be identically-shaped (N, m)")
    n_rows, m = indices.shape
    if d % 128:
        raise ValueError("cabin_build_sparse kernel requires d % 128 == 0")
    bd_ = min(bd, d)
    while d % bd_:
        bd_ //= 2
    bd_ = max(bd_, 128)
    bm_ = min(bm, max(1, n_rows))
    bk_ = min(bk, m)

    pad_rows = (-n_rows) % bm_
    pad_cols = (-m) % bk_
    # zero padding is safe: value 0 => psi bit 0 => no contribution
    idx_p = jnp.pad(indices, ((0, pad_rows), (0, pad_cols)))
    val_p = jnp.pad(values, ((0, pad_rows), (0, pad_cols)))
    mp, m_p = idx_p.shape
    k_steps = m_p // bk_
    grid = (mp // bm_, d // bd_, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _cabin_sparse_kernel,
            psi_seed=psi_seed,
            pi_seed=pi_seed,
            d=d,
            bd=bd_,
            k_steps=k_steps,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, t, k: (i, k)),
            pl.BlockSpec((bm_, bk_), lambda i, t, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((bm_, bd_ // 32), lambda i, t, k: (i, t)),
        out_shape=jax.ShapeDtypeStruct((mp, d // 32), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bd_), jnp.int32)],
        interpret=interpret,
    )(idx_p, val_p)
    return out[:n_rows]
