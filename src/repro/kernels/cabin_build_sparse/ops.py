"""Jit'd wrapper for fused sparse-Cabin sketch construction.

Mirrors repro.kernels.cabin_build.ops: `use_pallas=None` auto-selects the
compiled kernel on TPU for 128-aligned sketch dims, the jnp scatter-max
reference otherwise; tests run the kernel with interpret=True on CPU.
"""

from __future__ import annotations

import jax

from repro.core.cabin import CabinParams, sketch_sparse_jnp
from repro.kernels.cabin_build_sparse import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cabin_sketch_sparse(params: CabinParams, indices, values, *,
                        use_pallas: bool | None = None,
                        interpret: bool | None = None):
    """Cabin sketches for padded-COO rows (N, m) x2 -> packed (N, w).

    Uses the fused Pallas kernel when the sketch dim is 128-aligned (TPU) or
    when explicitly requested (tests run it with interpret=True); otherwise
    the jnp reference path.  Output is bit-identical either way.
    """
    if use_pallas is None:
        use_pallas = _on_tpu() and params.sketch_dim % 128 == 0
    if use_pallas and params.sketch_dim % 128 == 0:
        return kernel.cabin_build_sparse(
            indices,
            values,
            d=params.sketch_dim,
            psi_seed=params.psi_seed,
            pi_seed=params.pi_seed,
            interpret=bool(interpret if interpret is not None else not _on_tpu()),
        )
    return sketch_sparse_jnp(params, indices, values)
