"""Pure-jnp oracle for the cabin_build_sparse kernel: the core-library
scatter-max Cabin path on padded-COO rows."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.cabin import CabinParams, sketch_sparse_jnp


def cabin_build_sparse_ref(
    indices: jnp.ndarray, values: jnp.ndarray, *, n_dims: int, d: int,
    psi_seed: int, pi_seed: int,
) -> jnp.ndarray:
    params = CabinParams(n_dims=n_dims, sketch_dim=d,
                         psi_seed=psi_seed, pi_seed=pi_seed)
    return sketch_sparse_jnp(params, indices, values)
