"""Jit'd attention dispatcher: pallas flash (TPU) / chunked-lax / reference.

`chunked_attention` is the XLA-level flash algorithm (lax.scan over KV
blocks with online softmax).  It is the default off-TPU and for dry-run
lowering: it never materialises the (S, S) score matrix, so 32k-token
prefill fits in HBM without the Mosaic kernel (same asymptotic flops, so the
roofline analysis is representative of the TPU kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block"))
def chunked_attention(q, k, v, *, causal: bool = True, block: int = 1024):
    """Online-softmax attention scanning KV in blocks. Shapes as ref."""
    b, hq, s, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dh_v = v.shape[-1]  # may differ from dh (MLA)
    group = hq // hkv
    blk = min(block, skv)
    while skv % blk:
        blk //= 2
    steps = skv // blk
    scale = 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32)
    k_blocks = k.astype(jnp.float32).reshape(b, hkv, steps, blk, dh)
    v_blocks = v.astype(jnp.float32).reshape(b, hkv, steps, blk, dh_v)
    k_blocks = jnp.moveaxis(k_blocks, 2, 0)  # (steps, b, hkv, blk, dh)
    v_blocks = jnp.moveaxis(v_blocks, 2, 0)

    q_pos = jnp.arange(s)

    def body(carry, xs):
        acc, m, l = carry
        kb, vb, j = xs
        kb = jnp.repeat(kb, group, axis=1)  # (b, hq, blk, dh)
        vb = jnp.repeat(vb, group, axis=1)
        sres = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale
        if causal:
            kpos = j * blk + jnp.arange(blk)
            mask = q_pos[:, None] >= kpos[None, :]
            sres = jnp.where(mask, sres, -1e30)
        m_cur = sres.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(sres - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hq, s, dh_v), jnp.float32)
    m0 = jnp.full((b, hq, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, s, 1), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (k_blocks, v_blocks, jnp.arange(steps)),
    )
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, impl: str | None = None,
              interpret: bool | None = None):
    """Dispatch: impl in {None(auto), 'pallas', 'chunked', 'ref'}."""
    if impl is None:
        impl = "pallas" if _on_tpu() else "chunked"
    if impl == "pallas":
        return kernel.flash_attention(
            q, k, v, causal=causal,
            interpret=bool(interpret if interpret is not None else not _on_tpu()),
        )
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal)
    return ref.attention_ref(q, k, v, causal=causal)
