"""Pure-jnp oracle for flash attention (materialised-scores softmax)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q (B,Hq,S,Dh), k/v (B,Hkv,Skv,Dh) -> (B,Hq,S,Dh); f32 math."""
    b, hq, s, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, kr, vr))
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / (dh ** 0.5)
    if causal:
        q_pos = jnp.arange(s)[:, None]
        k_pos = jnp.arange(skv)[None, :]
        scores = jnp.where(q_pos >= k_pos, scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
