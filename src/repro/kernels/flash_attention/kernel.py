"""Pallas TPU kernel: causal flash attention (online softmax), GQA-aware.

The LM stack's perf-critical hot spot.  Standard two-pass-free flash
algorithm: for each (batch, q-head, q-block) the kernel streams KV blocks,
maintaining running max m, normaliser l and output accumulator in VMEM
scratch (all f32), rescaling on the fly.

GQA without materialising repeated KV: the kv BlockSpec index_map divides the
q-head grid index by the group size, so K/V tiles are fetched from the shared
kv head directly (no repeat in HBM).

Causal masking: KV blocks entirely above the diagonal are skipped via
pl.when (they still occupy grid steps but do no flops/стores); the diagonal
block is masked with iota comparisons.

Block sizes default to (BQ=256, BK=256) with Dh <= 256:
  VMEM: q (256, Dh) f32-ish + k/v (256, Dh) + acc (256, Dh) f32 + s (256, 256)
  f32 ~= 1.3 MiB at Dh=128 — comfortable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, bq, bk, kv_steps):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal: query block [qi*bq, qi*bq+bq) attends kv block [ki*bk, ...+bk)
    # only if ki*bk <= qi*bq + bq - 1.
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, Dh)
    k: jnp.ndarray,  # (B, Hkv, Skv, Dh)
    v: jnp.ndarray,  # (B, Hkv, Skv, Dh)
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, s, dh = q.shape
    _, hkv, skv, _ = k.shape
    dh_v = v.shape[-1]  # may differ from dh (MLA value dim)
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    bq_ = min(bq, s)
    bk_ = min(bk, skv)
    assert s % bq_ == 0 and skv % bk_ == 0, "seq must divide block size"
    kv_steps = skv // bk_
    scale = 1.0 / (dh ** 0.5)

    grid = (b, hq, s // bq_, kv_steps)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, bq=bq_, bk=bk_,
            kv_steps=kv_steps,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, dh), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, dh),
                         lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk_, dh_v),
                         lambda bi, h, i, j, g=group: (bi, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, dh_v),
                               lambda bi, h, i, j: (bi, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, dh_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, dh_v), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
