"""Pure-jnp oracle for the cabin_build kernel: the core-library Cabin path."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.cabin import CabinParams, sketch_dense


def cabin_build_ref(x: jnp.ndarray, *, d: int, psi_seed: int, pi_seed: int
                    ) -> jnp.ndarray:
    params = CabinParams(n_dims=x.shape[-1], sketch_dim=d,
                         psi_seed=psi_seed, pi_seed=pi_seed)
    return sketch_dense(params, x)
