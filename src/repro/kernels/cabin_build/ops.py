"""Jit'd wrapper for fused Cabin sketch construction."""

from __future__ import annotations

import jax

from repro.core.cabin import CabinParams
from repro.kernels.cabin_build import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cabin_sketch(params: CabinParams, x, *, use_pallas: bool | None = None,
                 interpret: bool | None = None):
    """Cabin sketches for dense categorical rows (N, n) -> packed (N, w).

    Uses the fused Pallas kernel when the sketch dim is 128-aligned (TPU) or
    when explicitly requested (tests run it with interpret=True); otherwise
    the jnp reference path.
    """
    if use_pallas is None:
        use_pallas = _on_tpu() and params.sketch_dim % 128 == 0
    if use_pallas and params.sketch_dim % 128 == 0:
        return kernel.cabin_build(
            x,
            d=params.sketch_dim,
            psi_seed=params.psi_seed,
            pi_seed=params.pi_seed,
            interpret=bool(interpret if interpret is not None else not _on_tpu()),
        )
    return ref.cabin_build_ref(
        x, d=params.sketch_dim, psi_seed=params.psi_seed, pi_seed=params.pi_seed
    )
