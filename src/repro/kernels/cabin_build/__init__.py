"""cabin_build kernel package."""
from repro.kernels.cabin_build import kernel, ops, ref  # noqa: F401
