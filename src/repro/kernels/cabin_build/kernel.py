"""Pallas TPU kernel: fused Cabin sketch construction (BinEm + BinSketch).

A GPU port of the paper's algorithm would scatter bits through global memory
atomics.  TPUs have no scatter/atomics in the kernel programming model, so we
re-derive the OR-aggregation as MXU work (DESIGN.md section 2):

    out[i, t] = OR_j ( psi(j, x[i,j]) AND pi(j) == t )
              = ( sum_j bits[i, j] * onehot[j, t] ) > 0

i.e. a {0,1} matmul against an on-the-fly one-hot bucket matrix followed by a
`> 0`.  Both psi (category mapping) and pi (attribute mapping) are evaluated
INSIDE the kernel with the same stateless mixers as repro.core.hashing, so
the kernel reads the raw categorical tile from HBM exactly once and never
materialises the n-dimensional binary intermediate u'.

Grid: (N/BM, d/BD, n/BK) with the contraction (k over attribute slabs)
innermost; a (BM, BD) f32 collision-count accumulator lives in VMEM scratch
and is packed to int32 words (BD/32 per block) on the last k step.

Alignment contract: d % BD == 0 and BD % 128 == 0 (callers round the sketch
dimension up to a multiple of 128 — the theory gives a MINIMUM d, so rounding
up only tightens the estimate; ops.py falls back to the jnp reference path
for unaligned d).  The same d % 128 contract is shared by the padded-COO
twin, repro.kernels.cabin_build_sparse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing


def _cabin_kernel(x_ref, out_ref, acc_ref, *, psi_seed, pi_seed, d, bk, bd,
                  n_total, k_steps):
    i = pl.program_id(0)  # noqa: F841  (row block — implicit via BlockSpec)
    dblk = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (BM, BK) int32 categorical slab
    j_global = (k * bk + jax.lax.broadcasted_iota(jnp.int32, (x.shape[1],), 0)
                ).astype(jnp.uint32)
    # Stage 1 (BinEm): psi(j, x) in {0,1}; padding columns (j >= n) carry
    # x == 0 and thus bit == 0, contributing nothing.
    bits = hashing.psi_bits(j_global[None, :], x, psi_seed)  # (BM, BK)
    # Stage 2 (BinSketch): pi(j) buckets; restrict to this d-block.
    buckets = hashing.pi_buckets(j_global, d, pi_seed)  # (BK,)
    local = buckets - dblk * bd
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (x.shape[1], bd), 1)
    onehot = (local[:, None] == t_iota).astype(jnp.float32)  # (BK, BD)
    acc_ref[...] += jnp.dot(
        bits.astype(jnp.float32), onehot, preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _finalize():
        hit = (acc_ref[...] > 0.0).astype(jnp.uint32)  # (BM, BD)
        bm = hit.shape[0]
        lanes = hit.reshape(bm, bd // 32, 32)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        out_ref[...] = jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32
                               ).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("d", "psi_seed", "pi_seed", "bm", "bd", "bk",
                              "interpret")
)
def cabin_build(
    x: jnp.ndarray,
    *,
    d: int,
    psi_seed: int,
    pi_seed: int,
    bm: int = 128,
    bd: int = 2048,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused Cabin on dense categorical rows: (N, n) int32 -> (N, d/32) int32.

    Requires d % 128 == 0 (see module docstring).
    """
    n_rows, n = x.shape
    if d % 128:
        raise ValueError("cabin_build kernel requires d % 128 == 0")
    bd_ = min(bd, d)
    while d % bd_:
        bd_ //= 2
    bd_ = max(bd_, 128)
    bm_ = min(bm, max(8, n_rows))
    bk_ = min(bk, n)

    pad_rows = (-n_rows) % bm_
    pad_cols = (-n) % bk_
    x_p = jnp.pad(x, ((0, pad_rows), (0, pad_cols)))
    mp, np_ = x_p.shape
    k_steps = np_ // bk_
    grid = (mp // bm_, d // bd_, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _cabin_kernel,
            psi_seed=psi_seed,
            pi_seed=pi_seed,
            d=d,
            bk=bk_,
            bd=bd_,
            n_total=n,
            k_steps=k_steps,
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm_, bk_), lambda i, t, k: (i, k))],
        out_specs=pl.BlockSpec((bm_, bd_ // 32), lambda i, t, k: (i, t)),
        out_shape=jax.ShapeDtypeStruct((mp, d // 32), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bd_), jnp.float32)],
        interpret=interpret,
    )(x_p)
    return out[:n_rows]
