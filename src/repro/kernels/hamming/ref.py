"""Pure-jnp oracle for the hamming/pair_stats kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import popcount32


def pair_stats_ref(a: jnp.ndarray, b: jnp.ndarray):
    """a: (M, W) int32, b: (N, W) int32 -> (inner (M,N), hamming (M,N))."""
    a3 = a[:, None, :]
    b3 = b[None, :, :]
    inner = jnp.sum(popcount32(a3 & b3), axis=-1, dtype=jnp.int32)
    ham = jnp.sum(popcount32(a3 ^ b3), axis=-1, dtype=jnp.int32)
    return inner, ham


def row_popcount_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(popcount32(x), axis=-1, dtype=jnp.int32)
