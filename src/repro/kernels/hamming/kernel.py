"""Pallas TPU kernel: all-pairs popcount statistics on packed binary sketches.

The Cham hot loop (heatmaps, RMSE, k-mode assignment, dedup) is an all-pairs
reduction over packed int32 sketch words:

    inner[i, j]   = sum_w popcount(a[i, w] & b[j, w])
    hamming[i, j] = sum_w popcount(a[i, w] ^ b[j, w])

TPU adaptation (vs. the paper's CPU bitops / a CUDA warp-popcount port):
  * there is no popcount unit on the MXU; we run a SWAR popcount on the VPU
    over (BM, BK) x (BN, BK) VMEM tiles, contracting BK packed words at a
    time with a broadcasted AND/XOR into a (BM, BN) f32 accumulator.
  * tile sizes default to (128, 128) output blocks — MXU-alignment-friendly
    and small enough that a (128, BK) int32 tile pair + (128, 128) f32
    accumulator stays well under VMEM (BK=256: 2*128KiB + 64KiB).
  * the K grid dimension is innermost so the accumulator tile stays resident
    in VMEM across the contraction (revisiting semantics), giving one HBM
    write per output tile.

Grid: (M/BM, N/BN, W/BK); index_maps broadcast A tiles over j and B tiles
over i.  Output dtype int32 (counts fit in 32 bits: w*32 <= 2^31).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import pad_to_multiple as _pad_to


def _popcount_u32(v):
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _pair_stats_kernel(a_ref, b_ref, inner_ref, ham_ref, *, op_inner, op_ham):
    """One (BM, BN) output tile, one BK slab of the contraction."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        if op_inner:
            inner_ref[...] = jnp.zeros_like(inner_ref)
        if op_ham:
            ham_ref[...] = jnp.zeros_like(ham_ref)

    a = a_ref[...]  # (BM, BK) int32
    b = b_ref[...]  # (BN, BK) int32
    # Broadcast to (BM, BN, BK): the VPU processes the 8x128 lanes of the
    # trailing dims; BK is the vectorised axis.
    a3 = a[:, None, :]
    b3 = b[None, :, :]
    if op_inner:
        inner_ref[...] += jnp.sum(_popcount_u32(a3 & b3), axis=-1, dtype=jnp.int32)
    if op_ham:
        ham_ref[...] += jnp.sum(_popcount_u32(a3 ^ b3), axis=-1, dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("op_inner", "op_ham", "bm", "bn", "bk", "interpret"),
)
def pair_stats(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    op_inner: bool = True,
    op_ham: bool = True,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = False,
):
    """All-pairs packed popcount stats.

    a: (M, W) int32 packed rows; b: (N, W) int32 packed rows.
    Returns (inner, hamming), each (M, N) int32 (None if the op is disabled).
    """
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[1]
    m, w = a.shape
    n = b.shape[0]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, w)
    a_p = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    b_p = _pad_to(_pad_to(b, bn_, 0), bk_, 1)
    mp, wp = a_p.shape
    np_ = b_p.shape[0]
    grid = (mp // bm_, np_ // bn_, wp // bk_)

    out_shapes = []
    out_specs = []
    if op_inner:
        out_shapes.append(jax.ShapeDtypeStruct((mp, np_), jnp.int32))
        out_specs.append(pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)))
    if op_ham:
        out_shapes.append(jax.ShapeDtypeStruct((mp, np_), jnp.int32))
        out_specs.append(pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)))

    def kernel(a_ref, b_ref, *out_refs):
        refs = list(out_refs)
        inner_ref = refs.pop(0) if op_inner else None
        ham_ref = refs.pop(0) if op_ham else None
        _pair_stats_kernel(
            a_ref, b_ref, inner_ref, ham_ref, op_inner=op_inner, op_ham=op_ham
        )

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
        ],
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
        interpret=interpret,
    )(a_p, b_p)

    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    outs = [o[:m, :n] for o in outs]
    it = iter(outs)
    inner = next(it) if op_inner else None
    ham = next(it) if op_ham else None
    return inner, ham


def row_popcount_kernel(x_ref, o_ref):
    """Row Hamming weights: (BM, W) int32 -> (BM, 1) int32."""
    o_ref[...] = jnp.sum(_popcount_u32(x_ref[...]), axis=-1, keepdims=True,
                         dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def row_popcount(x: jnp.ndarray, *, bm: int = 256, interpret: bool = False):
    m, w = x.shape
    bm_ = min(bm, m)
    x_p = _pad_to(x, bm_, 0)
    mp = x_p.shape[0]
    out = pl.pallas_call(
        row_popcount_kernel,
        grid=(mp // bm_,),
        in_specs=[pl.BlockSpec((bm_, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.int32),
        interpret=interpret,
    )(x_p)
    return out[:m, 0]
