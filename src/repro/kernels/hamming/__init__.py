"""hamming kernel package."""
from repro.kernels.hamming import kernel, ops, ref  # noqa: F401
