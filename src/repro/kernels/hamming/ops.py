"""Jit'd public wrappers around the hamming pair-stats kernel.

`use_pallas=None` auto-selects: real TPU -> compiled kernel; CPU -> the jnp
reference (the interpreter is for correctness tests, not production CPU use).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cham import binhamming_from_stats
from repro.kernels.hamming import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pair_stats(a, b, *, use_pallas: bool | None = None, interpret: bool | None = None):
    """(inner, hamming) between packed rows a (M,W) and b (N,W)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.pair_stats(
            a, b, interpret=bool(interpret if interpret is not None else not _on_tpu())
        )
    return ref.pair_stats_ref(a, b)


def cham_matrix_fast(a, b, d: int, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """All-pairs Cham estimate using the kernel for the popcount contraction."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        inner, _ = kernel.pair_stats(a, b, op_ham=False, interpret=not _on_tpu())
        wa = kernel.row_popcount(a, interpret=not _on_tpu())
        wb = kernel.row_popcount(b, interpret=not _on_tpu())
    else:
        inner, _ = ref.pair_stats_ref(a, b)
        wa, wb = ref.row_popcount_ref(a), ref.row_popcount_ref(b)
    return 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)


def hamming_matrix_fast(a, b, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """Exact all-pairs HD between packed binary rows."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        _, ham = kernel.pair_stats(a, b, op_inner=False, interpret=not _on_tpu())
        return ham
    return ref.pair_stats_ref(a, b)[1]


def dist_matrix(q, store, d: int, *, metric: str = "cham",
                use_pallas: bool | None = None) -> jnp.ndarray:
    """Query-vs-store distance tile: (Q, W) x (N, W) packed -> (Q, N) f32.

    The serving-shaped entry to the pair-stats kernel: a small query block
    against a large store slab, under either distance the index subsystem
    serves ("cham" = estimated HD of the original categorical rows,
    "hamming" = exact HD of the packed sketches, as wa + wb - 2*inner).
    The pairwise statistics (wq, ws, inner) are exact integers on both
    backends, so "hamming" entries are exact and bit-stable everywhere.
    "cham" applies the float estimator to those exact integers: values agree
    with the streaming engine's tiles (repro.core.allpairs._tile_dist) to
    cross-graph libm noise (~1e-7 relative — eager vs fused-loop log
    lowering), NOT bit-for-bit; repro.index therefore serves topk/radius
    through core.allpairs and uses this path only for re-ranking, where
    last-ulp noise is immaterial.
    """
    if metric == "cham":
        return cham_matrix_fast(q, store, d, use_pallas=use_pallas)
    if metric == "hamming":
        # wa + wb - 2*inner == the XOR popcount the fast path computes
        return hamming_matrix_fast(q, store,
                                   use_pallas=use_pallas).astype(jnp.float32)
    raise ValueError(f"unknown metric {metric!r}")
