"""Jit'd public wrappers around the hamming pair-stats kernel.

`use_pallas=None` auto-selects: real TPU -> compiled kernel; CPU -> the jnp
reference (the interpreter is for correctness tests, not production CPU use).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cham import binhamming_from_stats
from repro.kernels.hamming import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pair_stats(a, b, *, use_pallas: bool | None = None, interpret: bool | None = None):
    """(inner, hamming) between packed rows a (M,W) and b (N,W)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.pair_stats(
            a, b, interpret=bool(interpret if interpret is not None else not _on_tpu())
        )
    return ref.pair_stats_ref(a, b)


def cham_matrix_fast(a, b, d: int, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """All-pairs Cham estimate using the kernel for the popcount contraction."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        inner, _ = kernel.pair_stats(a, b, op_ham=False, interpret=not _on_tpu())
        wa = kernel.row_popcount(a, interpret=not _on_tpu())
        wb = kernel.row_popcount(b, interpret=not _on_tpu())
    else:
        inner, _ = ref.pair_stats_ref(a, b)
        wa, wb = ref.row_popcount_ref(a), ref.row_popcount_ref(b)
    return 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)


def hamming_matrix_fast(a, b, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """Exact all-pairs HD between packed binary rows."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        _, ham = kernel.pair_stats(a, b, op_inner=False, interpret=not _on_tpu())
        return ham
    return ref.pair_stats_ref(a, b)[1]
