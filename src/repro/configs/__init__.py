"""Config package: base dataclasses + one module per assigned arch."""
