"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752(expert)
vocab=100352, 16 experts top-4 [hf:databricks/dbrx-base]."""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    layer_pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752,
                  capacity_factor=1.25),
    rope_theta=500000.0,
)
