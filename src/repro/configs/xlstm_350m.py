"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517].  Period of 8: one sLSTM block per 8 (xLSTM[7:1]),
no FFN (d_ff=0 per assignment -> mlp='none')."""

from repro.configs.base import LayerSpec, ModelConfig, XLSTMConfig

_PERIOD = tuple(
    LayerSpec(mixer="slstm" if i == 7 else "mlstm", mlp="none")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    layer_pattern=_PERIOD,
    xlstm=XLSTMConfig(slstm_every=8),
    tie_embeddings=True,
    subquadratic=True,
)
