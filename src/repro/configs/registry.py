"""Architecture registry: --arch <id> -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "phi_3_vision_4_2b",
    "llama3_8b",
    "deepseek_7b",
    "qwen2_7b",
    "internlm2_1_8b",
    "deepseek_v3_671b",
    "dbrx_132b",
    "jamba_v0_1_52b",
    "xlstm_350m",
    "whisper_tiny",
)

# public --arch aliases (dashes as in the assignment sheet)
ALIASES = {aid.replace("_", "-"): aid for aid in ARCH_IDS}
ALIASES.update({aid: aid for aid in ARCH_IDS})


def get_config(arch: str) -> ModelConfig:
    key = arch.replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch}'; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {aid: get_config(aid) for aid in ARCH_IDS}
