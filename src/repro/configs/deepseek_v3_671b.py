"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MLA, 1 shared + 256 routed experts top-8 [arXiv:2412.19437].

The dense d_ff (first_k_dense layers + shared expert sizing) is 18432 per
the paper; routed experts use d_ff_expert=2048 as assigned.
"""

from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,  # MLA: latent-shared, head count for q
    d_ff=18432,      # dense layers (first 3)
    vocab_size=129280,
    head_dim=128,
    layer_pattern=(LayerSpec(mixer="mla", mlp="moe"),),
    first_k_dense=3,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    rope_theta=10000.0,
)
