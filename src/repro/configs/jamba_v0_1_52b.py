"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
MoE 16e top-2, Mamba:attn 7:1 interleave [arXiv:2403.19887].

Period of 8 layers: attention at position 3 (jamba convention), Mamba
elsewhere; MoE MLP every other layer (even offsets dense, odd MoE).
"""

from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig

_PERIOD = tuple(
    LayerSpec(
        mixer="attn" if i == 3 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    layer_pattern=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  capacity_factor=1.25),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10000.0,
    subquadratic=True,  # mamba-dominant: long_500k applicable
)
