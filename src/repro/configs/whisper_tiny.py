"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865 — enc-dec, conv frontend stubbed as precomputed frame
embeddings (B, 1500, 384) [arXiv:2212.04356]."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    kind="encdec",
    n_encoder_layers=4,
    frontend="audio",
    n_frontend_tokens=1500,  # 30 s of audio at 20 ms hop (stub)
    tie_embeddings=True,
    rope_theta=10000.0,
)
