"""Distributed substrate."""
