"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (stub patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct]."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    layer_pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    rope_theta=10000.0,
    frontend="vision",
    n_frontend_tokens=576,  # 24x24 CLIP patch grid (stub embeddings)
)
