"""Config system: frozen dataclasses describing model architecture, shapes,
parallelism and training hyperparameters.

Every assigned architecture file in repro/configs/<id>.py builds a
ModelConfig via these dataclasses; launchers consume them via
repro.configs.registry.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

Mixer = Literal["attn", "mla", "mamba", "mlstm", "slstm"]
MlpKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    mlp: MlpKind = "dense"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0  # deepseek-v3 shared expert(s)
    capacity_factor: float = 1.25
    group_size: int = 4096  # tokens per dispatch group (memory knob)
    router_aux_weight: float = 0.01
    # dtype of the one-hot dispatch/combine tensors: f32 baseline; bf16
    # halves the dominant all-to-all traffic (see EXPERIMENTS.md section Perf)
    dispatch_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class XLSTMConfig:
    # indices (mod pattern period) using sLSTM; the rest are mLSTM
    slstm_every: int = 8  # one sLSTM block per this many layers
    proj_factor: float = 2.0


@dataclass(frozen=True)
class Precision:
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    logits_dtype: str = "float32"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    layer_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    first_k_dense: int = 0  # leading layers forced to dense MLP (dsv3)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    rope_theta: float = 10000.0
    qkv_bias: bool = False  # qwen2
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    kind: str = "decoder"  # decoder | encdec
    n_encoder_layers: int = 0  # encdec only
    frontend: str | None = None  # vision | audio | None (stub embeddings)
    n_frontend_tokens: int = 0  # patches / audio frames provided by stub
    max_seq_len: int = 131072
    # paper-technique integration knobs
    hashed_embedding: bool = False  # CabinEmbed hashed vocab embedding
    hashed_embedding_buckets: int = 0
    hashed_embedding_k: int = 2
    precision: Precision = field(default_factory=Precision)
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_spec(self, i: int) -> LayerSpec:
        if i < self.first_k_dense:
            base = self.layer_pattern[i % len(self.layer_pattern)]
            return replace(base, mlp="dense")
        return self.layer_pattern[i % len(self.layer_pattern)]

    def all_layers(self) -> tuple[LayerSpec, ...]:
        return tuple(self.layer_spec(i) for i in range(self.n_layers))


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Parallelism & memory knobs consumed by train/serve/launch."""

    microbatches: int = 1  # gradient accumulation steps
    remat: str = "block"  # none | block | full
    sequence_parallel: bool = True  # shard residual seq over 'model'
    zero3: bool = True  # shard params/moments over 'data'
    grad_compress_pods: bool = False  # EF-sign cross-pod compression
    kv_cache_dtype: str = "bfloat16"  # or int8
    attention_impl: str | None = None  # None=auto, pallas|chunked|ref
    moe_group_size: int = 4096
    # Unroll layer scans into straight-line HLO.  Used by the dry-run's cost
    # pass: XLA's HloCostAnalysis counts while-loop bodies ONCE regardless of
    # trip count, so flops/bytes/collectives of scanned stacks are measured
    # on unrolled reduced-depth twins and extrapolated (launch/dryrun.py).
    unroll_scan: bool = False


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    seed: int = 0


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per spec f)."""
    pattern_period = len(cfg.layer_pattern)
    n_layers = max(pattern_period, min(cfg.n_layers, 2 * pattern_period))
    moe = None
    if cfg.moe is not None:
        moe = replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            group_size=128,
        )
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                        qk_rope_dim=8, v_dim=16)
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        moe=moe,
        mla=mla,
        first_k_dense=min(cfg.first_k_dense, 1),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
        max_seq_len=512,
        precision=Precision(param_dtype="float32", compute_dtype="float32"),
    )
