"""span() tracing: Chrome trace-event JSON out of the serving hot paths.

Spans are recorded as "X" (complete) events — one dict per span with a
microsecond start timestamp and duration, keyed by (pid, tid).  Perfetto /
chrome://tracing reconstruct nesting per thread from ts/dur containment,
so thread-safe nesting costs nothing beyond tagging each event with
`threading.get_ident()`: concurrent threads (Checkpointer's async save,
future per-shard workers) land on separate tracks instead of corrupting a
shared stack.  `instant()` records zero-duration "i" events; the
runtime.faultinject observer hook routes every crash-point crossing here,
so a trace of a migration shows exactly where the durability boundaries
fell relative to the batch spans around them.

The buffer is a bounded deque (default 64k events, oldest dropped) — a
long-lived server records a sliding window, not an unbounded log.  Export
with `export_trace(path)`: the file is the standard `{"traceEvents": []}`
JSON object, loadable in https://ui.perfetto.dev.

This module always records when called; the REPRO_OBS=0 gating lives in
`repro.obs.__init__`, which rebinds the public `span`/`instant` names to
no-op closures so disabled call sites never reach here.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

TRACE_CAPACITY = 1 << 16

_events: deque = deque(maxlen=TRACE_CAPACITY)
# one origin per process: Chrome trace ts is relative anyway, and
# perf_counter deltas from a fixed origin keep spans from different
# threads on one consistent clock
_T0 = time.perf_counter()


def _now_us() -> float:
    return (time.perf_counter() - _T0) * 1e6


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("name", "args", "_ts")

    def __init__(self, name: str, args: dict | None):
        self.name = name
        self.args = args

    def __enter__(self):
        self._ts = _now_us()
        return self

    def __exit__(self, *exc):
        ev = {
            "name": self.name, "ph": "X", "ts": self._ts,
            "dur": _now_us() - self._ts,
            "pid": os.getpid(), "tid": threading.get_ident(),
        }
        if self.args:
            ev["args"] = self.args
        _events.append(ev)
        return False


def span(name: str, **args) -> _Span:
    """Trace the `with` block as a named span (extra kwargs become the
    event's `args`, visible in the Perfetto detail pane)."""
    return _Span(name, args or None)


def instant(name: str, **args) -> None:
    """Record a zero-duration instant event (thread scope)."""
    ev = {
        "name": name, "ph": "i", "s": "t", "ts": _now_us(),
        "pid": os.getpid(), "tid": threading.get_ident(),
    }
    if args:
        ev["args"] = args
    _events.append(ev)


def export_trace(path: str) -> int:
    """Write the buffered events as Chrome trace-event JSON; returns the
    number of events written.  The buffer is NOT cleared — export is a
    read, `clear_trace()` is the reset."""
    evs = list(_events)
    with open(path, "w") as f:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
    return len(evs)


def clear_trace() -> None:
    _events.clear()


def trace_events() -> list[dict]:
    """The buffered events (a copy) — for tests and in-process tooling."""
    return list(_events)
