"""MetricsRegistry: counters, gauges, and log-bucketed latency histograms.

The serving stack's measurement discipline mirrors its sketching one: every
instrument is MERGEABLE.  A histogram is a map exponent -> count over
power-of-two buckets (value v lands in the bucket (2^(e-1), 2^e] — frexp,
no log calls on the hot path), so merging two histograms is integer
addition per bucket, exactly like OR-merging two BinSketch sketches —
per-shard registries (ROADMAP item 2's merge-tree workers) ship upward and
combine without losing quantile information beyond the bucket width.
Quantiles are extracted by walking the cumulative bucket counts and
interpolating inside the crossing bucket, so p50/p95/p99 are exact to
within one power-of-two bucket — the same "within one bucket" contract the
acceptance tests pin against numpy percentiles.

Three instrument kinds:

  * Counter — monotone float/int, `inc(n)`.  Merge: sum.
  * Gauge — last-set value, or a CALLBACK evaluated at snapshot/render time
    (`MetricsRegistry.gauge_fn`) so structural gauges (tier row counts,
    compile-cache size, migration progress) always read the live state
    instead of a stale sample.  Merge: sum (per-shard row counts add; a
    last-write-wins merge would silently drop shards).
  * Histogram — pow2 buckets + count/sum/min/max, `observe(v)`,
    `quantile(p)`, `time()` context manager.  Merge: per-bucket sum.

Instruments are identified by (name, sorted label items); `labels` render
into Prometheus text format (`render_prom`) and nest under the name in
`snapshot()`.  All mutation goes through per-registry locks: spans fire
from helper threads (Checkpointer's async save) and per-shard workers, and
a lost increment would break the "hit/miss counters are exact" contract the
LRU property test enforces.

The null twins at the bottom (`NullRegistry` etc.) are the REPRO_OBS=0
path: every method is a constant-returning no-op on shared singletons, so
disabled instrumentation costs an attribute lookup and an empty call — no
allocation, no branches in caller code, and (being pure host no-ops) zero
compiled-graph entries, which tests/test_obs.py pins with a _cache_size
test.
"""

from __future__ import annotations

import math
import threading
import time


def _bucket_exp(v: float) -> int:
    """Bucket exponent e such that v lands in (2^(e-1), 2^e] — exact powers
    of two land on their own boundary.  Non-positive values collapse into a
    single underflow bucket below every real one."""
    if v <= 0.0:
        return -1075  # below the smallest positive float's exponent
    m, e = math.frexp(v)  # v = m * 2^e, m in [0.5, 1)
    return e - 1 if m == 0.5 else e


def _quantile(buckets: dict, count: int, mn: float, mx: float,
              p: float) -> float:
    """Quantile over an already-copied histogram state (see
    Histogram.state) — lock-free, so exporters can compute p50/p95/p99
    from one consistent copy instead of re-locking per quantile."""
    if count == 0:
        return math.nan
    target = max(1.0, (p / 100.0) * count)
    cum = 0
    for e in sorted(buckets):
        n = buckets[e]
        lo, hi = 2.0 ** (e - 1), 2.0 ** e
        if cum + n >= target:
            frac = (target - cum) / n
            est = lo + frac * (hi - lo)
            return min(max(est, mn), mx)
        cum += n
    return mx


class Counter:
    """Monotone counter.  `value` is a float (Prometheus convention); inc
    with ints to keep it exact for accounting counters."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-set value (or live callback — see MetricsRegistry.gauge_fn)."""

    __slots__ = ("_value", "_fn")

    def __init__(self, fn=None):
        self._value = 0.0
        self._fn = fn

    def set(self, v) -> None:
        self._value = v

    @property
    def value(self):
        if self._fn is not None:
            return self._fn()
        return self._value


class Histogram:
    """Power-of-two log-bucketed histogram with exact count/sum/min/max.

    Buckets are keyed by exponent: value v counts toward bucket e with
    upper edge 2^e, where 2^(e-1) < v <= 2^e.  `quantile(p)` (p in [0,100])
    walks the cumulative counts to the crossing bucket and linearly
    interpolates inside it — within one bucket of the true order statistic
    by construction.
    """

    __slots__ = ("buckets", "count", "sum", "min", "max", "_lock")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        e = _bucket_exp(v)
        with self._lock:
            self.buckets[e] = self.buckets.get(e, 0) + 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def time(self) -> "_HistTimer":
        """Context manager observing the block's wall time in MILLISECONDS
        — the unit every latency histogram in the repo uses."""
        return _HistTimer(self)

    def quantile(self, p: float) -> float:
        """p-th percentile (p in [0, 100]), exact to within one pow2 bucket
        (linear interpolation inside the crossing bucket, clamped to the
        observed min/max so degenerate histograms stay sensible).  NaN when
        empty."""
        buckets, count, _, mn, mx = self.state()
        return _quantile(buckets, count, mn, mx, p)

    def state(self) -> tuple:
        """Consistent copy of (buckets, count, sum, min, max) taken under
        the lock — the one safe way to READ a histogram that other threads
        are concurrently observing into.  Iterating `.buckets` directly can
        see the dict resize mid-iteration (RuntimeError) or pair a bucket
        sum with a count from a different instant; every reader in this
        module (`quantile`, `snapshot`, `render_prom`, `merge_from`) goes
        through here."""
        with self._lock:
            return (dict(self.buckets), self.count, self.sum,
                    self.min, self.max)

    def reset(self) -> None:
        """Zero the histogram — for measurement windows (benchmarks reset
        after warmup so compile-time outliers stay out of the quantiles).
        Production scrapes never reset; Prometheus rates over cumulative
        counts."""
        with self._lock:
            self.buckets.clear()
            self.count = 0
            self.sum = 0.0
            self.min = math.inf
            self.max = -math.inf

    def merge_from(self, other: "Histogram") -> None:
        # copy other's state under ITS lock first, then fold under ours —
        # sequential lock holds, never nested, so merging a registry into
        # itself or cross-merging two registries cannot deadlock
        buckets, count, total, mn, mx = other.state()
        with self._lock:
            for e, n in buckets.items():
                self.buckets[e] = self.buckets.get(e, 0) + n
            self.count += count
            self.sum += total
            self.min = min(self.min, mn)
            self.max = max(self.max, mx)


class _HistTimer:
    __slots__ = ("_h", "_t0")

    def __init__(self, h: Histogram):
        self._h = h

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._h.observe((time.perf_counter() - self._t0) * 1e3)
        return False


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


class MetricsRegistry:
    """A namespace of instruments, mergeable with other registries.

    `counter`/`gauge`/`histogram` are get-or-create by (name, labels) —
    hot paths cache the returned instrument once and hit only its own
    method afterwards.  One name must keep one kind (ValueError otherwise:
    a name that is a counter on one shard and a gauge on another could not
    merge or render).
    """

    is_null = False

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, object] = {}
        self._kinds: dict[str, type] = {}

    def _get(self, cls, name: str, labels: dict, factory):
        key = _key(name, labels)
        with self._lock:
            got = self._metrics.get(key)
            if got is None:
                kind = self._kinds.setdefault(name, cls)
                if kind is not cls:
                    raise ValueError(
                        f"metric {name!r} is already a {kind.__name__}, "
                        f"not a {cls.__name__}")
                got = self._metrics[key] = factory()
            elif type(got) is not cls:
                raise ValueError(
                    f"metric {name!r} is already a {type(got).__name__}, "
                    f"not a {cls.__name__}")
            return got

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels, Gauge)

    def gauge_fn(self, name: str, fn, **labels) -> Gauge:
        """A gauge whose value is `fn()` evaluated at read time — the live
        window onto structural state (tier depths, cache sizes, migration
        progress).  Re-registering the same (name, labels) swaps the
        callback: the engine re-registers across store swaps/restores."""
        g = self._get(Gauge, name, labels, lambda: Gauge(fn))
        g._fn = fn
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels, Histogram)

    # -- merge (the merge-tree discipline) ----------------------------------

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold `other`'s instruments into this registry: counters and
        gauges sum, histograms add per-bucket — associative and
        commutative, so a log-depth merge tree of per-worker registries
        yields the same totals as any sequential order.  Callback gauges
        merge by their value AT MERGE TIME (the callback itself stays with
        its own registry — a shipped registry is a snapshot)."""
        if getattr(other, "is_null", False):
            return
        with other._lock:
            items = list(other._metrics.items())
        for (name, labels), m in items:
            if isinstance(m, Counter):
                self.counter(name, **dict(labels)).inc(m.value)
            elif isinstance(m, Histogram):
                self.histogram(name, **dict(labels)).merge_from(m)
            else:
                g = self.gauge(name, **dict(labels))
                g._fn = None
                g._value = g._value + m.value

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> dict:
        """Nested plain-python dict: name -> {label_str -> value} for
        counters/gauges, name -> {label_str -> {count, sum, min, max, p50,
        p95, p99}} for histograms.  Unlabeled instruments collapse the
        inner level to the value itself."""
        with self._lock:
            items = list(self._metrics.items())
        out: dict = {}
        for (name, labels), m in items:
            if isinstance(m, Counter):
                val: object = m.value
            elif isinstance(m, Gauge):
                val = m.value
            else:
                buckets, count, total, mn, mx = m.state()
                val = {
                    "count": count, "sum": total,
                    "min": None if count == 0 else mn,
                    "max": None if count == 0 else mx,
                    "p50": _quantile(buckets, count, mn, mx, 50),
                    "p95": _quantile(buckets, count, mn, mx, 95),
                    "p99": _quantile(buckets, count, mn, mx, 99),
                }
            if not labels:
                out[name] = val
            else:
                lab = ",".join(f"{k}={v}" for k, v in labels)
                out.setdefault(name, {})[lab] = val
        return out

    def render_prom(self) -> str:
        """Prometheus text exposition format.  Histograms render cumulative
        `_bucket{le=...}` series over their occupied pow2 bucket edges plus
        `_sum`/`_count`; counters get the `_total`-less raw name with
        `# TYPE` headers (names here already carry `_total` suffixes where
        conventional)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: list[str] = []
        typed: set[str] = set()

        def labstr(labels: tuple, extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in labels]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        for (name, labels), m in items:
            if isinstance(m, Counter):
                if name not in typed:
                    lines.append(f"# TYPE {name} counter")
                    typed.add(name)
                lines.append(f"{name}{labstr(labels)} {m.value}")
            elif isinstance(m, Gauge):
                if name not in typed:
                    lines.append(f"# TYPE {name} gauge")
                    typed.add(name)
                lines.append(f"{name}{labstr(labels)} {m.value}")
            else:
                if name not in typed:
                    lines.append(f"# TYPE {name} histogram")
                    typed.add(name)
                buckets, count, total, _, _ = m.state()
                cum = 0
                for e in sorted(buckets):
                    cum += buckets[e]
                    edge = f'le="{2.0 ** e:g}"'
                    lines.append(
                        f"{name}_bucket{labstr(labels, edge)} {cum}")
                inf_edge = labstr(labels, 'le="+Inf"')
                lines.append(f"{name}_bucket{inf_edge} {count}")
                lines.append(f"{name}_sum{labstr(labels)} {total:g}")
                lines.append(f"{name}_count{labstr(labels)} {count}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# the REPRO_OBS=0 no-op twins — shared singletons, every method constant
# ---------------------------------------------------------------------------


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class _NullCounter:
    __slots__ = ()
    value = 0

    def inc(self, n=1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0

    def set(self, v) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    count = 0
    sum = 0.0

    def observe(self, v) -> None:
        pass

    def time(self):
        return _NULL_TIMER

    def quantile(self, p):
        return math.nan

    def state(self):
        return {}, 0, 0.0, math.inf, -math.inf

    def reset(self) -> None:
        pass


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class NullRegistry:
    """The disabled path: hands out shared no-op instruments, ignores
    merges, exports empty.  Callers keep IDENTICAL code for both modes —
    they cache instruments at construction and call their methods; with
    this registry those are empty host calls that touch no jax API, so the
    disabled engine compiles exactly the graphs the uninstrumented one
    did (pinned by tests/test_obs.py)."""

    is_null = True

    def counter(self, name, **labels):
        return _NULL_COUNTER

    def gauge(self, name, **labels):
        return _NULL_GAUGE

    def gauge_fn(self, name, fn, **labels):
        return _NULL_GAUGE

    def histogram(self, name, **labels):
        return _NULL_HISTOGRAM

    def merge(self, other) -> None:
        pass

    def snapshot(self) -> dict:
        return {}

    def render_prom(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()
