"""repro.obs: the engine-wide flight recorder (DESIGN.md section 11).

Three pieces, all mergeable and all removable:

  * `MetricsRegistry` (obs/registry.py) — counters, gauges, pow2-bucketed
    latency histograms with p50/p95/p99 extraction; registries merge
    (per-bucket integer addition — the same discipline that makes the
    sketches shard-friendly).
  * `span` / `instant` tracing (obs/trace.py) — Chrome trace-event JSON
    via `export_trace(path)`, loadable in Perfetto; runtime.faultinject
    crash-point crossings appear as instant events.
  * exporters — `snapshot()`, `render_prom()` (Prometheus text format),
    and the `QueryEngine.stats()` facade built on them.

The on/off contract: REPRO_OBS=0 (or "false"/"off") in the environment
disables the whole layer at import.  Disabled, `new_registry()` returns
the shared `NULL_REGISTRY` (all instruments are constant no-ops) and
`span`/`instant` are rebound to no-op CLOSURES — instrumented code runs
bit-identically, compiles zero additional graphs, and pays one attribute
lookup plus an empty call per site (the CI overhead guard bounds the
enabled path too).  `configure(enabled)` flips the switch at runtime for
tests; call sites must access `obs.span` through the module attribute
(every in-repo site does) for the rebind to take effect.
"""

from __future__ import annotations

import os

from repro.obs import trace as _trace_mod
from repro.obs.registry import (Counter, Gauge, Histogram,  # noqa: F401
                                MetricsRegistry, NULL_REGISTRY,
                                NullRegistry)
from repro.obs.trace import (TRACE_CAPACITY, clear_trace,  # noqa: F401
                             export_trace, trace_events)
from repro.runtime import faultinject as _faultinject

__all__ = [
    "MetricsRegistry", "NullRegistry", "NULL_REGISTRY",
    "Counter", "Gauge", "Histogram",
    "span", "instant", "export_trace", "clear_trace", "trace_events",
    "enabled", "configure", "new_registry", "get_registry", "render_prom",
    "snapshot", "TRACE_CAPACITY",
]


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def _noop_span(name, **args):
    return _NULL_SPAN


def _noop_instant(name, **args):
    return None


_enabled = os.environ.get("REPRO_OBS", "1").strip().lower() not in (
    "0", "false", "off")
_default_registry: MetricsRegistry | None = None

# rebound by configure(); import-time defaults set at the bottom
span = _noop_span
instant = _noop_instant


def enabled() -> bool:
    return _enabled


def configure(on: bool) -> None:
    """Flip the module switch at runtime (tests; production uses the
    REPRO_OBS env var read at import).  Registries already handed out keep
    their mode — only objects created AFTER the flip see it."""
    global _enabled, span, instant
    _enabled = bool(on)
    if _enabled:
        span = _trace_mod.span
        instant = _trace_mod.instant
        _faultinject.set_observer(_crash_point_instant)
    else:
        span = _noop_span
        instant = _noop_instant
        _faultinject.set_observer(None)


def _crash_point_instant(point: str) -> None:
    """faultinject observer: each crash-point crossing becomes an instant
    event, so durability boundaries are visible inside migration/save
    spans in the exported trace."""
    _trace_mod.instant("crash_point", point=point)


def new_registry() -> MetricsRegistry | NullRegistry:
    """A fresh registry under the current switch — what QueryEngine builds
    its per-engine registry from (NULL_REGISTRY when disabled, so every
    instrument call in the engine is a shared no-op)."""
    return MetricsRegistry() if _enabled else NULL_REGISTRY


def get_registry() -> MetricsRegistry | NullRegistry:
    """The process-default registry (created on first use) — for module
    code with no engine to hang metrics on.  Engines default to their OWN
    registries so per-engine stats stay separable; merge them into this
    one to get a process-wide view."""
    global _default_registry
    if not _enabled:
        return NULL_REGISTRY
    if _default_registry is None:
        _default_registry = MetricsRegistry()
    return _default_registry


def render_prom(registry=None) -> str:
    """Prometheus text format of `registry` (default: the process-default
    registry)."""
    return (registry if registry is not None else get_registry()
            ).render_prom()


def snapshot(registry=None) -> dict:
    """Plain-dict snapshot of `registry` (default: the process-default)."""
    return (registry if registry is not None else get_registry()).snapshot()


configure(_enabled)
