"""Mamba (S6 selective state space) mixer — used by jamba's hybrid stack.

Train/prefill runs the recurrence with lax.scan over time, keeping the live
state at (B, ED, N) — the associative-scan formulation materialises
(B, S, ED, N) which is a 32x activation blowup at jamba scale, so the
sequential scan is the memory-sane XLA path (a chunked Pallas kernel is the
TPU-native alternative; see DESIGN.md/EXPERIMENTS notes).  Decode is the
natural O(1) recurrent step carrying (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dt, matmul


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    ed = m.expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return m, ed, dt_rank


def mamba_init(cfg: ModelConfig, key) -> dict:
    m, ed, dt_rank = _dims(cfg)
    pdt = dt(cfg.precision.param_dtype)
    ks = jax.random.split(key, 5)
    a = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None, :],
                 (ed, 1))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * ed, pdt),
        "conv_w": (jax.random.normal(ks[1], (ed, m.d_conv), jnp.float32)
                   * (1.0 / m.d_conv) ** 0.5).astype(pdt),
        "conv_b": jnp.zeros((ed,), pdt),
        "x_proj": dense_init(ks[2], ed, dt_rank + 2 * m.d_state, pdt),
        "dt_w": dense_init(ks[3], dt_rank, ed, pdt),
        "dt_b": jnp.full((ed,), -4.6, pdt),  # softplus^-1(0.01)
        "a_log": jnp.log(a),  # f32: selective dynamics stay in f32
        "d": jnp.ones((ed,), jnp.float32),
        "out_proj": dense_init(ks[4], ed, cfg.d_model, pdt),
    }


def _ssm_inputs(cfg: ModelConfig, params, xc):
    """xc: (B, S, ED) post-conv. Returns dt_full, b_in, c_in (f32)."""
    m, ed, dt_rank = _dims(cfg)
    cdt = dt(cfg.precision.compute_dtype)
    proj = matmul(xc, params["x_proj"], cdt)  # (B,S,R+2N) f32 accum
    dt_part = proj[..., :dt_rank]
    b_in = proj[..., dt_rank : dt_rank + m.d_state].astype(jnp.float32)
    c_in = proj[..., dt_rank + m.d_state :].astype(jnp.float32)
    dt_full = jax.nn.softplus(
        matmul(dt_part.astype(cdt), params["dt_w"], cdt)
        + params["dt_b"].astype(jnp.float32))  # (B,S,ED) f32
    return dt_full, b_in, c_in


def _causal_conv(cfg, params, x, conv_state=None):
    """Depthwise causal conv. x: (B, S, ED). conv_state: (B, K-1, ED)."""
    m, ed, _ = _dims(cfg)
    k = m.d_conv
    xf = x.astype(jnp.float32)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, ed), jnp.float32)
    else:
        pad = conv_state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)  # (B, S+K-1, ED)
    w = params["conv_w"].astype(jnp.float32)  # (ED, K)
    out = sum(xp[:, i : i + x.shape[1], :] * w[:, i] for i in range(k))
    out = out + params["conv_b"].astype(jnp.float32)
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out), new_state


def mamba_batch(cfg: ModelConfig, params, x, positions=None):
    """x: (B, S, D) -> (out, final_state) with lax.scan over time."""
    m, ed, _ = _dims(cfg)
    cdt = dt(cfg.precision.compute_dtype)
    b, s, d = x.shape
    xz = matmul(x, params["in_proj"], cdt)
    x1, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(cfg, params, x1)
    dt_full, b_in, c_in = _ssm_inputs(cfg, params, xc.astype(cdt))
    a = -jnp.exp(params["a_log"])  # (ED, N)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,ED),(B,ED),(B,N),(B,N)
        da = jnp.exp(dtt[..., None] * a[None])  # (B,ED,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("ben,bn->be", h, ct)
        return h, y

    h0 = jnp.zeros((b, ed, m.d_state), jnp.float32)
    xs = (
        jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt_full, 1, 0),
        jnp.moveaxis(b_in, 1, 0),
        jnp.moveaxis(c_in, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,ED)
    y = y + params["d"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = matmul(y.astype(cdt), params["out_proj"], cdt).astype(x.dtype)
    return out, {"conv": conv_state.astype(cdt), "ssm": h_final}


def mamba_init_cache(cfg: ModelConfig, batch: int, max_len: int, quantized: bool):
    m, ed, _ = _dims(cfg)
    cdt = dt(cfg.precision.compute_dtype)
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, ed), cdt),
        "ssm": jnp.zeros((batch, ed, m.d_state), jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, params, x, cache: dict, pos=None):
    """x: (B, 1, D) single-token step."""
    m, ed, _ = _dims(cfg)
    cdt = dt(cfg.precision.compute_dtype)
    b = x.shape[0]
    xz = matmul(x, params["in_proj"], cdt)
    x1, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(cfg, params, x1.astype(cdt), cache["conv"])
    dt_full, b_in, c_in = _ssm_inputs(cfg, params, xc.astype(cdt))
    a = -jnp.exp(params["a_log"])
    xt, dtt = xc[:, 0].astype(jnp.float32), dt_full[:, 0]
    bt, ct = b_in[:, 0], c_in[:, 0]
    da = jnp.exp(dtt[..., None] * a[None])
    h = da * cache["ssm"] + (dtt * xt)[..., None] * bt[:, None, :]
    y = jnp.einsum("ben,bn->be", h, ct)
    y = y + params["d"].astype(jnp.float32) * xt
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = matmul(y[:, None].astype(cdt), params["out_proj"], cdt).astype(x.dtype)
    return out, {"conv": conv_state.astype(cdt), "ssm": h}
