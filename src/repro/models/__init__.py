"""Model zoo."""
