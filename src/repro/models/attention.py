"""Attention mixers: GQA multi-head attention and DeepSeek-style MLA.

Two execution paths per mixer:
  * batch path (train / prefill): full-sequence causal attention through the
    dispatcher in repro.kernels.flash_attention.ops (pallas on TPU, chunked
    online-softmax lax elsewhere — never materialises (S, S) scores).
  * decode path: one new token against a cache.  GQA caches K/V directly
    (optionally int8 with per-token-head scales); MLA caches the compressed
    latent + rope key and uses the ABSORBED matmul form, so decode flops and
    cache bytes scale with kv_lora_rank instead of n_heads * head_dim — the
    MLA serving optimisation from the DeepSeek-V2/V3 papers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as attn_ops
from repro.models import layers
from repro.models.layers import apply_rope, dense_init, dt, matmul, rmsnorm, rmsnorm_init

# ---------------------------------------------------------------------------
# KV cache quantisation
# ---------------------------------------------------------------------------


def quantize_kv(x: jnp.ndarray):
    """(..., dh) -> int8 values + f32 scale over the last dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_init(cfg: ModelConfig, key) -> dict:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    pdt = dt(cfg.precision.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, pdt),
        "wk": dense_init(ks[1], d, hkv * dh, pdt),
        "wv": dense_init(ks[2], d, hkv * dh, pdt),
        "wo": dense_init(ks[3], h * dh, d, pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), pdt)
        p["bk"] = jnp.zeros((hkv * dh,), pdt)
        p["bv"] = jnp.zeros((hkv * dh,), pdt)
    return p


def _project_qkv(cfg: ModelConfig, params, x):
    cdt = dt(cfg.precision.compute_dtype)
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = matmul(x, params["wq"], cdt)
    k = matmul(x, params["wk"], cdt)
    v = matmul(x, params["wv"], cdt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(jnp.float32)
        k = k + params["bk"].astype(jnp.float32)
        v = v + params["bv"].astype(jnp.float32)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3).astype(cdt)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3).astype(cdt)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3).astype(cdt)
    return q, k, v


def gqa_batch(cfg: ModelConfig, params, x, positions, *, causal=True,
              impl=None, kv_override=None, rope=True):
    """Train/prefill path. x: (B, S, D). Returns (out, kv) where kv are the
    pre-transpose K/V (B, Hkv, S, dh) for cache seeding."""
    cdt = dt(cfg.precision.compute_dtype)
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if kv_override is not None:  # cross-attention (enc-dec)
        k, v = kv_override
        causal = False
    o = attn_ops.attention(q, k, v, causal=causal, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    out = matmul(o, params["wo"], cdt).astype(x.dtype)
    return out, (k, v)


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int, quantized: bool):
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = dt(cfg.precision.compute_dtype)
    if quantized:
        return {
            "k": jnp.zeros((batch, hkv, max_len, dh), jnp.int8),
            "v": jnp.zeros((batch, hkv, max_len, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, hkv, max_len, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, hkv, max_len, dh), cdt),
        "v": jnp.zeros((batch, hkv, max_len, dh), cdt),
    }


def gqa_decode(cfg: ModelConfig, params, x, cache: dict, pos: jnp.ndarray):
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current length).

    Returns (out, new_cache).
    """
    cdt = dt(cfg.precision.compute_dtype)
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    group = h // hkv
    q, k_new, v_new = _project_qkv(cfg, params, x)  # (B,*,1,dh)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)

    quantized = "k_scale" in cache
    if quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, pos, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, pos, 0))
        cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks, (0, 0, pos, 0))
        cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs, (0, 0, pos, 0))
        k_all = dequantize_kv(cache["k"], cache["k_scale"], cdt)
        v_all = dequantize_kv(cache["v"], cache["v_scale"], cdt)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, pos, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, pos, 0))
        k_all, v_all = cache["k"], cache["v"]

    # Attention math streams the cache in its STORED dtype with f32
    # accumulation on the MXU (preferred_element_type) — casting the whole
    # cache to f32 would double the dominant HBM term of the decode roofline
    # (EXPERIMENTS.md section Perf, llama3 decode_32k iteration 1).
    s_max = k_all.shape[2]
    qg = q.reshape(b, hkv, group, dh)  # (B, Hkv, G, dh); S_q=1 folded into G
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(k_all.dtype), k_all,
                        preferred_element_type=jnp.float32) / (dh ** 0.5)
    mask = jnp.arange(s_max)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bhsd->bhgd", probs.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(b, 1, h * dh).astype(cdt)
    out = matmul(ctx, params["wo"], cdt).astype(x.dtype)
    return out, cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(cfg: ModelConfig, key) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    pdt = dt(cfg.precision.param_dtype)
    ks = jax.random.split(key, 5)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, pdt),
        "q_norm": rmsnorm_init(m.q_lora_rank, pdt),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * qk_dim, pdt),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, pdt),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, pdt),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank, h * (m.qk_nope_dim + m.v_dim), pdt),
        "wo": dense_init(ks[4], h * m.v_dim, d, pdt),
    }


def _mla_q(cfg: ModelConfig, params, x, positions):
    m = cfg.mla
    cdt = dt(cfg.precision.compute_dtype)
    b, s, _ = x.shape
    h = cfg.n_heads
    q_lat = matmul(x, params["wq_a"], cdt).astype(cdt)
    q_lat = rmsnorm(params["q_norm"], q_lat, cfg.norm_eps)
    q = matmul(q_lat, params["wq_b"], cdt)
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim).transpose(0, 2, 1, 3)
    q_nope = q[..., : m.qk_nope_dim].astype(cdt)
    q_rope = apply_rope(q[..., m.qk_nope_dim:].astype(cdt), positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, params, x, positions):
    m = cfg.mla
    cdt = dt(cfg.precision.compute_dtype)
    kv = matmul(x, params["wkv_a"], cdt)
    c_kv = rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank].astype(cdt),
                   cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:].astype(cdt)  # (B, S, rope)
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)[:, 0]
    return c_kv, k_rope


def mla_batch(cfg: ModelConfig, params, x, positions, *, impl=None):
    """Naive (expanded) MLA for train/prefill: flops-equivalent to GQA with
    per-head qk_dim keys, using the flash path."""
    m = cfg.mla
    cdt = dt(cfg.precision.compute_dtype)
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, params, x, positions)
    c_kv, k_rope = _mla_latent(cfg, params, x, positions)
    kv = matmul(c_kv, params["wkv_b"], cdt)
    kv = kv.reshape(b, s, h, m.qk_nope_dim + m.v_dim).transpose(0, 2, 1, 3)
    k_nope = kv[..., : m.qk_nope_dim].astype(cdt)
    v = kv[..., m.qk_nope_dim:].astype(cdt)
    k_rope_h = jnp.broadcast_to(k_rope[:, None], (b, h, s, m.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1).astype(cdt)
    o = attn_ops.attention(q_full, k_full, v, causal=True, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_dim)
    out = matmul(o, params["wo"], cdt).astype(x.dtype)
    return out, (c_kv, k_rope)


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, quantized: bool):
    m = cfg.mla
    cdt = dt(cfg.precision.compute_dtype)
    if quantized:
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.int8),
            "c_scale": jnp.zeros((batch, max_len, 1), jnp.float32),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), cdt),
        }
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), cdt),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), cdt),
    }


def mla_decode(cfg: ModelConfig, params, x, cache: dict, pos: jnp.ndarray):
    """Absorbed-form MLA decode: score/value math in latent space."""
    m = cfg.mla
    cdt = dt(cfg.precision.compute_dtype)
    b = x.shape[0]
    h = cfg.n_heads
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, params, x, pos_arr)  # (B,H,1,*)
    c_new, kr_new = _mla_latent(cfg, params, x, pos_arr)  # (B,1,r), (B,1,rope)

    cache = dict(cache)
    quantized = "c_scale" in cache
    if quantized:
        cq, cs = quantize_kv(c_new)
        cache["c_kv"] = jax.lax.dynamic_update_slice(cache["c_kv"], cq, (0, pos, 0))
        cache["c_scale"] = jax.lax.dynamic_update_slice(
            cache["c_scale"], cs, (0, pos, 0))
        c_all = dequantize_kv(cache["c_kv"], cache["c_scale"], cdt)
    else:
        cache["c_kv"] = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
        c_all = cache["c_kv"]
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    kr_all = cache["k_rope"]

    # Absorb k-projection into q: q_lat (B,H,r) = q_nope (B,H,nope) @ Wk^h.
    # Latent cache streamed in its stored dtype with f32 MXU accumulation
    # (same HBM-term reasoning as gqa_decode).
    wkv_b = params["wkv_b"].astype(cdt).reshape(
        m.kv_lora_rank, h, m.qk_nope_dim + m.v_dim)
    w_k = wkv_b[..., : m.qk_nope_dim]  # (r, H, nope)
    w_v = wkv_b[..., m.qk_nope_dim:]  # (r, H, v)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(cdt), w_k,
                       preferred_element_type=jnp.float32)
    scores = jnp.einsum("bhr,bsr->bhs", q_lat.astype(c_all.dtype), c_all,
                        preferred_element_type=jnp.float32)
    scores = scores + jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, :, 0].astype(kr_all.dtype), kr_all,
        preferred_element_type=jnp.float32)
    scores = scores / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)
    s_max = c_all.shape[1]
    mask = jnp.arange(s_max)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs.astype(c_all.dtype), c_all,
                         preferred_element_type=jnp.float32)
    ctx = jnp.einsum("bhr,rhv->bhv", ctx_lat.astype(cdt), w_v,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(b, 1, h * m.v_dim).astype(cdt)
    out = matmul(ctx, params["wo"], cdt).astype(x.dtype)
    return out, cache


__all__ = [
    "gqa_init", "gqa_batch", "gqa_decode", "gqa_init_cache",
    "mla_init", "mla_batch", "mla_decode", "mla_init_cache",
    "quantize_kv", "dequantize_kv",
]
