"""Shared neural-net building blocks (pure functional JAX, no framework).

Parameters are nested dicts of jnp arrays.  Initialisers take a PRNG key and
return the param tree; apply functions are pure.  Dtype policy: params are
created in cfg.precision.param_dtype; matmuls run in compute_dtype with f32
accumulation (preferred_element_type); norms/softmax in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------


def dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16, "int8": jnp.int8}[name]


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def matmul(x, w, compute_dtype):
    return jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp_apply(params, x, compute_dtype):
    g = matmul(x, params["w_gate"], compute_dtype)
    u = matmul(x, params["w_up"], compute_dtype)
    h = (jax.nn.silu(g) * u).astype(compute_dtype)
    return matmul(h, params["w_down"], compute_dtype).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, H, S, Dh) (Dh even), positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
        angles = angles[None, None, :, :]  # (1,1,S,dh/2)
    else:
        angles = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
