"""Model assembly: heterogeneous decoder stacks (+ enc-dec) with scan.

Depth discipline: layers are grouped into STAGES of repeating periods
(jamba: 4 repeats x 8-layer period; deepseek-v3: 3 dense layers then 58
identical MoE layers; dense archs: n_layers x 1-layer period).  Parameters
for a stage are stacked over the repeat axis and applied with lax.scan, so
HLO size and compile time are O(period), not O(depth) — essential for the
40-cell x 512-device dry-run matrix.

Three entry points:
  forward(...)      train/prefill logits (+ MoE aux loss)
  prefill(...)      forward + populated decode caches
  decode_step(...)  one-token step updating caches (scan over repeats, too)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, ParallelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import hashed_embedding as hemb
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    dense_init, dt, embed_init, matmul, mlp_apply, mlp_init, rmsnorm,
    rmsnorm_init,
)


@dataclass(frozen=True)
class Stage:
    specs: tuple[LayerSpec, ...]
    n_repeat: int


def build_stages(cfg: ModelConfig) -> tuple[Stage, ...]:
    all_layers = cfg.all_layers()
    stages: list[Stage] = []
    i = cfg.first_k_dense
    if i:
        stages.append(Stage(all_layers[:i], 1))
    rest = all_layers[i:]
    p = len(cfg.layer_pattern)
    if rest:
        if len(rest) % p:
            # fall back to a single unrolled stage
            stages.append(Stage(tuple(rest), 1))
        else:
            stages.append(Stage(tuple(rest[:p]), len(rest) // p))
    return tuple(stages)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": attn.gqa_init,
    "mla": attn.mla_init,
    "mamba": mamba_mod.mamba_init,
    "mlstm": xlstm_mod.mlstm_init,
    "slstm": xlstm_mod.slstm_init,
}
_MIXER_KEY = {"attn": "attn", "mla": "attn", "mamba": "mamba",
              "mlstm": "lstm", "slstm": "lstm"}
_MIXER_BATCH = {
    "attn": attn.gqa_batch,
    "mla": attn.mla_batch,
    "mamba": mamba_mod.mamba_batch,
    "mlstm": xlstm_mod.mlstm_batch,
    "slstm": xlstm_mod.slstm_batch,
}
_MIXER_DECODE = {
    "attn": attn.gqa_decode,
    "mla": attn.mla_decode,
    "mamba": mamba_mod.mamba_decode,
    "mlstm": xlstm_mod.mlstm_decode,
    "slstm": xlstm_mod.slstm_decode,
}
_MIXER_CACHE = {
    "attn": attn.gqa_init_cache,
    "mla": attn.mla_init_cache,
    "mamba": mamba_mod.mamba_init_cache,
    "mlstm": xlstm_mod.mlstm_init_cache,
    "slstm": xlstm_mod.slstm_init_cache,
}


def _layer_init(cfg: ModelConfig, spec: LayerSpec, key, cross_attn=False):
    pdt = dt(cfg.precision.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": rmsnorm_init(cfg.d_model, pdt),
         _MIXER_KEY[spec.mixer]: _MIXER_INIT[spec.mixer](cfg, k1)}
    if cross_attn:
        p["norm_x"] = rmsnorm_init(cfg.d_model, pdt)
        p["xattn"] = attn.gqa_init(cfg, k4)
    if spec.mlp != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, pdt)
        if spec.mlp == "dense":
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, pdt)
        else:
            p["moe"] = moe_mod.moe_init(cfg, k3)
    return p


def _project_cross_kv(cfg, p_x, enc_out):
    cdt = dt(cfg.precision.compute_dtype)
    b, t, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    k = matmul(enc_out, p_x["wk"], cdt).reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    v = matmul(enc_out, p_x["wv"], cdt).reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    return k.astype(cdt), v.astype(cdt)


def _layer_batch(cfg, spec, p, x, positions, pcfg: ParallelConfig,
                 enc_kv=None):
    mixer_key = _MIXER_KEY[spec.mixer]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer in ("attn", "mla"):
        out, _ = _MIXER_BATCH[spec.mixer](cfg, p[mixer_key], h, positions,
                                          impl=pcfg.attention_impl)
    else:
        out, _ = _MIXER_BATCH[spec.mixer](cfg, p[mixer_key], h, positions)
    x = x + out
    if enc_kv is not None and "xattn" in p:
        _, enc_out, _ = enc_kv
        kv = _project_cross_kv(cfg, p["xattn"], enc_out)
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        out, _ = attn.gqa_batch(cfg, p["xattn"], h, positions,
                                impl=pcfg.attention_impl, kv_override=kv,
                                rope=False)
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp != "none":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.mlp == "dense":
            x = x + mlp_apply(p["mlp"], h, dt(cfg.precision.compute_dtype))
        else:
            out, aux = moe_mod.moe_apply(cfg, p["moe"], h)
            x = x + out
    if pcfg.sequence_parallel:
        x = constrain(x, "dp", "model", None)
    return x, aux


def _layer_decode(cfg, spec, p, x, cache, pos, enc_kv=None):
    mixer_key = _MIXER_KEY[spec.mixer]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    out, new_cache = _MIXER_DECODE[spec.mixer](cfg, p[mixer_key], h,
                                               cache["mixer"], pos)
    x = x + out
    new_entry = {"mixer": new_cache}
    if enc_kv is not None and "xattn" in p:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        # cross attention over fixed encoder KV (no cache update)
        out, _ = attn.gqa_batch(cfg, p["xattn"], h,
                                jnp.zeros((1,), jnp.int32),
                                impl="ref", kv_override=enc_kv, rope=False)
        x = x + out
    if spec.mlp != "none":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.mlp == "dense":
            x = x + mlp_apply(p["mlp"], h, dt(cfg.precision.compute_dtype))
        else:
            out, _ = moe_mod.moe_apply(cfg, p["moe"], h)
            x = x + out
    return x, new_entry


def _layer_cache(cfg, spec, batch, max_len, quantized):
    return {"mixer": _MIXER_CACHE[spec.mixer](cfg, batch, max_len, quantized)}


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    pdt = dt(cfg.precision.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict = {}
    if cfg.hashed_embedding:
        params["hashed_embed"] = hemb.hashed_embed_init(cfg, keys[0])
    else:
        params["embed"] = {"table": embed_init(keys[0], cfg.vocab_size,
                                               cfg.d_model, pdt)}
    stages = build_stages(cfg)
    stage_params = []
    for si, stage in enumerate(stages):
        def init_one(k):
            ks = jax.random.split(k, len(stage.specs))
            return {f"l{i}": _layer_init(cfg, spec, ks[i])
                    for i, spec in enumerate(stage.specs)}
        rep_keys = jax.random.split(jax.random.fold_in(keys[1], si),
                                    stage.n_repeat)
        stage_params.append(jax.vmap(init_one)(rep_keys))
    params["stages"] = stage_params
    params["final_norm"] = rmsnorm_init(cfg.d_model, pdt)
    if not cfg.tie_embeddings and not cfg.hashed_embedding:
        params["lm_head"] = dense_init(keys[2], cfg.d_model, cfg.vocab_size, pdt)

    if cfg.kind == "encdec":
        enc_spec = LayerSpec(mixer="attn", mlp="dense")
        def init_enc(k):
            return {"l0": _layer_init(cfg, enc_spec, k)}
        enc_keys = jax.random.split(keys[3], cfg.n_encoder_layers)
        params["encoder"] = {
            "stage": jax.vmap(init_enc)(enc_keys),
            "final_norm": rmsnorm_init(cfg.d_model, pdt),
        }
        # decoder layers additionally get cross-attention
        def init_one_x(k):
            ks = jax.random.split(k, len(stages[0].specs))
            return {f"l{i}": _layer_init(cfg, spec, ks[i], cross_attn=True)
                    for i, spec in enumerate(stages[0].specs)}
        rep_keys = jax.random.split(keys[4], stages[0].n_repeat)
        params["stages"] = [jax.vmap(init_one_x)(rep_keys)]
    return params


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    if cfg.hashed_embedding:
        return hemb.hashed_embed(cfg, params["hashed_embed"], tokens)
    return jnp.take(params["embed"]["table"], tokens, axis=0)


def lm_logits(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    cdt = dt(cfg.precision.compute_dtype)
    if cfg.hashed_embedding:
        logits = hemb.hashed_logits(cfg, params["hashed_embed"], x)
    elif cfg.tie_embeddings:
        table = params["embed"]["table"].astype(cdt)
        logits = jax.lax.dot_general(
            x.astype(cdt), table.T, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        logits = matmul(x, params["lm_head"], cdt)
    logits = constrain(logits, "dp", None, "model")
    return logits.astype(dt(cfg.precision.logits_dtype))


# ---------------------------------------------------------------------------
# forward (train / prefill logits)
# ---------------------------------------------------------------------------


def _stage_scan(cfg, stage: Stage, stage_params, x, positions, pcfg,
                enc_kv=None, remat=True):
    def body(carry, rep_params):
        h, aux = carry
        for i, spec in enumerate(stage.specs):
            h, a = _layer_batch(cfg, spec, rep_params[f"l{i}"], h, positions,
                                pcfg, enc_kv=enc_kv)
            aux = aux + a
        return (h, aux), None

    if remat and pcfg.remat == "dots":
        # save matmul/collective outputs, recompute elementwise only:
        # trades HBM for the backward re-gather traffic (Perf cell A it7)
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat and pcfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    carry = (x, jnp.zeros((), jnp.float32))
    if pcfg.unroll_scan:
        for r in range(stage.n_repeat):
            rep = jax.tree_util.tree_map(lambda a: a[r], stage_params)
            carry, _ = body(carry, rep)
        x, aux = carry
        return x, aux
    (x, aux), _ = jax.lax.scan(body, carry, stage_params)
    return x, aux


def _run_encoder(cfg, params, frontend, pcfg):
    enc_spec = LayerSpec(mixer="attn", mlp="dense")
    x = frontend.astype(dt(cfg.precision.compute_dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, rep_params):
        h = carry
        hh = rmsnorm(rep_params["l0"]["norm1"], h, cfg.norm_eps)
        out, _ = attn.gqa_batch(cfg, rep_params["l0"]["attn"], hh, positions,
                                causal=False, impl=pcfg.attention_impl)
        h = h + out
        hh = rmsnorm(rep_params["l0"]["norm2"], h, cfg.norm_eps)
        h = h + mlp_apply(rep_params["l0"]["mlp"], hh,
                          dt(cfg.precision.compute_dtype))
        return h, None

    stage = params["encoder"]["stage"]
    if pcfg.unroll_scan:
        n_rep = jax.tree_util.tree_leaves(stage)[0].shape[0]
        for r in range(n_rep):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[r], stage))
    else:
        x, _ = jax.lax.scan(body, x, stage)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch: dict,
            pcfg: ParallelConfig = ParallelConfig()):
    """batch: {'tokens': (B, S_text)[, 'frontend': (B, P, D)]}.

    Returns (logits (B, S_total, V), aux_loss scalar).
    """
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    enc_kv = None
    if cfg.kind == "encdec":
        enc_out = _run_encoder(cfg, params, batch["frontend"], pcfg)
        # precompute nothing: cross-attn projects per layer from enc_out
        enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        enc_kv = ("enc_out", enc_out, enc_positions)
    elif cfg.frontend is not None and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    x = constrain(x, "dp", None, None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    total_aux = jnp.zeros((), jnp.float32)
    for stage, sp in zip(build_stages(cfg), params["stages"]):
        x, aux = _stage_scan(cfg, stage, sp, x, positions, pcfg, enc_kv=enc_kv)
        total_aux = total_aux + aux
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(cfg, params, x), total_aux


# ---------------------------------------------------------------------------
# caches / prefill / decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                kv_dtype: str = "bfloat16") -> list:
    quantized = kv_dtype == "int8"
    caches = []
    for stage in build_stages(cfg):
        entry = {f"l{i}": _layer_cache(cfg, spec, batch, max_len, quantized)
                 for i, spec in enumerate(stage.specs)}
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (stage.n_repeat,) + a.shape),
            entry)
        caches.append(stacked)
    return caches


def decode_step(cfg: ModelConfig, params, caches: list, tokens: jnp.ndarray,
                pos, pcfg: ParallelConfig = ParallelConfig(), enc_out=None):
    """tokens: (B, 1) int32; pos: scalar int32 current position.

    Returns (logits (B, 1, V), new_caches).
    """
    x = embed_tokens(cfg, params, tokens)
    enc_kv = None
    if cfg.kind == "encdec" and enc_out is not None:
        enc_kv = ("raw", enc_out, jnp.arange(enc_out.shape[1], dtype=jnp.int32))
    new_caches = []
    for stage, sp, cache in zip(build_stages(cfg), params["stages"], caches):
        def body(carry, xs):
            h = carry
            rep_params, rep_cache = xs
            new_entries = {}
            for i, spec in enumerate(stage.specs):
                kv = None
                if enc_kv is not None:
                    _, eo, _ = enc_kv
                    kv = _project_cross_kv(cfg, rep_params[f"l{i}"]["xattn"], eo)
                h, entry = _layer_decode(cfg, spec, rep_params[f"l{i}"], h,
                                         rep_cache[f"l{i}"], pos,
                                         enc_kv=kv)
                new_entries[f"l{i}"] = entry
            return h, new_entries

        if pcfg.unroll_scan:
            outs = []
            for r in range(stage.n_repeat):
                xs_r = jax.tree_util.tree_map(lambda a: a[r], (sp, cache))
                x, upd = body(x, xs_r)
                outs.append(upd)
            updated = jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves), *outs)
        else:
            x, updated = jax.lax.scan(body, x, (sp, cache))
        new_caches.append(updated)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(cfg, params, x), new_caches


def prefill(cfg: ModelConfig, params, batch: dict, max_len: int,
            pcfg: ParallelConfig = ParallelConfig(),
            kv_dtype: str = "bfloat16"):
    """Run the batch path token-by-token-free prefill, returning logits and
    caches seeded with the prompt.  Implementation: run forward() for the
    logits, then replay per-layer batch mixers to collect K/V/state (memory
    identical to forward; double compute is accepted on the serving prefill
    path off-TPU — the pallas path fuses this on real hardware)."""
    logits, _ = forward(cfg, params, batch, pcfg)
    caches = init_caches(cfg, batch["tokens"].shape[0], max_len, kv_dtype)
    x = embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend is not None and cfg.kind != "encdec" and "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    s = x.shape[1]
    quantized = kv_dtype == "int8"
    new_caches = []
    for stage, sp, cache in zip(build_stages(cfg), params["stages"], caches):
        def body(carry, xs):
            h = carry
            rep_params, rep_cache = xs
            new_entries = {}
            for i, spec in enumerate(stage.specs):
                p_l = rep_params[f"l{i}"]
                hh = rmsnorm(p_l["norm1"], h, cfg.norm_eps)
                mixer_key = _MIXER_KEY[spec.mixer]
                out, state = _MIXER_BATCH[spec.mixer](
                    cfg, p_l[mixer_key], hh, positions)
                h = h + out
                entry = dict(rep_cache[f"l{i}"])
                mc = dict(entry["mixer"])
                if spec.mixer == "attn":
                    k_new, v_new = state
                    if quantized:
                        kq, ks = attn.quantize_kv(k_new)
                        vq, vs = attn.quantize_kv(v_new)
                        mc["k"] = jax.lax.dynamic_update_slice(
                            mc["k"], kq, (0, 0, 0, 0))
                        mc["v"] = jax.lax.dynamic_update_slice(
                            mc["v"], vq, (0, 0, 0, 0))
                        mc["k_scale"] = jax.lax.dynamic_update_slice(
                            mc["k_scale"], ks, (0, 0, 0, 0))
                        mc["v_scale"] = jax.lax.dynamic_update_slice(
                            mc["v_scale"], vs, (0, 0, 0, 0))
                    else:
                        mc["k"] = jax.lax.dynamic_update_slice(
                            mc["k"], k_new.astype(mc["k"].dtype), (0, 0, 0, 0))
                        mc["v"] = jax.lax.dynamic_update_slice(
                            mc["v"], v_new.astype(mc["v"].dtype), (0, 0, 0, 0))
                elif spec.mixer == "mla":
                    c_kv, k_rope = state
                    if quantized:
                        cq, cs = attn.quantize_kv(c_kv)
                        mc["c_kv"] = jax.lax.dynamic_update_slice(
                            mc["c_kv"], cq, (0, 0, 0))
                        mc["c_scale"] = jax.lax.dynamic_update_slice(
                            mc["c_scale"], cs, (0, 0, 0))
                    else:
                        mc["c_kv"] = jax.lax.dynamic_update_slice(
                            mc["c_kv"], c_kv.astype(mc["c_kv"].dtype), (0, 0, 0))
                    mc["k_rope"] = jax.lax.dynamic_update_slice(
                        mc["k_rope"], k_rope.astype(mc["k_rope"].dtype),
                        (0, 0, 0))
                else:
                    mc = jax.tree_util.tree_map(
                        lambda _, s_new: s_new.astype(_.dtype), mc, state)
                entry["mixer"] = mc
                new_entries[f"l{i}"] = entry
                if spec.mlp == "dense":
                    hh = rmsnorm(p_l["norm2"], h, cfg.norm_eps)
                    h = h + mlp_apply(p_l["mlp"], hh,
                                      dt(cfg.precision.compute_dtype))
                elif spec.mlp == "moe":
                    hh = rmsnorm(p_l["norm2"], h, cfg.norm_eps)
                    out, _ = moe_mod.moe_apply(cfg, p_l["moe"], hh)
                    h = h + out
            return h, new_entries

        x, updated = jax.lax.scan(body, x, (sp, cache))
        new_caches.append(updated)
    return logits, new_caches
