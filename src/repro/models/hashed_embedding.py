"""CabinEmbed: hashed vocabulary embeddings built on the paper's machinery.

Token ids are categorical values; BinSketch's random attribute map pi gives
k independent bucket assignments per id and the BinEm-style sign hash psi
gives a Rademacher sign per (id, repetition):

    embed(t) = (1/sqrt(k)) * sum_j sign_j(t) * table[pi_j(t)]

This shrinks a (V, D) table to (n_buckets, D) with V-independent size — the
same "dimension depends on density, not on the ambient dimension" property
the paper proves for Cabin sketches, applied to the embedding matrix.  The
tied output head uses the transposed trick: y = x @ table^T (B,S,buckets),
then logits[t] = sum_j sign_j(t) * y[pi_j(t)].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.configs.base import ModelConfig
from repro.models.layers import dt


def _bucket_and_sign(cfg: ModelConfig, token_ids: jnp.ndarray, j: int):
    nb = cfg.hashed_embedding_buckets
    t = token_ids.astype(jnp.uint32)
    bucket = hashing.pi_buckets(t, nb, seed=811 + j)
    sign = hashing.rademacher(t, seed=911 + j)
    return bucket, sign


def hashed_embed_init(cfg: ModelConfig, key) -> dict:
    nb = cfg.hashed_embedding_buckets
    pdt = dt(cfg.precision.param_dtype)
    table = (jax.random.normal(key, (nb, cfg.d_model), jnp.float32) * 0.02
             ).astype(pdt)
    return {"table": table}


def hashed_embed(cfg: ModelConfig, params, token_ids: jnp.ndarray) -> jnp.ndarray:
    """token_ids (B, S) -> embeddings (B, S, D)."""
    k = cfg.hashed_embedding_k
    out = None
    table = params["table"]
    for j in range(k):
        bucket, sign = _bucket_and_sign(cfg, token_ids, j)
        e = jnp.take(table, bucket, axis=0).astype(jnp.float32)
        e = e * sign[..., None]
        out = e if out is None else out + e
    return (out / (k ** 0.5)).astype(table.dtype)


def hashed_logits(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied output head: x (B, S, D) -> logits (B, S, V)."""
    k = cfg.hashed_embedding_k
    cdt = dt(cfg.precision.compute_dtype)
    table = params["table"].astype(cdt)
    y = jax.lax.dot_general(
        x.astype(cdt), table.T, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (B, S, n_buckets)
    vocab = jnp.arange(cfg.vocab_size, dtype=jnp.uint32)
    logits = None
    for j in range(k):
        bucket, sign = _bucket_and_sign(cfg, vocab, j)
        lj = jnp.take(y, bucket, axis=-1) * sign
        logits = lj if logits is None else logits + lj
    return logits / (k ** 0.5)
