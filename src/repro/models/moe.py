"""Mixture-of-Experts layer: top-k routing with capacity, GShard-style
one-hot dispatch/combine einsums (the GSPMD-proven formulation).

Memory discipline: tokens are reshaped into dispatch GROUPS of
`moe.group_size` tokens so the (S_g, E, C) dispatch tensor stays bounded
regardless of batch x seq (DESIGN.md section 5) — capacity C is computed per
group.  Experts live on the 'model' mesh axis (expert parallelism); the
dispatch einsum therefore lowers to the expected all-to-all style
collectives under pjit.

DeepSeek-V3 extras supported: `num_shared_experts` dense experts applied to
every token, and first_k_dense layers handled by the stack (configs).
Router uses softmax gating + Switch-style load-balance aux loss (dsv3's
sigmoid+bias-free balancing is noted as a deviation in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.layers import dense_init, dt, matmul, mlp_init, mlp_apply


def moe_init(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    pdt = dt(cfg.precision.param_dtype)
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(k_experts, 3)
    params = {
        "router": dense_init(k_router, d, e, jnp.float32),  # router in f32
        "w_gate": (jax.random.normal(ks[0], (e, d, f), jnp.float32)
                   * (1.0 / d) ** 0.5).astype(pdt),
        "w_up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * (1.0 / d) ** 0.5).astype(pdt),
        "w_down": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                   * (1.0 / f) ** 0.5).astype(pdt),
    }
    if m.num_shared_experts:
        params["shared"] = mlp_init(
            k_shared, d, f * m.num_shared_experts, pdt)
    return params


def _dispatch_groups(cfg: ModelConfig, params, x, capacity: int):
    """x: (G, S, D) dispatch groups. Returns (out (G, S, D), aux_loss).

    Explicit group-batched einsums (no vmap) so the sharding constraints
    below reach GSPMD: groups stay on their data shard ('dp'), the expert
    dim lives on 'model', contraction dims are UNSHARDED.  Without these
    constraints XLA propagates the sequence-parallel 'model' sharding into
    the dispatch contractions and all-reduces dispatch-sized tensors every
    layer — the dominant term of the deepseek-v3 baseline (EXPERIMENTS.md
    section Perf, cell A iteration 2).
    """
    m = cfg.moe
    cdt = dt(cfg.precision.compute_dtype)
    gn, s, d = x.shape
    e, k = m.num_experts, m.top_k

    ddt = dt(m.dispatch_dtype)
    x = constrain(x, "dp", None, None)  # gather SP shards once for routing
    logits = matmul(x, params["router"], jnp.float32)  # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # Positions within each expert's capacity buffer, assigned in slot-major
    # order per group: slot 0 for all tokens, then slot 1 (GShard).
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (G, S, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(gn, k * s, e)  # slot-major
    pos_flat = jnp.cumsum(flat, axis=1) - flat  # (G, k*S, E)
    pos = pos_flat.reshape(gn, k, s, e).transpose(0, 2, 1, 3)  # (G, S, k, E)
    pos = jnp.sum(pos * onehot, axis=-1)  # (G, S, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine (G, S, E, C); dtype is a traffic knob (position math
    # above stays f32 for exactness)
    onehot_d = onehot.astype(ddt)
    pos_oh = (jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
              * keep[..., None]).astype(ddt)
    disp = jnp.einsum("gske,gskc->gsec", onehot_d, pos_oh)  # {0,1}
    comb = jnp.einsum("gske,gskc,gsk->gsec", onehot_d, pos_oh,
                      gate_vals.astype(ddt))
    disp = constrain(disp, "dp", None, "model", None)
    comb = constrain(comb, "dp", None, "model", None)

    xe = jnp.einsum("gsd,gsec->gecd", x.astype(ddt), disp).astype(cdt)
    xe = constrain(xe, "dp", "model", None, None)
    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(cdt),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(cdt),
                   preferred_element_type=jnp.float32)
    h = constrain((jax.nn.silu(g) * u).astype(cdt),
                  "dp", "model", None, None)
    eo = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(cdt),
                    preferred_element_type=jnp.float32)
    eo = constrain(eo.astype(ddt), "dp", "model", None, None)
    out = jnp.einsum("gecd,gsec->gsd", eo, comb,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    # reduce over the model-sharded expert dim lands as reduce-scatter back
    # into the sequence-parallel layout:
    out = constrain(out, "dp", "model", None)

    # Switch-style aux loss: E * sum_e f_e * p_e
    f_e = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))  # top-1 routing fraction
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    return out, aux


def moe_apply(cfg: ModelConfig, params, x) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    g_size = min(m.group_size, b * s)
    # pad to a multiple of the group size
    pad = (-tokens.shape[0]) % g_size
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, d), tokens.dtype)], axis=0)
    groups = tokens.reshape(-1, g_size, d)
    capacity = max(1, int(g_size * m.top_k * m.capacity_factor / m.num_experts))

    out, aux_loss = _dispatch_groups(cfg, params, groups, capacity)
    out = out.reshape(-1, d)[: b * s].reshape(b, s, d)

    if m.num_shared_experts:
        out = out + mlp_apply(params["shared"], x,
                              dt(cfg.precision.compute_dtype))
    return out, aux_loss * m.router_aux_weight
