"""xLSTM mixers: mLSTM (matrix memory, exp gating) and sLSTM (scalar memory).

Faithful to the xLSTM paper's cell equations (exponential input gate,
sigmoid/exp forget gate, max-stabiliser state m, normaliser state n):

  mLSTM:  C_t = f C_{t-1} + i v_t k_t^T,  n_t = f n_{t-1} + i k_t,
          h_t = o * (C_t q_t) / max(|n_t . q_t|, 1)
  sLSTM:  c_t = f c_{t-1} + i z_t,        n_t = f n_{t-1} + i,
          h_t = o * c_t / n_t            (per-head recurrent R weights)

Both run as lax.scan over time (state O(B*H*dh^2) resp. O(B*d)); decode is a
single recurrent step — this is what makes long_500k O(1)-per-token for the
ssm family.  Stabilisation follows Appendix A of the paper: all gate math in
f32 with running log-max m_t.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, dt, matmul


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    """(inner_dim, head_dim) with the xLSTM up-projection factor."""
    pf = cfg.xlstm.proj_factor if cfg.xlstm is not None else 2.0
    inner = int(pf * cfg.d_model)
    return inner, inner // cfg.n_heads


def mlstm_init(cfg: ModelConfig, key) -> dict:
    """xLSTM mLSTM block: up-projection (x2, with a gating branch),
    BLOCK-DIAGONAL per-head q/k/v (the paper's parameter-efficient form),
    matrix-memory cell, down-projection."""
    d, h = cfg.d_model, cfg.n_heads
    inner, dh = _mlstm_dims(cfg)
    pdt = dt(cfg.precision.param_dtype)
    ks = jax.random.split(key, 7)

    def headwise(k):
        return (jax.random.normal(k, (h, dh, dh), jnp.float32)
                * (1.0 / dh) ** 0.5).astype(pdt)

    return {
        "w_up": dense_init(ks[0], d, inner, pdt),
        "w_z": dense_init(ks[1], d, inner, pdt),  # gating branch
        "wq": headwise(ks[2]),
        "wk": headwise(ks[3]),
        "wv": headwise(ks[4]),
        "w_i": dense_init(ks[5], d, h, pdt),
        "w_f": dense_init(ks[6], d, h, pdt),
        "w_down": dense_init(jax.random.fold_in(ks[0], 7), inner, d, pdt),
    }


def _mlstm_qkv_gates(cfg, params, x):
    cdt = dt(cfg.precision.compute_dtype)
    b, s, _ = x.shape
    h = cfg.n_heads
    inner, dh = _mlstm_dims(cfg)
    xm = matmul(x, params["w_up"], cdt).reshape(b, s, h, dh).astype(cdt)
    q = jnp.einsum("bshd,hde->bshe", xm, params["wq"].astype(cdt),
                   preferred_element_type=jnp.float32) / (dh ** 0.5)
    k = jnp.einsum("bshd,hde->bshe", xm, params["wk"].astype(cdt),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bshd,hde->bshe", xm, params["wv"].astype(cdt),
                   preferred_element_type=jnp.float32)
    i_pre = matmul(x, params["w_i"], cdt)  # (B,S,H) log-space input gate
    f_pre = matmul(x, params["w_f"], cdt)  # (B,S,H)
    z = matmul(x, params["w_z"], cdt)  # (B,S,inner) output gating branch
    return (q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), i_pre.astype(jnp.float32),
            f_pre.astype(jnp.float32), z.astype(jnp.float32))


def _mlstm_step(state, inp):
    c, n, m = state  # (B,H,dh,dh), (B,H,dh), (B,H)
    qt, kt, vt, it, ft = inp  # (B,H,dh) x3, (B,H) x2
    log_f = -jax.nn.softplus(-ft)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, it)
    i_s = jnp.exp(it - m_new)[..., None]  # (B,H,1)
    f_s = jnp.exp(log_f + m - m_new)[..., None]
    c = f_s[..., None] * c + i_s[..., None] * vt[..., :, None] * kt[..., None, :]
    n = f_s * n + i_s * kt
    num = jnp.einsum("bhvk,bhk->bhv", c, qt)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
    h_t = num / den[..., None]
    return (c, n, m_new), h_t


def mlstm_batch(cfg: ModelConfig, params, x, positions=None):
    cdt = dt(cfg.precision.compute_dtype)
    b, s, d = x.shape
    h = cfg.n_heads
    inner, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z = _mlstm_qkv_gates(cfg, params, x)
    # reorder (B,S,H,*) -> (S,B,H,*) for the time scan
    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
          jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(f_pre, 1, 0))
    state0 = (
        jnp.zeros((b, h, dh, dh), jnp.float32),
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    state, hs = jax.lax.scan(_mlstm_step, state0, xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, inner)  # (B,S,inner)
    y = y * jax.nn.silu(z)  # output gating branch
    out = matmul(y.astype(cdt), params["w_down"], cdt).astype(x.dtype)
    return out, {"c": state[0], "n": state[1], "m": state[2]}


def mlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int, quantized: bool):
    h = cfg.n_heads
    _, dh = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(cfg: ModelConfig, params, x, cache: dict, pos=None):
    cdt = dt(cfg.precision.compute_dtype)
    b = x.shape[0]
    inner, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z = _mlstm_qkv_gates(cfg, params, x)
    state = (cache["c"], cache["n"], cache["m"])
    state, h_t = _mlstm_step(state, (q[:, 0], k[:, 0], v[:, 0],
                                     i_pre[:, 0], f_pre[:, 0]))
    y = h_t.reshape(b, 1, inner) * jax.nn.silu(z)
    out = matmul(y.astype(cdt), params["w_down"], cdt).astype(x.dtype)
    return out, {"c": state[0], "n": state[1], "m": state[2]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    pdt = dt(cfg.precision.param_dtype)
    ks = jax.random.split(key, 9)
    p = {}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}" if g == "z" else f"w_{g}"] = dense_init(ks[i], d, d, pdt)
        p[f"r_{g}"] = dense_init(ks[4 + i], d, d, pdt, scale=0.5 / d ** 0.5)
    p["out_proj"] = dense_init(ks[8], d, d, pdt)
    return p


def _slstm_step(cfg, params, state, xt):
    """state: (c, n, m, h_prev) each (B, d); xt: (B, d) f32 pre-acts dict."""
    c, n, m, h_prev = state
    cdt = dt(cfg.precision.compute_dtype)

    def pre(wname, rname):
        return (xt[wname]
                + matmul(h_prev.astype(cdt), params[rname], cdt))

    z = jnp.tanh(pre("wz", "r_z"))
    i_pre = pre("w_i", "r_i")
    f_pre = pre("w_f", "r_f")
    o = jax.nn.sigmoid(pre("w_o", "r_o"))
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h), h


def _slstm_preacts(cfg, params, x):
    cdt = dt(cfg.precision.compute_dtype)
    return {name: matmul(x, params[name], cdt)
            for name in ("wz", "w_i", "w_f", "w_o")}


def slstm_batch(cfg: ModelConfig, params, x, positions=None):
    cdt = dt(cfg.precision.compute_dtype)
    b, s, d = x.shape
    pre = _slstm_preacts(cfg, params, x)
    xs = {k2: jnp.moveaxis(v, 1, 0) for k2, v in pre.items()}
    state0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
              jnp.full((b, d), -1e30, jnp.float32), jnp.zeros((b, d), jnp.float32))
    state, hs = jax.lax.scan(
        lambda st, xt: _slstm_step(cfg, params, st, xt), state0, xs)
    y = jnp.moveaxis(hs, 0, 1)
    out = matmul(y.astype(cdt), params["out_proj"], cdt).astype(x.dtype)
    return out, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}


def slstm_init_cache(cfg: ModelConfig, batch: int, max_len: int, quantized: bool):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_decode(cfg: ModelConfig, params, x, cache: dict, pos=None):
    cdt = dt(cfg.precision.compute_dtype)
    pre = _slstm_preacts(cfg, params, x)
    xt = {k2: v[:, 0] for k2, v in pre.items()}
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    state, h = _slstm_step(cfg, params, state, xt)
    out = matmul(h[:, None].astype(cdt), params["out_proj"], cdt).astype(x.dtype)
    return out, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
