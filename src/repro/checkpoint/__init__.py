"""Checkpointing."""
