"""Checkpointing: sharded-tree save/restore with async writes, retention,
and elastic resharding across meshes.

Layout per step:  <dir>/step_<n>/arrays.npz  +  meta.json
Arrays are keyed by their tree path; meta.json stores the path list, shapes,
dtypes and step.  In this single-controller container each checkpoint holds
the full (host-gathered) arrays; on a multi-host deployment `save` is called
with each host's addressable shards and the same layout holds per-host files
(process_index suffix) — the restore/reshard path below is identical either
way because restore produces host arrays that are device_put under the
TARGET mesh's shardings.  That device_put-with-new-shardings IS elastic
resharding: a checkpoint written under mesh A (e.g. 16x16) restores cleanly
onto mesh B (e.g. 2x16x16 or a degraded 8x16) — covered by tests.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(leaf)
    return flat


def flat_to_tree(flat: dict[str, np.ndarray], like):
    paths = [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [flat[p] for p in paths]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- steps --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                os.path.join(self.directory, name, "meta.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None,
             block: bool = False) -> None:
        self.wait()  # one outstanding async save at a time
        flat = tree_to_flat(tree)  # host copy happens synchronously

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            meta = {
                "step": step,
                "time": time.time(),
                "paths": sorted(flat.keys()),
                **(extra_meta or {}),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if self.async_save and not block:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of `like`; device_put under `shardings`
        (a matching tree of NamedSharding) if given — this is the elastic
        reshard path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            flat = {k: data[k] for k in data.files}
        tree = flat_to_tree(flat, like)
        tree = jax.tree_util.tree_map(
            lambda ref, a: np.asarray(a, dtype=ref.dtype)
            if hasattr(ref, "dtype") else a, like, tree)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}", "meta.json")) as f:
            return json.load(f)
