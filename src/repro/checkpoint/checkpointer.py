"""Checkpointing: sharded-tree save/restore with async writes, retention,
integrity verification, and elastic resharding across meshes.

Layout per step:  <dir>/step_<n>/arrays.npz  +  meta.json
Arrays are keyed by their tree path; meta.json stores the path list, and a
per-array integrity record (CRC32 of the raw bytes, shape, dtype) written at
save and verified at restore.  A mismatch, truncation, or unreadable file
raises `CheckpointCorruptError` naming the step and the array key — and
`restore(step=None)` falls back to the newest INTACT step instead of dying
on a torn latest one, so one bad write never takes recovery down with it.

In this single-controller container each checkpoint holds the full
(host-gathered) arrays; on a multi-host deployment `save` is called with
each host's addressable shards and the same layout holds per-host files
(process_index suffix) — the restore/reshard path below is identical either
way because restore produces host arrays that are device_put under the
TARGET mesh's shardings.  That device_put-with-new-shardings IS elastic
resharding: a checkpoint written under mesh A (e.g. 16x16) restores cleanly
onto mesh B (e.g. 2x16x16 or a degraded 8x16) — covered by tests.

Crash safety: writes land in a `.tmp_step_<n>` staging directory and are
published by one atomic os.rename; a crash mid-save leaves only the staging
dir, which the next Checkpointer construction sweeps.  The save path
carries named fault-injection points (repro.runtime.faultinject) so the
crash-matrix test can kill it at every stage and assert the published-state
invariant rather than assume it.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zipfile
import zlib

import jax
import numpy as np

from repro.runtime import faultinject

# the save path's crash points, in execution order (see module docstring)
_CP_TMP_WRITTEN = faultinject.declare("checkpointer.save.tmp_written")
_CP_ARRAYS_WRITTEN = faultinject.declare("checkpointer.save.arrays_written")
_CP_META_WRITTEN = faultinject.declare("checkpointer.save.meta_written")
_CP_PUBLISHED = faultinject.declare("checkpointer.save.published")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step failed integrity verification.  Carries the step
    and the offending array key (None when the damage is file-level, e.g. a
    truncated archive or unreadable meta.json)."""

    def __init__(self, step: int, key: str | None, reason: str):
        where = f"step {step}" + (f", array {key!r}" if key else "")
        super().__init__(f"corrupt checkpoint at {where}: {reason}")
        self.step = step
        self.key = key


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(leaf)
    return flat


def flat_to_tree(flat: dict[str, np.ndarray], like):
    paths = [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [flat[p] for p in paths]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _array_record(a: np.ndarray) -> dict:
    return {
        "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
        "shape": list(a.shape),
        "dtype": str(a.dtype),
    }


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)
        self._sweep_orphans()

    def _sweep_orphans(self) -> None:
        """Delete `.tmp_step_*` staging dirs left by a crash mid-save.  A
        crashed save can never be resumed (its writer is gone), and leaving
        the orphan around would let a LATER save of the same step blindly
        mix freshly written files with the corpse's."""
        for name in os.listdir(self.directory):
            if name.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    # -- steps --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                os.path.join(self.directory, name, "meta.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def latest_intact_step(self) -> int | None:
        """Newest step that passes full integrity verification (None if no
        step does) — what `restore(step=None)` actually resolves to."""
        for step in reversed(self.all_steps()):
            try:
                self.verify(step)
                return step
            except CheckpointCorruptError:
                continue
        return None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None,
             block: bool = False) -> None:
        self.wait()  # one outstanding async save at a time
        flat = tree_to_flat(tree)  # host copy happens synchronously

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            # never build on a previous attempt's staging files: stale
            # arrays.npz/meta.json from a crashed bigger tree would survive
            # into the published dir otherwise
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            faultinject.crash_point(_CP_TMP_WRITTEN)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            faultinject.crash_point(_CP_ARRAYS_WRITTEN)
            meta = {
                "step": step,
                "time": time.time(),
                "paths": sorted(flat.keys()),
                "arrays": {k: _array_record(v) for k, v in flat.items()},
                **(extra_meta or {}),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            faultinject.crash_point(_CP_META_WRITTEN)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            faultinject.crash_point(_CP_PUBLISHED)
            self._gc()

        if self.async_save and not block:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- integrity ----------------------------------------------------------
    def verify(self, step: int) -> dict[str, np.ndarray]:
        """Load step `step` and verify it against its integrity record:
        every recorded path present, shapes/dtypes matching, CRC32 of the
        raw bytes equal.  Returns the verified flat arrays (so restore pays
        one read, not two).  Raises CheckpointCorruptError naming the step
        and the first offending array key."""
        path = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(step, None,
                                         f"unreadable meta.json ({e})")
        try:
            with np.load(os.path.join(path, "arrays.npz")) as data:
                flat = {k: data[k] for k in data.files}
        except (OSError, ValueError, zipfile.BadZipFile, KeyError) as e:
            # a truncated npz surfaces as BadZipFile or a zlib ValueError
            # mid-member read, depending on where the bytes stop
            raise CheckpointCorruptError(
                step, None, f"unreadable arrays.npz ({e})")
        records = meta.get("arrays")
        for key in meta.get("paths", []):
            if key not in flat:
                raise CheckpointCorruptError(
                    step, key, "array missing from arrays.npz")
            if records is None:
                continue  # pre-integrity snapshot: presence check only
            rec, a = records.get(key), flat[key]
            if rec is None:
                continue
            if list(a.shape) != rec["shape"] or str(a.dtype) != rec["dtype"]:
                raise CheckpointCorruptError(
                    step, key,
                    f"shape/dtype {a.shape}/{a.dtype} != recorded "
                    f"{tuple(rec['shape'])}/{rec['dtype']}")
            crc = zlib.crc32(np.ascontiguousarray(a).tobytes())
            if crc != rec["crc32"]:
                raise CheckpointCorruptError(
                    step, key,
                    f"CRC32 mismatch ({crc:#010x} != {rec['crc32']:#010x})")
        return flat

    # -- restore ------------------------------------------------------------
    def restore(self, like=None, step: int | None = None, shardings=None):
        """Restore into the structure of `like` (or, when `like` is None,
        return the verified flat {path: array} dict as-is); device_put under
        `shardings` (a matching tree of NamedSharding) if given — this is
        the elastic reshard path.

        step=None restores the newest step that passes integrity
        verification, skipping (not deleting) corrupt ones; an explicit
        step that fails verification raises CheckpointCorruptError."""
        if step is None:
            steps = self.all_steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
            flat = None
            first_err: CheckpointCorruptError | None = None
            for s in reversed(steps):
                try:
                    flat, step = self.verify(s), s
                    break
                except CheckpointCorruptError as e:
                    first_err = first_err or e
            if flat is None:
                raise CheckpointCorruptError(
                    first_err.step, first_err.key,
                    f"no intact step in {self.directory} "
                    f"(newest failure: {first_err})")
        else:
            flat = self.verify(step)
        if like is None:
            tree = flat
        else:
            tree = flat_to_tree(flat, like)
            tree = jax.tree_util.tree_map(
                lambda ref, a: np.asarray(a, dtype=ref.dtype)
                if hasattr(ref, "dtype") else a, like, tree)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}", "meta.json")) as f:
            return json.load(f)
