"""repro.cluster: online clustering over the live index (DESIGN.md §9).

The paper's third headline workload (clustering) promoted from a one-shot
batch fit to a subsystem that serves a MUTATING collection: `ClusterIndex`
maintains k-medoid centres and per-row labels over a `repro.index`
QueryEngine/SketchStore, assigning fresh rows as they arrive (through the
engine's own `topk_packed` k=1 serving path), tracking per-cluster
counts/weights through add/remove/compact, refitting on demand with the
device k-mode engine (`core.kmode.kmode_packed`), and surviving
save/restore through `checkpoint.Checkpointer` alongside the store.

Public API:
    ClusterIndex — attach to a QueryEngine (or `engine.cluster(k)`);
                   labels()/label_of()/assign(), counts/weights,
                   refit(n_iter), save/restore
"""

from repro.cluster.online import ClusterIndex  # noqa: F401
