"""ClusterIndex: k-medoid centres and per-row labels over a live index.

`core.kmode.kmode_packed` answers the one-shot question "cluster this
matrix"; a serving system owns a COLLECTION that mutates between questions.
ClusterIndex is the bridge (DESIGN.md section 9.3): it subscribes to the
ENGINE's mutation events (`QueryEngine.subscribe` — the engine relays its
stores' events and knows which store each belongs to, which matters once a
spec migration has several in flight), so rows added through ANY path
(engine.add_dense / add_sparse / add_packed, streaming ingest) are assigned
to their nearest centre the moment they land, removes decrement the cluster
bookkeeping, and compaction is a no-op (labels are keyed by external id,
which compaction preserves).

The data engine's layout topology is invisible here: a `shard(mesh)`-ed
engine emits the same mutation events and answers the same bits
(DESIGN.md section 13), so ClusterIndex works unchanged on a sharded
engine — assignment queries go to the private centres engine, which
stays unsharded (k rows never need scale-out).

Three disciplines, all inherited rather than reinvented:

  * Assignment IS a k-NN query.  Centres live in a private k-row
    QueryEngine; assigning a batch is `topk_packed(k=1)` against it, which
    buys the serving stack's shape bucketing, traced valid counts, and LRU
    for free — and its (value, id)-lex tie-break equals `argmin_rows`'
    first-minimum tie-break because centre ids are centre indices, so
    incremental assignment agrees exactly with what a `refit` would assign
    against the same centres.
  * Refit is deterministic in the membership.  `refit()` gathers the alive
    rows in id order (the store's history-independent canonical order) and
    runs the full-batch device engine with the index's fixed seed: two
    stores holding the same membership — however they got there, including
    through save/restore — refit to identical centres and labels.  The
    property tests pin this.
  * Snapshots ride the store's.  `save` writes the engine snapshot plus a
    `cluster/` Checkpointer tree (centres, label sidecar, counts/weights),
    and `restore` reproduces the exact live state — including labels
    assigned incrementally since the last refit, which a re-fit would not
    reproduce (they depend on arrival order by design).

Between refits, labels of rows added incrementally are path-dependent
(each batch is assigned against the centres of its arrival moment); the
invariance contract applies AFTER `refit()`, which is the point of having
one.  `refit_every=n` auto-refits once n mutations accumulate.

Spec migrations (DESIGN.md section 10) are survived, not merely tolerated:
`refit` captures the medoids' RAW rows (from the engine's archive), so when
the engine emits "migrate_start" the centres are re-sketched under the new
spec — rows arriving mid-migration (new-spec sketches) assign against
new-spec centres, labels/counts carry over unchanged (membership did not
move), and a pending auto-refit is deferred to the "migrate" publish event
(a mid-migration membership spans two sketch spaces and cannot be refit).
A ClusterIndex whose centres predate the raw capture (e.g. restored from a
v1 snapshot) raises at migrate_start with instructions to `refit()` first.
"""

from __future__ import annotations

import os

import numpy as np

from repro import obs
from repro.core.kmode import kmode_packed
from repro.core.packing import pad_rows_pow2, padded_take
from repro.index.engine import QueryEngine
from repro.index.mergeable import MergeIncompatible


class ClusterIndex:
    """Online k-medoid clustering attached to a QueryEngine.

    Parameters
    ----------
    engine : the QueryEngine whose store is being clustered.  The index
        subscribes to the store's mutation events at construction; if the
        store already holds rows, an initial `refit()` runs immediately,
        otherwise the first `add` bootstraps it.
    k : number of clusters (>= 1; k > n is legal — degenerate clusters
        simply stay empty or share duplicate centres, matching kmode).
    seed / n_iter / block : forwarded to `kmode_packed` on every refit —
        fixed at construction so refits are a pure function of membership.
    refit_every : auto-refit after this many mutated rows (None = manual).
    """

    def __init__(self, engine: QueryEngine, k: int, *, seed: int = 0,
                 n_iter: int = 15, block: int = 2048,
                 refit_every: int | None = None):
        if k < 1:
            raise ValueError(f"ClusterIndex: k must be >= 1, got {k}")
        if n_iter < 1:
            raise ValueError(
                f"ClusterIndex: n_iter must be >= 1, got {n_iter}")
        if refit_every is not None and refit_every < 1:
            raise ValueError(
                f"ClusterIndex: refit_every must be >= 1, got {refit_every}")
        self.engine = engine
        self.k = int(k)
        self.seed = int(seed)
        self.n_iter = int(n_iter)
        self.block = int(block)
        self.refit_every = refit_every
        self._centers: np.ndarray | None = None   # (k, w) packed, host
        self._medoid_ids = np.full(self.k, -1, np.int64)
        # medoids' raw COO rows (idx, val), captured at refit — what lets
        # the centres be re-sketched when the engine migrates specs
        self._center_raw: tuple[np.ndarray, np.ndarray] | None = None
        self._centre_engine: QueryEngine | None = None
        self._centre_ids = np.zeros(0, np.int64)
        # label sidecar over the ALIVE rows, ascending by external id (ids
        # are monotone and adds append, so order is maintained for free)
        self._lab_ids = np.zeros(0, np.int64)
        self._lab = np.zeros(0, np.int64)
        self._counts = np.zeros(self.k, np.int64)
        self._weights = np.zeros(self.k, np.int64)
        self.mutations_since_refit = 0
        self.n_refits = 0
        self._refit_pending = False
        self._wire_obs()
        engine.subscribe(self._on_engine_event)
        if len(engine):
            self.refit()

    def _wire_obs(self) -> None:
        """Assignment is a query op like topk/radius: its latency lands in
        the OWNING engine's `engine_query_latency_ms` histogram under
        op="assign" (the private centre engine runs on the null registry —
        its internal hits would pollute the real engine's counters)."""
        self._h_assign = self.engine.obs.histogram(
            "engine_query_latency_ms", op="assign")
        self._c_refits = self.engine.obs.counter("cluster_refits_total")

    def detach(self) -> None:
        """Stop observing the engine.  The engine holds a strong reference
        to every subscriber, so an abandoned index would keep paying a k-NN
        assignment per add forever — detach before replacing one (e.g. to
        change k or seed)."""
        self.engine.unsubscribe(self._on_engine_event)

    # -- introspection ------------------------------------------------------

    @property
    def fitted(self) -> bool:
        return self._centers is not None

    @property
    def counts(self) -> np.ndarray:
        """Alive rows per cluster, (k,) int64 (a copy)."""
        return self._counts.copy()

    @property
    def weights(self) -> np.ndarray:
        """Summed sketch Hamming weight of alive rows per cluster — the
        cheap density signal band planning already mirrors on host."""
        return self._weights.copy()

    @property
    def centers(self) -> np.ndarray:
        """Packed centre rows (k, w) int32 (a copy)."""
        self._require_fit()
        return self._centers.copy()

    @property
    def medoid_ids(self) -> np.ndarray:
        """External id each centre was elected from at the last refit
        (-1 for clusters whose medoid id predates the sidecar, e.g. after
        a restore of an unfitted snapshot)."""
        return self._medoid_ids.copy()

    def labels(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, labels) over the alive rows, ascending by id (copies)."""
        return self._lab_ids.copy(), self._lab.copy()

    def label_of(self, ids) -> np.ndarray:
        """Cluster of each external id; KeyError on unknown/removed ids."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(ids) == 0:
            return np.zeros(0, np.int64)
        n = len(self._lab_ids)
        pos = np.searchsorted(self._lab_ids, ids)
        ok = pos < n
        if n:
            ok &= self._lab_ids[np.minimum(pos, n - 1)] == ids
        if not ok.all():
            raise KeyError(f"id {ids[~ok][0]} not in cluster index")
        return self._lab[pos]

    def stats(self) -> dict:
        return {
            "k": self.k,
            "fitted": self.fitted,
            "n_labeled": len(self._lab_ids),
            "counts": self._counts.tolist(),
            "n_refits": self.n_refits,
            "mutations_since_refit": self.mutations_since_refit,
        }

    # -- assignment (the engine's own serving path) -------------------------

    def _require_fit(self) -> None:
        if self._centers is None:
            raise RuntimeError(
                "ClusterIndex has no centres yet: add rows (the first add "
                "bootstraps a fit) or call refit() on a non-empty store")

    def _ids_to_clusters(self, ids: np.ndarray) -> np.ndarray:
        if ids.shape[1] == 0:  # empty query batch: topk returns (0, 0)
            return np.zeros(ids.shape[0], np.int64)
        return np.searchsorted(self._centre_ids, ids[:, 0]).astype(np.int64)

    def _assign_packed(self, sk, n_valid: int) -> np.ndarray:
        """Nearest-centre labels for packed query rows via the centre
        engine's topk_packed(k=1) — LRU + shape bucketing for free, and the
        (value, id)-lex tie-break equals argmin's first minimum because
        centre ids are centre indices."""
        with self._h_assign.time(), obs.span("cluster.assign",
                                             rows=int(n_valid)):
            ids, _ = self._centre_engine.topk_packed(sk, 1, n_valid=n_valid)
        return self._ids_to_clusters(ids)

    def assign(self, queries) -> np.ndarray:
        """Label raw categorical queries (dense rows or (indices, values)
        COO) WITHOUT ingesting them — the read-only classification path."""
        self._require_fit()
        ids, _ = self._centre_engine.topk(queries, 1)
        return self._ids_to_clusters(ids)

    def assign_packed(self, sk) -> np.ndarray:
        """Pre-sketched twin of `assign` (rows must share the engine's
        CabinParams)."""
        self._require_fit()
        import jax.numpy as jnp

        sk = jnp.asarray(sk)
        return self._assign_packed(pad_rows_pow2(sk), n_valid=sk.shape[0])

    # -- mutation mirror (engine hook) --------------------------------------

    def _bincount(self, lab: np.ndarray, weights=None) -> np.ndarray:
        """bincount over the k clusters, ignoring unlabeled (-1) rows —
        rows added to an UNFITTED index mid-migration carry -1 until the
        deferred bootstrap refit at publish."""
        m = lab >= 0
        return np.bincount(
            lab[m], weights=None if weights is None else weights[m],
            minlength=self.k).astype(np.int64)

    def _on_engine_event(self, event: str, ids: np.ndarray,
                         slots: np.ndarray, store) -> None:
        if event == "add":
            if self._centers is None:
                if self.engine.migrating:
                    # cannot refit a membership spanning two sketch spaces;
                    # bootstrap at publish, rows carry -1 until then
                    self._refit_pending = True
                    lab = np.full(len(ids), -1, np.int64)
                else:
                    self.refit()  # bootstrap covers these rows too
                    return
            else:
                # `store` is the originating tier, so the gathered sketches
                # share a spec with the centre engine even mid-migration
                # (migrate_start re-sketched the centres before any add
                # could land in the new-spec tier)
                sk = padded_take(store.sk_buf, slots)
                lab = self._assign_packed(sk, n_valid=len(ids))
            self._lab_ids = np.concatenate([self._lab_ids, ids])
            self._lab = np.concatenate([self._lab, lab])
            self._counts += self._bincount(lab)
            self._weights += self._bincount(lab, store.weights_at(slots))
        elif event == "merge":
            # another store's alive rows just landed (SketchStore.merge);
            # their ids may interleave with the sidecar's, so labels insert
            # at their sorted positions instead of concatenating.  Counts
            # and weights are sums — the Mergeable discipline.  These
            # incremental labels are arrival-moment assignments like any
            # add's; ClusterIndex.merge refits afterwards to re-seed the
            # centres from the union membership.
            if len(ids) == 0:
                return
            if self._centers is None:
                self.refit()  # bootstrap covers the merged rows too
                return
            sk = padded_take(store.sk_buf, slots)
            lab = self._assign_packed(sk, n_valid=len(ids))
            pos = np.searchsorted(self._lab_ids, ids)
            self._lab_ids = np.insert(self._lab_ids, pos, ids)
            self._lab = np.insert(self._lab, pos, lab)
            self._counts += self._bincount(lab)
            self._weights += self._bincount(lab, store.weights_at(slots))
        elif event == "remove":
            pos = np.searchsorted(self._lab_ids, ids)
            lab = self._lab[pos]
            self._counts -= self._bincount(lab)
            self._weights -= self._bincount(lab, store.weights_at(slots))
            keep = np.ones(len(self._lab_ids), bool)
            keep[pos] = False
            self._lab_ids = self._lab_ids[keep]
            self._lab = self._lab[keep]
        elif event == "migrate_start":
            self._resketch_centers(store)
            return
        elif event == "migrate":
            # per-cluster weights were accumulated per-row under each row's
            # own spec; now every row is under the new spec — rebuild the
            # signal in one pass (store.weights() is id-ordered, exactly
            # the sidecar's order)
            if len(self._lab_ids):
                self._weights = self._bincount(
                    self._lab, store.weights().astype(np.float64))
            if self._refit_pending:
                self._refit_pending = False
                self.refit()
            return
        else:  # compact: ids (hence the sidecar) survive slot renumbering
            return
        self.mutations_since_refit += len(ids)
        if (self.refit_every is not None
                and self.mutations_since_refit >= self.refit_every):
            if self.engine.migrating:
                self._refit_pending = True  # refit at the "migrate" event
            else:
                self.refit()

    # -- (re)fitting --------------------------------------------------------

    def refit(self, n_iter: int | None = None) -> np.ndarray:
        """Re-cluster the current membership with the device engine and
        return the new labels (id order).

        Deterministic in the membership: the alive rows are gathered in id
        order (history-independent) and `kmode_packed` runs with the
        index's fixed seed, so any two stores holding the same vectors
        under the same ids — regardless of the add/remove/compact/restore
        history between — refit to identical centres, labels, counts.  An
        empty store resets to the unfitted state.

        Unavailable while a spec migration is in flight (the membership
        spans two sketch spaces); refits requested by `refit_every` during
        one run automatically once the migration publishes."""
        if self.engine.migrating:
            raise RuntimeError(
                "refit() is unavailable while a spec migration is in "
                "flight: the membership spans two sketch spaces.  Drive "
                "the migration to completion (engine.migrate_all()) first; "
                "auto-refits are deferred to the publish automatically")
        store = self.engine.store
        mat, n_alive, ids = store.gather_alive()
        if n_alive == 0:
            self._centers = None
            self._center_raw = None
            self._centre_engine = None
            self._centre_ids = np.zeros(0, np.int64)
            self._medoid_ids = np.full(self.k, -1, np.int64)
            self._lab_ids = np.zeros(0, np.int64)
            self._lab = np.zeros(0, np.int64)
            self._counts = np.zeros(self.k, np.int64)
            self._weights = np.zeros(self.k, np.int64)
            self.mutations_since_refit = 0
            return np.zeros(0, np.int64)
        with obs.span("cluster.refit", rows=int(n_alive), k=self.k):
            res = kmode_packed(
                mat[:n_alive], self.k, d=store.d,
                n_iter=self.n_iter if n_iter is None else n_iter,
                seed=self.seed, metric=self.engine.metric, block=self.block,
                mode=self.engine.mode)
        self._medoid_ids = ids[res.medoids]
        self._lab_ids = ids.copy()
        self._lab = res.labels
        self._counts = np.bincount(res.labels, minlength=self.k)
        self._weights = np.bincount(
            res.labels, weights=store.weights(),
            minlength=self.k).astype(np.int64)
        self._install_centers(res.centers)
        self._capture_center_raw()
        self.mutations_since_refit = 0
        self.n_refits += 1
        self._c_refits.inc()
        return res.labels.copy()

    def _capture_center_raw(self) -> None:
        """Copy the medoids' raw COO rows out of the engine's archive — a
        k-medoid centre IS a data row, so its raw form re-sketches to the
        centre under any spec.  No archive (keep_raw=False, or medoids from
        a pre-archive snapshot) leaves the capture empty; a later
        migrate_start then fails loudly instead of serving old-spec
        centres against new-spec rows."""
        raw = self.engine.raw
        if raw is None or len(raw.missing(self._medoid_ids)):
            self._center_raw = None
            return
        idx, val = raw.batch(self._medoid_ids)
        self._center_raw = (idx.copy(), val.copy())

    def _resketch_centers(self, dst_store) -> None:
        """migrate_start: rebuild the centre engine under the new spec from
        the captured raw medoids, so mid-migration arrivals (sketched under
        the new spec) assign against centres in the SAME sketch space."""
        if self._centers is None:
            return
        if self._center_raw is None:
            raise RuntimeError(
                "ClusterIndex centres cannot follow the spec migration: no "
                "raw medoid capture (centres predate the archive, e.g. a "
                "v1 snapshot, or keep_raw=False).  refit() before "
                "engine.migrate()")
        params = dst_store.spec.params
        sk, k = self.engine._sketch(self._center_raw, params=params)
        self._install_centers(np.asarray(sk[:k]), params=params)

    def _install_centers(self, centers: np.ndarray,
                         params=None) -> None:
        """(Re)build the private centre engine: k packed rows whose ids ARE
        the centre indices (fresh store, ids 0..k-1).  `params` pins the
        sketch space (default: the engine's current params)."""
        self._centers = np.asarray(centers, np.int32)
        self._centre_engine = QueryEngine(
            params if params is not None else self.engine.params,
            metric=self.engine.metric, block=self.block,
            mode=self.engine.mode, keep_raw=False,
            registry=obs.NULL_REGISTRY)
        self._centre_ids = self._centre_engine.add_packed(self._centers)

    # -- convenience mutation wrappers --------------------------------------

    def add_dense(self, x) -> tuple[np.ndarray, np.ndarray]:
        """Ingest via the engine; returns (ids, labels) of the new rows."""
        ids = self.engine.add_dense(x)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def add_sparse(self, indices, values) -> tuple[np.ndarray, np.ndarray]:
        ids = self.engine.add_sparse(indices, values)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def add_packed(self, packed, raw=None) -> tuple[np.ndarray, np.ndarray]:
        ids = self.engine.add_packed(packed, raw=raw)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def remove(self, ids) -> int:
        return self.engine.remove(ids)

    def compact(self) -> None:
        self.engine.compact()

    # -- merge (the Mergeable contract, repro.index.mergeable) --------------

    def merge(self, other: "ClusterIndex") -> "ClusterIndex":
        """Absorb another ClusterIndex (and its engine) and return self.

        The engines merge first — id-disjoint membership union, validated
        before anything mutates — which streams the absorbed rows through
        the "merge" event (counts/weights arrive as sums, labels as
        arrival-moment assignments).  Then the centres are re-seeded from
        the UNION membership via the existing `refit` path: refit is
        deterministic in the membership, so a merged index ends bit-equal
        to a sequentially built index of the same rows after its own
        refit(), regardless of shard split or merge order.  `other` is
        detached from its engine and must be discarded."""
        if other is self:
            raise MergeIncompatible(
                "ClusterIndex.merge: cannot merge an index with itself")
        if (other.k, other.seed, other.n_iter) != (self.k, self.seed,
                                                   self.n_iter):
            raise MergeIncompatible(
                "ClusterIndex.merge: clustering configs differ "
                f"(k/seed/n_iter {self.k}/{self.seed}/{self.n_iter} vs "
                f"{other.k}/{other.seed}/{other.n_iter}) — refits of the "
                "merged membership would not be comparable")
        other.detach()
        self.engine.merge(other.engine)
        self.refit()
        return self

    # -- persistence --------------------------------------------------------

    _FORMAT = "repro.cluster.v2"
    _FORMATS = ("repro.cluster.v1", "repro.cluster.v2")

    def save(self, directory: str, step: int = 0, keep: int = 3) -> None:
        """Snapshot engine + cluster state: the engine snapshot lands in
        `directory` (QueryEngine.save) and the cluster sidecar in
        `directory/cluster` under the same step, both through
        checkpoint.Checkpointer's atomic-publish layout.  v2 adds the raw
        medoid capture, so a restored index can still follow a spec
        migration without an intervening refit."""
        from repro.checkpoint.checkpointer import Checkpointer

        self.engine.save(directory, step=step, keep=keep)
        w = self.engine.store.w
        centers = (self._centers if self._centers is not None
                   else np.zeros((0, w), np.int32))
        craw_i, craw_v = (self._center_raw if self._center_raw is not None
                          else (np.zeros((0, 1), np.int32),) * 2)
        tree = {
            "centers": centers,
            "medoid_ids": self._medoid_ids,
            "center_raw_idx": craw_i,
            "center_raw_val": craw_v,
            "lab_ids": self._lab_ids,
            "labels": self._lab,
            "counts": self._counts,
            "weights": self._weights,
        }
        meta = {
            "format": self._FORMAT,
            "k": self.k,
            "seed": self.seed,
            "n_iter": self.n_iter,
            "block": self.block,
            "refit_every": self.refit_every,
            "mutations_since_refit": self.mutations_since_refit,
            "n_refits": self.n_refits,
            "has_center_raw": self._center_raw is not None,
        }
        ckpt = Checkpointer(os.path.join(directory, "cluster"), keep=keep,
                            async_save=False)
        ckpt.save(step, tree, extra_meta=meta, block=True)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **engine_kwargs) -> "ClusterIndex":
        """Rebuild (engine, ClusterIndex) from a `save` snapshot.  The
        restored state is EXACT — including labels assigned incrementally
        since the last refit, which a fresh refit would not reproduce.

        The step is resolved from the CLUSTER sidecar (written last by
        `save`), then used for the engine snapshot too — so a save that
        crashed between the two publishes restores the newest CONSISTENT
        pair instead of pairing a fresh store with a stale sidecar."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(os.path.join(directory, "cluster"),
                            async_save=False)
        if step is None:
            step = ckpt.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no cluster snapshots in {directory}/cluster")
        engine = QueryEngine.restore(directory, step=step, **engine_kwargs)
        meta = ckpt.meta(step)
        if meta.get("format") not in cls._FORMATS:
            raise ValueError(f"not a cluster snapshot: {directory}/cluster")
        tree, _ = ckpt.restore(step=step)
        self = cls.__new__(cls)
        self.engine = engine
        self.k = int(meta["k"])
        self.seed = int(meta["seed"])
        self.n_iter = int(meta["n_iter"])
        self.block = int(meta.get("block", engine.block))
        refit_every = meta.get("refit_every")
        self.refit_every = None if refit_every is None else int(refit_every)
        self._centers = None
        self._center_raw = None
        self._centre_engine = None
        self._centre_ids = np.zeros(0, np.int64)
        self._medoid_ids = np.asarray(tree["medoid_ids"], np.int64).copy()
        self._lab_ids = np.asarray(tree["lab_ids"], np.int64).copy()
        self._lab = np.asarray(tree["labels"], np.int64).copy()
        self._counts = np.asarray(tree["counts"], np.int64).copy()
        self._weights = np.asarray(tree["weights"], np.int64).copy()
        self.mutations_since_refit = int(meta["mutations_since_refit"])
        self.n_refits = int(meta["n_refits"])
        self._refit_pending = False
        self._wire_obs()
        if len(self._lab_ids) and not np.array_equal(self._lab_ids,
                                                     engine.ids()):
            # a desynced pair would corrupt the remove bookkeeping later;
            # fail at the boundary instead
            raise ValueError(
                "cluster snapshot does not match the engine snapshot at "
                f"step {step}: label sidecar covers different ids than the "
                "restored store")
        if len(tree["centers"]):
            # a mid-migration snapshot saved centres ALREADY re-sketched
            # under the new spec (migrate_start ran before the save)
            cparams = (engine.migration.new_spec.params
                       if engine.migrating else None)
            self._install_centers(np.asarray(tree["centers"], np.int32),
                                  params=cparams)
        if meta.get("has_center_raw"):
            self._center_raw = (
                np.asarray(tree["center_raw_idx"], np.int32).copy(),
                np.asarray(tree["center_raw_val"], np.int32).copy())
        engine.subscribe(self._on_engine_event)
        return self
