"""ClusterIndex: k-medoid centres and per-row labels over a live index.

`core.kmode.kmode_packed` answers the one-shot question "cluster this
matrix"; a serving system owns a COLLECTION that mutates between questions.
ClusterIndex is the bridge (DESIGN.md section 9.3): it subscribes to the
engine's `SketchStore` mutation events, so rows added through ANY path
(engine.add_dense / add_sparse / add_packed, streaming ingest) are assigned
to their nearest centre the moment they land, removes decrement the cluster
bookkeeping, and compaction is a no-op (labels are keyed by external id,
which compaction preserves).

Three disciplines, all inherited rather than reinvented:

  * Assignment IS a k-NN query.  Centres live in a private k-row
    QueryEngine; assigning a batch is `topk_packed(k=1)` against it, which
    buys the serving stack's shape bucketing, traced valid counts, and LRU
    for free — and its (value, id)-lex tie-break equals `argmin_rows`'
    first-minimum tie-break because centre ids are centre indices, so
    incremental assignment agrees exactly with what a `refit` would assign
    against the same centres.
  * Refit is deterministic in the membership.  `refit()` gathers the alive
    rows in id order (the store's history-independent canonical order) and
    runs the full-batch device engine with the index's fixed seed: two
    stores holding the same membership — however they got there, including
    through save/restore — refit to identical centres and labels.  The
    property tests pin this.
  * Snapshots ride the store's.  `save` writes the engine snapshot plus a
    `cluster/` Checkpointer tree (centres, label sidecar, counts/weights),
    and `restore` reproduces the exact live state — including labels
    assigned incrementally since the last refit, which a re-fit would not
    reproduce (they depend on arrival order by design).

Between refits, labels of rows added incrementally are path-dependent
(each batch is assigned against the centres of its arrival moment); the
invariance contract applies AFTER `refit()`, which is the point of having
one.  `refit_every=n` auto-refits once n mutations accumulate.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.kmode import kmode_packed
from repro.core.packing import pad_rows_pow2, padded_take
from repro.index.engine import QueryEngine


class ClusterIndex:
    """Online k-medoid clustering attached to a QueryEngine.

    Parameters
    ----------
    engine : the QueryEngine whose store is being clustered.  The index
        subscribes to the store's mutation events at construction; if the
        store already holds rows, an initial `refit()` runs immediately,
        otherwise the first `add` bootstraps it.
    k : number of clusters (>= 1; k > n is legal — degenerate clusters
        simply stay empty or share duplicate centres, matching kmode).
    seed / n_iter / block : forwarded to `kmode_packed` on every refit —
        fixed at construction so refits are a pure function of membership.
    refit_every : auto-refit after this many mutated rows (None = manual).
    """

    def __init__(self, engine: QueryEngine, k: int, *, seed: int = 0,
                 n_iter: int = 15, block: int = 2048,
                 refit_every: int | None = None):
        if k < 1:
            raise ValueError(f"ClusterIndex: k must be >= 1, got {k}")
        if n_iter < 1:
            raise ValueError(
                f"ClusterIndex: n_iter must be >= 1, got {n_iter}")
        if refit_every is not None and refit_every < 1:
            raise ValueError(
                f"ClusterIndex: refit_every must be >= 1, got {refit_every}")
        self.engine = engine
        self.k = int(k)
        self.seed = int(seed)
        self.n_iter = int(n_iter)
        self.block = int(block)
        self.refit_every = refit_every
        self._centers: np.ndarray | None = None   # (k, w) packed, host
        self._medoid_ids = np.full(self.k, -1, np.int64)
        self._centre_engine: QueryEngine | None = None
        self._centre_ids = np.zeros(0, np.int64)
        # label sidecar over the ALIVE rows, ascending by external id (ids
        # are monotone and adds append, so order is maintained for free)
        self._lab_ids = np.zeros(0, np.int64)
        self._lab = np.zeros(0, np.int64)
        self._counts = np.zeros(self.k, np.int64)
        self._weights = np.zeros(self.k, np.int64)
        self.mutations_since_refit = 0
        self.n_refits = 0
        engine.store.subscribe(self._on_store_event)
        if len(engine.store):
            self.refit()

    def detach(self) -> None:
        """Stop observing the engine's store.  The store holds a strong
        reference to every subscriber, so an abandoned index would keep
        paying a k-NN assignment per add forever — detach before replacing
        one (e.g. to change k or seed)."""
        self.engine.store.unsubscribe(self._on_store_event)

    # -- introspection ------------------------------------------------------

    @property
    def fitted(self) -> bool:
        return self._centers is not None

    @property
    def counts(self) -> np.ndarray:
        """Alive rows per cluster, (k,) int64 (a copy)."""
        return self._counts.copy()

    @property
    def weights(self) -> np.ndarray:
        """Summed sketch Hamming weight of alive rows per cluster — the
        cheap density signal band planning already mirrors on host."""
        return self._weights.copy()

    @property
    def centers(self) -> np.ndarray:
        """Packed centre rows (k, w) int32 (a copy)."""
        self._require_fit()
        return self._centers.copy()

    @property
    def medoid_ids(self) -> np.ndarray:
        """External id each centre was elected from at the last refit
        (-1 for clusters whose medoid id predates the sidecar, e.g. after
        a restore of an unfitted snapshot)."""
        return self._medoid_ids.copy()

    def labels(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, labels) over the alive rows, ascending by id (copies)."""
        return self._lab_ids.copy(), self._lab.copy()

    def label_of(self, ids) -> np.ndarray:
        """Cluster of each external id; KeyError on unknown/removed ids."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(ids) == 0:
            return np.zeros(0, np.int64)
        n = len(self._lab_ids)
        pos = np.searchsorted(self._lab_ids, ids)
        ok = pos < n
        if n:
            ok &= self._lab_ids[np.minimum(pos, n - 1)] == ids
        if not ok.all():
            raise KeyError(f"id {ids[~ok][0]} not in cluster index")
        return self._lab[pos]

    def stats(self) -> dict:
        return {
            "k": self.k,
            "fitted": self.fitted,
            "n_labeled": len(self._lab_ids),
            "counts": self._counts.tolist(),
            "n_refits": self.n_refits,
            "mutations_since_refit": self.mutations_since_refit,
        }

    # -- assignment (the engine's own serving path) -------------------------

    def _require_fit(self) -> None:
        if self._centers is None:
            raise RuntimeError(
                "ClusterIndex has no centres yet: add rows (the first add "
                "bootstraps a fit) or call refit() on a non-empty store")

    def _ids_to_clusters(self, ids: np.ndarray) -> np.ndarray:
        if ids.shape[1] == 0:  # empty query batch: topk returns (0, 0)
            return np.zeros(ids.shape[0], np.int64)
        return np.searchsorted(self._centre_ids, ids[:, 0]).astype(np.int64)

    def _assign_packed(self, sk, n_valid: int) -> np.ndarray:
        """Nearest-centre labels for packed query rows via the centre
        engine's topk_packed(k=1) — LRU + shape bucketing for free, and the
        (value, id)-lex tie-break equals argmin's first minimum because
        centre ids are centre indices."""
        ids, _ = self._centre_engine.topk_packed(sk, 1, n_valid=n_valid)
        return self._ids_to_clusters(ids)

    def assign(self, queries) -> np.ndarray:
        """Label raw categorical queries (dense rows or (indices, values)
        COO) WITHOUT ingesting them — the read-only classification path."""
        self._require_fit()
        ids, _ = self._centre_engine.topk(queries, 1)
        return self._ids_to_clusters(ids)

    def assign_packed(self, sk) -> np.ndarray:
        """Pre-sketched twin of `assign` (rows must share the engine's
        CabinParams)."""
        self._require_fit()
        import jax.numpy as jnp

        sk = jnp.asarray(sk)
        return self._assign_packed(pad_rows_pow2(sk), n_valid=sk.shape[0])

    # -- mutation mirror (store hook) ---------------------------------------

    def _on_store_event(self, event: str, ids: np.ndarray,
                        slots: np.ndarray) -> None:
        store = self.engine.store
        if event == "add":
            if self._centers is None:
                self.refit()  # bootstrap covers these rows too
                return
            sk = padded_take(store.sk_buf, slots)
            lab = self._assign_packed(sk, n_valid=len(ids))
            self._lab_ids = np.concatenate([self._lab_ids, ids])
            self._lab = np.concatenate([self._lab, lab])
            self._counts += np.bincount(lab, minlength=self.k)
            self._weights += np.bincount(
                lab, weights=store.weights_at(slots),
                minlength=self.k).astype(np.int64)
        elif event == "remove":
            pos = np.searchsorted(self._lab_ids, ids)
            lab = self._lab[pos]
            self._counts -= np.bincount(lab, minlength=self.k)
            self._weights -= np.bincount(
                lab, weights=store.weights_at(slots),
                minlength=self.k).astype(np.int64)
            keep = np.ones(len(self._lab_ids), bool)
            keep[pos] = False
            self._lab_ids = self._lab_ids[keep]
            self._lab = self._lab[keep]
        else:  # compact: ids (hence the sidecar) survive slot renumbering
            return
        self.mutations_since_refit += len(ids)
        if (self.refit_every is not None
                and self.mutations_since_refit >= self.refit_every):
            self.refit()

    # -- (re)fitting --------------------------------------------------------

    def refit(self, n_iter: int | None = None) -> np.ndarray:
        """Re-cluster the current membership with the device engine and
        return the new labels (id order).

        Deterministic in the membership: the alive rows are gathered in id
        order (history-independent) and `kmode_packed` runs with the
        index's fixed seed, so any two stores holding the same vectors
        under the same ids — regardless of the add/remove/compact/restore
        history between — refit to identical centres, labels, counts.  An
        empty store resets to the unfitted state."""
        store = self.engine.store
        mat, n_alive, ids = store.gather_alive()
        if n_alive == 0:
            self._centers = None
            self._centre_engine = None
            self._centre_ids = np.zeros(0, np.int64)
            self._medoid_ids = np.full(self.k, -1, np.int64)
            self._lab_ids = np.zeros(0, np.int64)
            self._lab = np.zeros(0, np.int64)
            self._counts = np.zeros(self.k, np.int64)
            self._weights = np.zeros(self.k, np.int64)
            self.mutations_since_refit = 0
            return np.zeros(0, np.int64)
        res = kmode_packed(
            mat[:n_alive], self.k, d=store.d,
            n_iter=self.n_iter if n_iter is None else n_iter,
            seed=self.seed, metric=self.engine.metric, block=self.block,
            mode=self.engine.mode)
        self._medoid_ids = ids[res.medoids]
        self._lab_ids = ids.copy()
        self._lab = res.labels
        self._counts = np.bincount(res.labels, minlength=self.k)
        self._weights = np.bincount(
            res.labels, weights=store.weights(),
            minlength=self.k).astype(np.int64)
        self._install_centers(res.centers)
        self.mutations_since_refit = 0
        self.n_refits += 1
        return res.labels.copy()

    def _install_centers(self, centers: np.ndarray) -> None:
        """(Re)build the private centre engine: k packed rows whose ids ARE
        the centre indices (fresh store, ids 0..k-1)."""
        self._centers = np.asarray(centers, np.int32)
        self._centre_engine = QueryEngine(
            self.engine.params, metric=self.engine.metric, block=self.block,
            mode=self.engine.mode)
        self._centre_ids = self._centre_engine.add_packed(self._centers)

    # -- convenience mutation wrappers --------------------------------------

    def add_dense(self, x) -> tuple[np.ndarray, np.ndarray]:
        """Ingest via the engine; returns (ids, labels) of the new rows."""
        ids = self.engine.add_dense(x)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def add_sparse(self, indices, values) -> tuple[np.ndarray, np.ndarray]:
        ids = self.engine.add_sparse(indices, values)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def add_packed(self, packed) -> tuple[np.ndarray, np.ndarray]:
        ids = self.engine.add_packed(packed)
        return ids, self.label_of(ids) if len(ids) else ids.copy()

    def remove(self, ids) -> int:
        return self.engine.remove(ids)

    def compact(self) -> None:
        self.engine.compact()

    # -- persistence --------------------------------------------------------

    _FORMAT = "repro.cluster.v1"

    def save(self, directory: str, step: int = 0, keep: int = 3) -> None:
        """Snapshot engine + cluster state: the engine snapshot lands in
        `directory` (QueryEngine.save) and the cluster sidecar in
        `directory/cluster` under the same step, both through
        checkpoint.Checkpointer's atomic-publish layout."""
        from repro.checkpoint.checkpointer import Checkpointer

        self.engine.save(directory, step=step, keep=keep)
        w = self.engine.store.w
        centers = (self._centers if self._centers is not None
                   else np.zeros((0, w), np.int32))
        tree = {
            "centers": centers,
            "medoid_ids": self._medoid_ids,
            "lab_ids": self._lab_ids,
            "labels": self._lab,
            "counts": self._counts,
            "weights": self._weights,
        }
        meta = {
            "format": self._FORMAT,
            "k": self.k,
            "seed": self.seed,
            "n_iter": self.n_iter,
            "block": self.block,
            "refit_every": self.refit_every,
            "mutations_since_refit": self.mutations_since_refit,
            "n_refits": self.n_refits,
        }
        ckpt = Checkpointer(os.path.join(directory, "cluster"), keep=keep,
                            async_save=False)
        ckpt.save(step, tree, extra_meta=meta, block=True)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **engine_kwargs) -> "ClusterIndex":
        """Rebuild (engine, ClusterIndex) from a `save` snapshot.  The
        restored state is EXACT — including labels assigned incrementally
        since the last refit, which a fresh refit would not reproduce.

        The step is resolved from the CLUSTER sidecar (written last by
        `save`), then used for the engine snapshot too — so a save that
        crashed between the two publishes restores the newest CONSISTENT
        pair instead of pairing a fresh store with a stale sidecar."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(os.path.join(directory, "cluster"),
                            async_save=False)
        if step is None:
            step = ckpt.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no cluster snapshots in {directory}/cluster")
        engine = QueryEngine.restore(directory, step=step, **engine_kwargs)
        meta = ckpt.meta(step)
        if meta.get("format") != cls._FORMAT:
            raise ValueError(f"not a cluster snapshot: {directory}/cluster")
        w = engine.store.w
        like = {
            "centers": np.zeros((0, w), np.int32),
            "medoid_ids": np.zeros(0, np.int64),
            "lab_ids": np.zeros(0, np.int64),
            "labels": np.zeros(0, np.int64),
            "counts": np.zeros(0, np.int64),
            "weights": np.zeros(0, np.int64),
        }
        tree, _ = ckpt.restore(like, step=step)
        self = cls.__new__(cls)
        self.engine = engine
        self.k = int(meta["k"])
        self.seed = int(meta["seed"])
        self.n_iter = int(meta["n_iter"])
        self.block = int(meta.get("block", engine.block))
        refit_every = meta.get("refit_every")
        self.refit_every = None if refit_every is None else int(refit_every)
        self._centers = None
        self._centre_engine = None
        self._centre_ids = np.zeros(0, np.int64)
        self._medoid_ids = tree["medoid_ids"].copy()
        self._lab_ids = tree["lab_ids"].copy()
        self._lab = tree["labels"].copy()
        self._counts = tree["counts"].copy()
        self._weights = tree["weights"].copy()
        self.mutations_since_refit = int(meta["mutations_since_refit"])
        self.n_refits = int(meta["n_refits"])
        if len(self._lab_ids) and not np.array_equal(self._lab_ids,
                                                     engine.store.ids()):
            # a desynced pair would corrupt the remove bookkeeping later;
            # fail at the boundary instead
            raise ValueError(
                "cluster snapshot does not match the engine snapshot at "
                f"step {step}: label sidecar covers different ids than the "
                "restored store")
        if len(tree["centers"]):
            self._install_centers(tree["centers"])
        engine.store.subscribe(self._on_store_event)
        return self
