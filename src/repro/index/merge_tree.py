"""Merge-tree parallel bulk ingest: N workers sketch, log-depth combine.

`ingest_documents` streams one document at a time through one engine —
fine for a trickle, a bottleneck for "load the corpus".  Sketching is
embarrassingly parallel (each document's sketch is a pure function of the
document and the spec), and everything above the sketches is Mergeable
(repro.index.mergeable, DESIGN.md section 14), so bulk load becomes the
classic merge-tree reduction of the streaming-sketch literature: N
workers each run the EXISTING `ingest_documents` over a private engine,
then pairs combine via `QueryEngine.merge` in log2(N) levels until one
serveable engine remains, which folds into the caller's.

Id discipline is what makes the tree exact: worker i's private store
starts its id counter at the target's watermark plus the number of
documents in shards 0..i-1, so worker id ranges are DISJOINT and ascending
left-to-right by construction — every combine takes `SketchStore.merge`'s
append fast path (one device concat through the same compiled graph as
`add`, no epoch bump), the merged engine assigns exactly the ids a
sequential `ingest_documents` over the concatenated shards would, and the
final store is bit-identical to the sequential build (tests/test_merge.py
pins this, both metrics, any shard split).

The one caveat: per-shard DEDUP windows see different neighbours than one
sequential stream's windows would, so with `dedup_threshold` set the kept
set may differ from a sequential ingest near shard boundaries.  The
bit-identity guarantee is for dedup_threshold=None; deduped bulk loads
are still exact over whatever they kept.
"""

from __future__ import annotations

import logging
from concurrent.futures import ThreadPoolExecutor
from typing import Iterable, Sequence

import numpy as np

from repro import obs
from repro.index.engine import QueryEngine
from repro.index.ingest import ingest_documents

_log = logging.getLogger("repro.index.merge_tree")


def _worker_engine(target: QueryEngine, id_base: int) -> QueryEngine:
    """A private build engine for one shard: same spec / metric / serving
    config as the target, id counter pre-offset so worker id ranges are
    disjoint by construction, result cache off (build-only traffic), and
    its own registry — folded into the target's when the tree collapses,
    so per-worker ingest counters survive the merge."""
    w = QueryEngine(target.params, metric=target.metric, block=target.block,
                    mode=target.mode, band_rows=target.band_rows,
                    cache_entries=0, merge_ratio=target.merge_ratio,
                    keep_raw=target.raw is not None)
    w.spec = target.spec
    w.store.spec = target.spec
    w.store._next_id = int(id_base)
    return w


def merge_tree(engines: Sequence[QueryEngine], *,
               workers: int | None = None) -> QueryEngine:
    """Log-depth pairwise reduction of id-disjoint engines into one.

    Adjacent pairs combine per level (left absorbs right), so engines
    whose id ranges ascend left-to-right keep that property at every
    level and each combine rides the store's append fast path.  Merges
    are associative, so any other order is equally exact — just slower
    (interleaved ranges pay the gather path).  Pairs within a level run
    concurrently on a thread pool (`workers`, default: one per pair)."""
    level = list(engines)
    if not level:
        raise ValueError("merge_tree: no engines to merge")
    depth = 0
    while len(level) > 1:
        pairs = [(level[i], level[i + 1])
                 for i in range(0, len(level) - 1, 2)]
        tail = [level[-1]] if len(level) % 2 else []
        n_workers = min(len(pairs), workers or len(pairs))
        with obs.span("merge_tree.level", depth=depth, pairs=len(pairs)):
            with ThreadPoolExecutor(max_workers=n_workers) as pool:
                level = list(pool.map(lambda p: p[0].merge(p[1]),
                                      pairs)) + tail
        depth += 1
    return level[0]


def bulk_ingest(engine: QueryEngine,
                shards: Sequence[Iterable[np.ndarray]], *,
                workers: int | None = None, window: int = 512,
                dedup_threshold: float | None = None) -> np.ndarray:
    """Parallel bulk load: sketch `shards` of token-id documents into
    private per-shard engines concurrently, tree-reduce them, and absorb
    the result into `engine`.  Returns one entry per document in shard
    order: its assigned id, or -1 if the shard's dedup pass dropped it —
    the same contract as `ingest_documents`, whose sequential build this
    is bit-identical to for dedup_threshold=None (module docstring).

    `workers` caps the thread pool (default: one per shard).  Sketching
    is jax device work, so threads overlap Python-side windowing/COO prep
    with device dispatch rather than fighting a GIL-bound inner loop; on
    a multi-device or accelerator backend the same shape scales with the
    hardware.  An engine mid-migration refuses (merge would too)."""
    if engine.migrating:
        raise RuntimeError(
            "bulk_ingest: the target engine has a spec migration in "
            "flight; drive it to completion (migrate_all()) first")
    shards = [list(sh) for sh in shards]
    counts = [len(sh) for sh in shards]
    total = int(sum(counts))
    if total == 0:
        return np.zeros(0, np.int64)
    n_workers = max(1, min(len(shards), workers or len(shards)))
    base = engine.store._next_id
    offsets = base + np.concatenate(
        [[0], np.cumsum(counts[:-1], dtype=np.int64)])
    _log.info("bulk ingest: %d docs over %d shards (%d workers)",
              total, len(shards), n_workers)
    with obs.span("ingest.bulk", docs=total, shards=len(shards),
                  workers=n_workers):
        builders = [_worker_engine(engine, off) for off in offsets]

        def run(i: int) -> np.ndarray:
            return ingest_documents(builders[i], shards[i], window=window,
                                    dedup_threshold=dedup_threshold)

        with ThreadPoolExecutor(max_workers=n_workers) as pool:
            id_parts = list(pool.map(run, range(len(shards))))
        engine.merge(merge_tree(builders, workers=n_workers))
    return np.concatenate(id_parts)
