"""Streaming ingest: data.pipeline document streams -> a live QueryEngine.

Bridges the LM data plane and the index: token-id documents are windowed
(data.pipeline.document_windows — the same windowing the dedup filter
uses), converted to padded-COO categorical rows (data.dedup's BoW capping),
optionally near-dedup'd WITHIN the window against the engine's own sketch
space, and appended.  Because the window's sketches are computed once and
reused for both the dedup pass and the store append (`add_packed`), turning
dedup on costs only the candidate scan, not a second sketching pass.

This loop is one SEQUENTIAL writer — but no longer the only build story:
a document's sketch is a pure function of (document, spec) and everything
above the sketches is Mergeable (repro.index.mergeable), so
`index.merge_tree.bulk_ingest` runs N copies of this exact loop over
document shards in parallel and tree-merges the private engines into one,
bit-identical to the sequential build (dedup off; see merge_tree.py for
the dedup-window caveat).  Use this module directly for a trickle, the
merge tree for "load the corpus".
"""

from __future__ import annotations

import itertools
from typing import Iterable, Iterator

import numpy as np

from repro.data import dedup as dedup_mod
from repro.data.pipeline import document_windows
from repro.index.engine import QueryEngine


def ingest_documents(
    engine: QueryEngine,
    docs: Iterable[np.ndarray] | Iterator[np.ndarray],
    *,
    window: int = 512,
    max_docs: int | None = None,
    dedup_threshold: float | None = None,
) -> np.ndarray:
    """Stream token-id documents into `engine`; returns one entry per
    consumed document: its assigned id, or -1 if the in-window dedup pass
    dropped it as a near-duplicate (dedup_threshold=None keeps everything).

    The engine's CabinParams.n_dims is the vocabulary size: token counts
    (capped, BoW-style) are the categorical values, exactly as the dedup
    pipeline stage treats documents.
    """
    vocab = engine.params.n_dims
    out: list[np.ndarray] = []
    stream = iter(docs)
    if max_docs is not None:
        # cap BEFORE windowing so no document is pulled from the caller's
        # iterator without getting an output entry
        stream = itertools.islice(stream, max_docs)
    for win in document_windows(stream, window):
        idx, val = dedup_mod.docs_to_categorical(win, vocab)
        if dedup_threshold is None:
            out.append(engine.add_sparse(idx, val))
        else:
            sk, k = engine._sketch((idx, val))
            sk_host = np.asarray(sk[:k])
            # dedup in the ENGINE's metric so the threshold shares units
            # with every distance the engine serves
            result = dedup_mod.dedup_by_sketch(
                sk_host, engine.d, dedup_threshold, metric=engine.metric)
            ids = np.full(len(win), -1, np.int64)
            keep = result.keep_mask
            if keep.any():
                # hand the kept rows' raw COO along with the sketches so
                # the engine's archive keeps them re-sketchable (and the
                # mid-migration path can route them to the new-spec tier)
                ids[keep] = engine.add_packed(
                    sk_host[keep], raw=(idx[keep], val[keep]))
            out.append(ids)
    if not out:
        return np.zeros(0, np.int64)
    return np.concatenate(out)
