"""Weight-banded layouts: the query-pruning structures over a store.

A Cabin sketch's Hamming weight bounds how close it can be to anything:
dist(u, v) >= prune_factor(metric) * |s_u - s_v| for the per-row prune score
s (repro.core.allpairs.prune_score_host — the density estimate under cham,
the raw weight under exact hamming).  PR 1 exploited this bound INSIDE the
batch engine's tile loop; the index subsystem hoists it one level up: rows
are kept weight-sorted and partitioned into contiguous BANDS, each band
carrying its host-side score interval, so a radius query discards whole
bands on host — before a single distance tile, device gather, or compile is
touched — and a k-NN query expands outward through the bands nearest the
query, stopping at the exactness certificate (DESIGN.md sections 8.2/8.4).

Two layers live here (DESIGN.md section 8.5):

  * `BandedLayout` — an immutable weight-sorted banded snapshot of a slot
    set, plus a refreshable ALIVE mask so tombstones thread through without
    invalidating the sort or the device matrix.
  * `TieredLayout` — the LSM-style incremental layout the engine serves
    from: a big sorted base tier that survives mutations, a small unsorted
    delta tier holding fresh adds (scanned brute-force — the sketches are
    tiny, so a few thousand delta rows cost less than one band gather), and
    a size-ratio merge policy folding delta back into base.  `sync` absorbs
    a mutation in O(delta) instead of the O(N log N) host sort + O(N)
    device gather a fresh build pays.

Every prune in both layers is sound (the weight bound holds with
PRUNE_MARGIN slack for float noise), and the cross-tier merge is the same
(value, id)-lexicographic k-best used inside `topk_rows_banded`, so results
are bit-identical to a fresh batch build of the same membership — tiering
is a pure serving optimisation with zero bit-identity risk.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import allpairs
from repro.core.allpairs import (KBEST_KEY_PAD, PRUNE_MARGIN,
                                 kbest_lex_merge, prune_factor,
                                 prune_score_host)
from repro.core.packing import padded_take
from repro.index.store import SketchStore
from repro.obs.registry import NULL_REGISTRY


def merge_topk_parts(kk: int, parts: list[tuple[np.ndarray, np.ndarray]]
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-partition k-best lists into THE exact (value, id)-lex
    k-best: `parts` is a list of (ids (Q, <=kk), vals (Q, <=kk)) answers
    over DISJOINT row partitions, each already exact over its partition.
    Shared by TieredLayout's base+delta merge and the migration's
    cross-spec (old store / new store / fresh store) merge — one rule, so
    partitioned serving is bit-identical to a single scan by construction.
    Short lists are padded with (KBEST_KEY_PAD, inf), which sorts after any
    real candidate; pads survive only when the union holds < kk rows."""
    if len(parts) == 1:
        return parts[0]  # a lone partition is already the exact k'-best

    def pad_cols(ids: np.ndarray, vals: np.ndarray):
        have = ids.shape[1]
        if have == kk:
            return ids, vals
        padw = ((0, 0), (0, kk - have))
        return (np.pad(ids, padw, constant_values=KBEST_KEY_PAD),
                np.pad(vals, padw, constant_values=np.inf))

    padded = [pad_cols(i, v) for i, v in parts]
    vals, ids = kbest_lex_merge(
        kk, np.concatenate([v for _, v in padded], axis=1),
        np.concatenate([i for i, _ in padded], axis=1))
    return ids, vals


class BandedLayout:
    """Immutable weight-sorted banded snapshot of a slot set.

    Rows are sorted by (sketch weight, id) — a total, history-independent
    order — then cut into bands of `band_rows` consecutive rows.  The device
    matrix holds the sorted rows padded to a power of two; `ids` maps sorted
    positions back to external ids and `slots` back to store slots.

    The snapshot itself never mutates; later tombstones are threaded
    through `refresh_alive`, which re-reads the store's host bitmap at the
    snapshot's slots (O(n) host work, no device traffic).  Band score
    intervals are computed over the snapshot's rows and therefore stay
    conservative supersets for any alive subset — masked queries prune a
    little less but never wrongly.
    """

    def __init__(self, store: SketchStore, metric: str,
                 band_rows: int = 1024, registry=None):
        # banding effectiveness counters: visited vs pruned per query, and
        # how often the exactness certificate stopped the scan early.  The
        # instruments are cached here once — under NULL_REGISTRY they are
        # shared no-ops and the stats_out dict is never even built.
        reg = NULL_REGISTRY if registry is None else registry
        self._obs_off = reg.is_null
        self._c_queries = reg.counter("index_banded_queries_total")
        self._c_visited = reg.counter("index_bands_visited_total")
        self._c_pruned = reg.counter("index_bands_pruned_total")
        self._c_early = reg.counter("index_band_early_stops_total")
        self.metric = metric
        self.d = store.d
        self.band_rows = int(band_rows)
        self.version = store.version
        slots = store.alive_slots()
        weights = store.weights_at(slots)
        # stable sort over id-ordered rows => total order (weight, id):
        # incremental and fresh builds of the same membership agree exactly.
        order = np.argsort(weights, kind="stable")
        self.n = len(slots)
        self.slots = slots[order]
        self.ids = store.ids_at(slots)[order]
        w_sorted = weights[order]
        self.matrix = padded_take(store.sk_buf, self.slots)
        self.alive = np.ones(self.n, bool)
        self._n_alive = self.n
        self.n_bands = -(-self.n // self.band_rows) if self.n else 0
        scores = prune_score_host(w_sorted, self.d, metric)
        self.band_lo = np.asarray(
            [scores[b * self.band_rows] for b in range(self.n_bands)])
        self.band_hi = np.asarray(
            [scores[min((b + 1) * self.band_rows, self.n) - 1]
             for b in range(self.n_bands)])

    @property
    def n_alive(self) -> int:
        return self._n_alive

    def refresh_alive(self, store: SketchStore) -> None:
        """Re-read the store's tombstone bitmap at this snapshot's slots —
        how removes reach a layout without any rebuild or device work."""
        if self.n:
            self.alive = store.alive_at(self.slots)
            self._n_alive = int(np.count_nonzero(self.alive))

    def _mask(self) -> np.ndarray | None:
        # None keeps the fully-alive hot path identical to the pre-mask one
        return None if self._n_alive == self.n else self.alive

    def candidate_bands(self, query_weights: np.ndarray, radius: float
                        ) -> np.ndarray:
        """Bool mask over bands: band b survives iff SOME query's score is
        within reach of its [lo, hi] score interval — i.e. the weight bound
        cannot rule out every row in it."""
        if self.n == 0 or len(query_weights) == 0:
            return np.zeros(self.n_bands, bool)
        qs = prune_score_host(np.asarray(query_weights), self.d, self.metric)
        factor = prune_factor(self.metric)
        gap = np.maximum(
            np.maximum(self.band_lo[None, :] - qs[:, None],
                       qs[:, None] - self.band_hi[None, :]), 0.0)
        return (factor * gap < radius + PRUNE_MARGIN).any(axis=0)

    def topk(self, queries_padded: jnp.ndarray, query_weights: np.ndarray,
             k: int, *, q_valid: int, block: int = 2048,
             mode: str | None = None, deadline=None,
             info_out: dict | None = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Progressive band-expansion k-NN: (ids (Q, k'), dists (Q, k')),
        k' = min(k, n_alive), ascending by (distance, id) — exactly what
        core.allpairs.topk_rows returns over the alive membership in id
        order.

        Bands are visited in ascending prune-score distance from the query
        batch, the running k-th best distance is tracked, and the scan stops
        with the certificate `prune_factor * gap >= kth + PRUNE_MARGIN` for
        every (query, unvisited band) pair — see allpairs.topk_rows_banded
        for the exactness argument.  `queries_padded` is the pow2-padded
        packed query batch (first `q_valid` rows real); `query_weights` its
        host sketch weights, used for band planning only.

        `deadline` bounds the band walk (allpairs budgeted mode); when it
        fires, `info_out` (if given) reports partial=True + the residual
        cert_gap, and unfilled id columns carry KBEST_KEY_PAD so the tier
        merge keeps real candidates ahead of them.  Exact calls leave
        info_out with partial=False, cert_gap=0.0."""
        if info_out is not None:
            info_out.update(partial=False, cert_gap=0.0)
        if self._n_alive == 0 or k <= 0 or q_valid == 0:
            return (np.zeros((q_valid, 0), np.int64),
                    np.zeros((q_valid, 0), np.float32))
        qs = prune_score_host(np.asarray(query_weights)[:q_valid], self.d,
                              self.metric)
        st = None if (self._obs_off and info_out is None
                      and deadline is None) else {}
        pos, vals = allpairs.topk_rows_banded(
            queries_padded, self.matrix, k, d=self.d, metric=self.metric,
            q_scores=qs, band_lo=self.band_lo, band_hi=self.band_hi,
            band_rows=self.band_rows, n_valid=self.n, order_by=self.ids,
            block=block, mode=mode, q_valid=q_valid, alive=self._mask(),
            stats_out=st, deadline=deadline)
        if st is not None and not self._obs_off:
            self._c_queries.inc()
            self._c_visited.inc(st["bands_visited"])
            self._c_pruned.inc(st["n_bands"] - st["bands_visited"])
            if st["early_stop"]:
                self._c_early.inc()
        if info_out is not None and st is not None:
            info_out.update(partial=st["partial"],
                            cert_gap=st["cert_gap"],
                            bands_visited=st["bands_visited"],
                            rows_visited=st["rows_visited"])
        # a budget-stopped walk can leave columns unfilled (pos == -1);
        # map them to the KBEST pad id instead of wrapping through ids[-1]
        if st is not None and st["partial"]:
            ids = np.full(pos.shape, KBEST_KEY_PAD, np.int64)
            real = pos >= 0
            ids[real] = self.ids[pos[real]]
            return ids, vals
        return self.ids[pos], vals

    def select(self, band_mask: np.ndarray
               ) -> tuple[jnp.ndarray, int, np.ndarray]:
        """Gather the surviving bands' alive rows: (matrix (pow2, w),
        n_selected, ids (n_selected,)).  Bands are contiguous runs of the
        sorted matrix, so selection is a single padded device take."""
        kept = np.flatnonzero(band_mask)
        if len(kept) == 0:
            return self.matrix[:0], 0, self.ids[:0]
        rows = np.concatenate([
            np.arange(b * self.band_rows,
                      min((b + 1) * self.band_rows, self.n))
            for b in kept])
        mask = self._mask()
        if mask is not None:
            rows = rows[mask[rows]]
        if len(rows) == 0:
            return self.matrix[:0], 0, self.ids[:0]
        return padded_take(self.matrix, rows), len(rows), self.ids[rows]


class TieredLayout:
    """LSM-style incremental layout: sorted base tier + unsorted delta tier.

    The engine's serving structure (DESIGN.md section 8.5).  The base tier
    is a `BandedLayout` over the membership at the last merge; fresh adds
    accumulate as a DELTA of store slots served brute-force by the plain
    batch reductions; removes flip per-tier alive masks.  `sync` advances
    the layout across any version range of the same slot epoch in O(delta)
    — compaction (an epoch bump) or the size-ratio merge policy fold the
    tiers back into one sorted base.

    Exactness: the base tier returns the exact (value, id)-lex k-best over
    its alive rows (the banded certificate), the delta tier's rows are laid
    out in ascending id order so `topk_rows`' lower-column tie-break IS the
    id tie-break, and the two k-best lists merge by (value, id) — the same
    lexicographic merge `topk_rows_banded` uses across chunks.  Tier
    membership partitions the alive set, so the merged answer is
    bit-identical to a fresh batch build (tests/test_index.py pins this
    across tier boundaries, merges, and cache hits).
    """

    def __init__(self, store: SketchStore, metric: str,
                 band_rows: int = 1024, merge_ratio: float | None = 0.125,
                 registry=None):
        self.metric = metric
        self.d = store.d
        self.band_rows = int(band_rows)
        self.merge_ratio = merge_ratio
        self.registry = NULL_REGISTRY if registry is None else registry
        self.n_merges = -1  # the initial build below is not a merge
        self._rebuild(store)

    # -- construction / synchronisation ------------------------------------

    def _rebuild(self, store: SketchStore) -> None:
        """Fold everything into one freshly sorted base tier (the O(N log N)
        path `sync` exists to avoid paying per mutation)."""
        self.base = BandedLayout(store, self.metric,
                                 band_rows=self.band_rows,
                                 registry=self.registry)
        self._store = store
        # per-tier spec record: every row this layout serves was sketched
        # under it, and the cross-version merge keys the query sketch on it
        self.spec = store.spec
        self.delta_slots = np.zeros(0, np.int64)
        self.delta_n = 0
        self.delta_ids = np.zeros(0, np.int64)
        self._delta_cache: jnp.ndarray | None = None
        st = store.stamp()
        self.version, self.epoch, self.seen_size = (
            st.version, st.epoch, st.size)
        self.seen_removed = store.removed_count
        self.n_merges += 1

    def _refresh_delta(self, store: SketchStore,
                       mask: np.ndarray | None = None) -> None:
        """Drop tombstoned delta slots (they never resurrect; `mask` is
        the alive bitmap the sync already read, when it read one) and
        invalidate the gathered view only if the slot set changed —
        O(delta) host filter, NO device work: the gather is deferred to
        the next query, so a burst of mutations between two queries pays
        for one gather, not one per mutation."""
        changed = False
        if mask is not None and not mask.all():
            self.delta_slots = self.delta_slots[mask]
            changed = True
        new_n = len(self.delta_slots)
        if changed or new_n != self.delta_n:  # shrank, or grew via adds
            self._delta_cache = None
        self.delta_n = new_n
        self.delta_ids = store.ids_at(self.delta_slots)

    @property
    def delta_matrix(self) -> jnp.ndarray | None:
        """The delta tier's pow2-padded device matrix, gathered lazily at
        first use after a sync.  jnp.take copies, so the view survives
        later donated appends to the store buffer (unlike gather_alive's
        append-only fast path)."""
        if self._delta_cache is None and self.delta_n:
            self._delta_cache = padded_take(self._store.sk_buf,
                                            self.delta_slots)
        return self._delta_cache

    def sync(self, store: SketchStore) -> "TieredLayout":
        """Advance to the store's current (version, epoch) — THE entry the
        engine calls before serving.  Version unchanged: free.  Adds within
        the epoch: extend the delta tier (O(delta)).  Removes: refresh the
        per-tier alive masks (O(n) host bitmap reads).  Epoch change
        (compaction) or the merge policy tripping: full rebuild."""
        st = store.stamp()
        self._store = store
        if (st.version, st.epoch) == (self.version, self.epoch):
            return self
        if st.epoch != self.epoch or self.merge_ratio == 0:
            # epoch bump (compaction renumbered slots), or merge_ratio=0:
            # the pre-tiered rebuild-per-version baseline, which rebuilt
            # on EVERY mutation — removes included
            self._rebuild(store)
            return self
        added = st.size > self.seen_size
        if added:
            self.delta_slots = np.concatenate(
                [self.delta_slots, store.tail_slots(self.seen_size)])
            self.seen_size = st.size
        removed = store.removed_count != self.seen_removed
        delta_mask = None
        if removed:
            # only a version range that actually contains removes pays the
            # O(n) host bitmap re-read — append-heavy traffic skips it
            self.base.refresh_alive(store)
            self.seen_removed = store.removed_count
            delta_mask = store.alive_at(self.delta_slots)
            live_delta = int(np.count_nonzero(delta_mask))
        else:
            live_delta = len(self.delta_slots)  # filtered at the last sync
        dead_base = self.base.n - self.base.n_alive
        # merge policy: fold when the delta outgrows its share of the base
        # (brute-force delta scans stop being cheap), or when tombstones
        # outnumber alive base rows (the sorted matrix is mostly dead
        # weight).  None never auto-folds (the caller manages folding via
        # compact()).
        if (self.merge_ratio is not None
                and (live_delta > self.merge_ratio * max(self.base.n_alive, 1)
                     or dead_base > max(self.base.n_alive, 1))):
            self._rebuild(store)
            return self
        if added or removed:
            self._refresh_delta(store, delta_mask)
        self.version = st.version
        return self

    # -- introspection ------------------------------------------------------

    @property
    def n_alive(self) -> int:
        return self.base.n_alive + self.delta_n

    # -- serving ------------------------------------------------------------

    def topk(self, queries_padded: jnp.ndarray, query_weights: np.ndarray,
             k: int, *, q_valid: int, block: int = 2048,
             mode: str | None = None, deadline=None,
             info_out: dict | None = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Cross-tier k-NN: (ids (Q, k'), dists (Q, k')), k' = min(k,
        n_alive), ascending by (distance, id) — bit-identical to
        core.allpairs.topk_rows over the full alive membership in id
        order.

        `deadline`/`info_out` budget the BASE tier's band walk only (the
        delta tier is a brute-force scan, already O(delta) and exact); a
        partial base merged with the exact delta is reported partial with
        the base's cert_gap."""
        if info_out is not None:
            info_out.update(partial=False, cert_gap=0.0)
        kk = min(k, self.n_alive)
        if kk <= 0 or q_valid == 0:
            return (np.zeros((q_valid, 0), np.int64),
                    np.zeros((q_valid, 0), np.float32))
        parts: list[tuple[np.ndarray, np.ndarray]] = []
        if self.base.n_alive:
            parts.append(self.base.topk(
                queries_padded, query_weights, kk, q_valid=q_valid,
                block=block, mode=mode, deadline=deadline,
                info_out=info_out))
        if self.delta_n:
            # pad_k keeps k == kk even while the delta holds fewer rows:
            # k is a static jit arg, so letting it track the delta size
            # would recompile on every add (tail pads merge away below)
            pos, vals = allpairs.topk_rows(
                queries_padded, self.delta_matrix, kk, d=self.d,
                metric=self.metric, block=block, mode=mode,
                m_valid=self.delta_n, pad_k=True)
            pos, vals = pos[:q_valid], vals[:q_valid]
            ids = np.full(pos.shape, KBEST_KEY_PAD, np.int64)
            real = pos >= 0
            ids[real] = self.delta_ids[pos[real]]
            parts.append((ids, vals))
        # exact (value, id)-lexicographic merge of the per-tier k-best
        # lists — merge_topk_parts wraps allpairs.kbest_lex_merge, THE same
        # rule as topk_rows_banded's chunk merge.  Tier memberships are
        # disjoint, so on an exact (non-partial) walk kk real candidates
        # always exist and no pad survives the cut; only a budget-stopped
        # base can leave KBEST_KEY_PAD columns in the merged result.
        return merge_topk_parts(kk, parts)

    def radius_tiers(self, query_weights: np.ndarray, radius: float
                     ) -> list[tuple[jnp.ndarray, int, np.ndarray]]:
        """Per-tier (matrix, n_selected, ids) selections for a radius
        query: the base tier after the band prune, the delta tier whole
        (it is small by the merge policy — brute-force is the prune).
        Tier memberships partition the alive set, so the per-tier
        `threshold_pairs` hits union to exactly the batch engine's answer
        on the full membership."""
        out = []
        if self.base.n_alive:
            mask = self.base.candidate_bands(query_weights, radius)
            if not self.registry.is_null:
                kept = int(np.count_nonzero(mask))
                self.base._c_queries.inc()
                self.base._c_visited.inc(kept)
                self.base._c_pruned.inc(self.base.n_bands - kept)
            sel, n_sel, sel_ids = self.base.select(mask)
            if n_sel:
                out.append((sel, n_sel, sel_ids))
        if self.delta_n:
            out.append((self.delta_matrix, self.delta_n, self.delta_ids))
        return out
