"""Weight-banded layout: the query-pruning structure over a store.

A Cabin sketch's Hamming weight bounds how close it can be to anything:
dist(u, v) >= prune_factor(metric) * |s_u - s_v| for the per-row prune score
s (repro.core.allpairs.prune_score_host — the density estimate under cham,
the raw weight under exact hamming).  PR 1 exploited this bound INSIDE the
batch engine's tile loop; the index subsystem hoists it one level up: rows
are kept weight-sorted and partitioned into contiguous BANDS, each band
carrying its host-side score interval, so a radius query discards whole
bands on host — before a single distance tile, device gather, or compile is
touched — and a k-NN query expands outward through the bands nearest the
query, stopping at the exactness certificate (DESIGN.md sections 8.2/8.4).

The prune is sound (the bound holds with PRUNE_MARGIN slack for float
noise), so the surviving candidate set — and therefore every result the
QueryEngine returns — is identical whether bands were pruned or not.  That
is what lets the layout be rebuilt lazily per store version without any
bit-identity risk.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import allpairs
from repro.core.allpairs import PRUNE_MARGIN, prune_factor, prune_score_host
from repro.core.packing import padded_take
from repro.index.store import SketchStore


class BandedLayout:
    """Immutable weight-sorted banded snapshot of a store version.

    Rows are sorted by (sketch weight, id) — a total, history-independent
    order — then cut into bands of `band_rows` consecutive rows.  The device
    matrix holds the sorted rows padded to a power of two; `ids` maps sorted
    positions back to external ids.
    """

    def __init__(self, store: SketchStore, metric: str,
                 band_rows: int = 1024):
        self.metric = metric
        self.d = store.d
        self.band_rows = int(band_rows)
        self.version = store.version
        slots = store.alive_slots()
        weights = store._weights[slots]
        # stable sort over id-ordered rows => total order (weight, id):
        # incremental and fresh builds of the same membership agree exactly.
        order = np.argsort(weights, kind="stable")
        self.n = len(slots)
        self.ids = store._ids[slots][order]
        w_sorted = weights[order]
        self.matrix = padded_take(store.sk_buf, slots[order])
        self.n_bands = -(-self.n // self.band_rows) if self.n else 0
        scores = prune_score_host(w_sorted, self.d, metric)
        self.band_lo = np.asarray(
            [scores[b * self.band_rows] for b in range(self.n_bands)])
        self.band_hi = np.asarray(
            [scores[min((b + 1) * self.band_rows, self.n) - 1]
             for b in range(self.n_bands)])

    def candidate_bands(self, query_weights: np.ndarray, radius: float
                        ) -> np.ndarray:
        """Bool mask over bands: band b survives iff SOME query's score is
        within reach of its [lo, hi] score interval — i.e. the weight bound
        cannot rule out every row in it."""
        if self.n == 0 or len(query_weights) == 0:
            return np.zeros(self.n_bands, bool)
        qs = prune_score_host(np.asarray(query_weights), self.d, self.metric)
        factor = prune_factor(self.metric)
        gap = np.maximum(
            np.maximum(self.band_lo[None, :] - qs[:, None],
                       qs[:, None] - self.band_hi[None, :]), 0.0)
        return (factor * gap < radius + PRUNE_MARGIN).any(axis=0)

    def topk(self, queries_padded: jnp.ndarray, query_weights: np.ndarray,
             k: int, *, q_valid: int, block: int = 2048,
             mode: str | None = None) -> tuple[np.ndarray, np.ndarray]:
        """Progressive band-expansion k-NN: (ids (Q, k), dists (Q, k)),
        ascending by (distance, id) — exactly what core.allpairs.topk_rows
        returns over the id-ordered membership.

        Bands are visited in ascending prune-score distance from the query
        batch, the running k-th best distance is tracked, and the scan stops
        with the certificate `prune_factor * gap >= kth + PRUNE_MARGIN` for
        every (query, unvisited band) pair — see allpairs.topk_rows_banded
        for the exactness argument.  `queries_padded` is the pow2-padded
        packed query batch (first `q_valid` rows real); `query_weights` its
        host sketch weights, used for band planning only."""
        if self.n == 0 or k == 0 or q_valid == 0:
            return (np.zeros((q_valid, 0), np.int64),
                    np.zeros((q_valid, 0), np.float32))
        qs = prune_score_host(np.asarray(query_weights)[:q_valid], self.d,
                              self.metric)
        pos, vals = allpairs.topk_rows_banded(
            queries_padded, self.matrix, k, d=self.d, metric=self.metric,
            q_scores=qs, band_lo=self.band_lo, band_hi=self.band_hi,
            band_rows=self.band_rows, n_valid=self.n, order_by=self.ids,
            block=block, mode=mode, q_valid=q_valid)
        return self.ids[pos], vals

    def select(self, band_mask: np.ndarray
               ) -> tuple[jnp.ndarray, int, np.ndarray]:
        """Gather the surviving bands' rows: (matrix (pow2, w), n_selected,
        ids (n_selected,)).  Bands are contiguous runs of the sorted matrix,
        so selection is a single padded device take."""
        kept = np.flatnonzero(band_mask)
        if len(kept) == 0:
            return self.matrix[:0], 0, self.ids[:0]
        rows = np.concatenate([
            np.arange(b * self.band_rows,
                      min((b + 1) * self.band_rows, self.n))
            for b in kept])
        return padded_take(self.matrix, rows), len(rows), self.ids[rows]
