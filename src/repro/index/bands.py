"""Weight-banded layouts: the query-pruning structures over a store.

A Cabin sketch's Hamming weight bounds how close it can be to anything:
dist(u, v) >= prune_factor(metric) * |s_u - s_v| for the per-row prune score
s (repro.core.allpairs.prune_score_host — the density estimate under cham,
the raw weight under exact hamming).  PR 1 exploited this bound INSIDE the
batch engine's tile loop; the index subsystem hoists it one level up: rows
are kept weight-sorted and partitioned into contiguous BANDS, each band
carrying its host-side score interval, so a radius query discards whole
bands on host — before a single distance tile, device gather, or compile is
touched — and a k-NN query expands outward through the bands nearest the
query, stopping at the exactness certificate (DESIGN.md sections 8.2/8.4).

This module holds ONE layer: `BandedLayout`, an immutable weight-sorted
banded snapshot of a slot set, plus a refreshable ALIVE mask so tombstones
thread through without invalidating the sort or the device matrix.  A
layout can cover any slot subset (a shard's membership, not just the whole
store) and commit its matrix to a specific device — it is the
``sorted-banded`` partition kind of `repro.index.partition` (DESIGN.md
section 13), where the incremental tiering, sharding, and cross-partition
merge logic live (`PartitionSet`, historically `TieredLayout`, plus
`merge_topk_parts` — both re-exported here for back-compat).

Every prune is sound (the weight bound holds with PRUNE_MARGIN slack for
float noise), and the cross-partition merge is the same (value, id)-
lexicographic k-best used inside `topk_rows_banded`, so results are
bit-identical to a fresh batch build of the same membership — banding,
tiering, and sharding are pure serving optimisations with zero
bit-identity risk.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import allpairs
from repro.core.allpairs import (KBEST_KEY_PAD, PRUNE_MARGIN, prune_factor,
                                 prune_score_host)
from repro.core.packing import padded_take
from repro.index.store import SketchStore
from repro.obs.registry import NULL_REGISTRY


class BandedLayout:
    """Immutable weight-sorted banded snapshot of a slot set.

    Rows are sorted by (sketch weight, id) — a total, history-independent
    order — then cut into bands of `band_rows` consecutive rows.  The device
    matrix holds the sorted rows padded to a power of two; `ids` maps sorted
    positions back to external ids and `slots` back to store slots.  The
    snapshot can cover any slot SUBSET (`slots` — a shard's membership; the
    default is the whole alive store) and commit its matrix to a `device`,
    so the distance tiles against it run where its rows live.

    The snapshot itself never mutates; later tombstones are threaded
    through `refresh_alive`, which re-reads the store's host bitmap at the
    snapshot's slots (O(n) host work, no device traffic).  Band score
    intervals are computed over the snapshot's rows and therefore stay
    conservative supersets for any alive subset — masked queries prune a
    little less but never wrongly.
    """

    def __init__(self, store: SketchStore, metric: str,
                 band_rows: int = 1024, registry=None,
                 slots: np.ndarray | None = None, device=None):
        # banding effectiveness counters: visited vs pruned per query, and
        # how often the exactness certificate stopped the scan early.  The
        # instruments are cached here once — under NULL_REGISTRY they are
        # shared no-ops and the stats_out dict is never even built.
        reg = NULL_REGISTRY if registry is None else registry
        self._obs_off = reg.is_null
        self._c_queries = reg.counter("index_banded_queries_total")
        self._c_visited = reg.counter("index_bands_visited_total")
        self._c_pruned = reg.counter("index_bands_pruned_total")
        self._c_early = reg.counter("index_band_early_stops_total")
        self.metric = metric
        self.d = store.d
        self.band_rows = int(band_rows)
        self.version = store.version
        self.device = device
        if slots is None:
            slots = store.alive_slots()
        weights = store.weights_at(slots)
        # stable sort over id-ordered rows => total order (weight, id):
        # incremental and fresh builds of the same membership agree exactly,
        # and so do sharded and unsharded builds of the same shard subset
        # (slots arrive in ascending id order either way).
        order = np.argsort(weights, kind="stable")
        self.n = len(slots)
        self.slots = slots[order]
        self.ids = store.ids_at(slots)[order]
        w_sorted = weights[order]
        self.matrix = padded_take(store.sk_buf, self.slots)
        if device is not None:
            self.matrix = jax.device_put(self.matrix, device)
        self.alive = np.ones(self.n, bool)
        self._n_alive = self.n
        self.n_bands = -(-self.n // self.band_rows) if self.n else 0
        scores = prune_score_host(w_sorted, self.d, metric)
        self.band_lo = np.asarray(
            [scores[b * self.band_rows] for b in range(self.n_bands)])
        self.band_hi = np.asarray(
            [scores[min((b + 1) * self.band_rows, self.n) - 1]
             for b in range(self.n_bands)])

    @property
    def n_alive(self) -> int:
        return self._n_alive

    def refresh_alive(self, store: SketchStore) -> None:
        """Re-read the store's tombstone bitmap at this snapshot's slots —
        how removes reach a layout without any rebuild or device work."""
        if self.n:
            self.alive = store.alive_at(self.slots)
            self._n_alive = int(np.count_nonzero(self.alive))

    def _mask(self) -> np.ndarray | None:
        # None keeps the fully-alive hot path identical to the pre-mask one
        return None if self._n_alive == self.n else self.alive

    def candidate_bands(self, query_weights: np.ndarray, radius: float
                        ) -> np.ndarray:
        """Bool mask over bands: band b survives iff SOME query's score is
        within reach of its [lo, hi] score interval — i.e. the weight bound
        cannot rule out every row in it."""
        if self.n == 0 or len(query_weights) == 0:
            return np.zeros(self.n_bands, bool)
        qs = prune_score_host(np.asarray(query_weights), self.d, self.metric)
        factor = prune_factor(self.metric)
        gap = np.maximum(
            np.maximum(self.band_lo[None, :] - qs[:, None],
                       qs[:, None] - self.band_hi[None, :]), 0.0)
        return (factor * gap < radius + PRUNE_MARGIN).any(axis=0)

    def topk(self, queries_padded: jnp.ndarray, query_weights: np.ndarray,
             k: int, *, q_valid: int, block: int = 2048,
             mode: str | None = None, deadline=None,
             info_out: dict | None = None,
             init_kth: np.ndarray | None = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Progressive band-expansion k-NN: (ids (Q, k'), dists (Q, k')),
        k' = min(k, n_alive), ascending by (distance, id) — exactly what
        core.allpairs.topk_rows returns over the alive membership in id
        order.

        Bands are visited in ascending prune-score distance from the query
        batch, the running k-th best distance is tracked, and the scan stops
        with the certificate `prune_factor * gap >= kth + PRUNE_MARGIN` for
        every (query, unvisited band) pair — see allpairs.topk_rows_banded
        for the exactness argument.  `queries_padded` is the pow2-padded
        packed query batch (first `q_valid` rows real); `query_weights` its
        host sketch weights, used for band planning only.

        `init_kth` seeds the certificate with a cross-partition k-th bound
        (per query, length >= q_valid): rows pruned under it are provably
        outside the GLOBAL merged top-k, so this layout returns a
        sufficient — not necessarily full — k-best whose unfilled columns
        carry KBEST_KEY_PAD and merge away.  `deadline` bounds the band
        walk (allpairs budgeted mode); when it fires, `info_out` (if given)
        reports partial=True + the residual cert_gap.  Exact calls leave
        info_out with partial=False, cert_gap=0.0."""
        if info_out is not None:
            info_out.update(partial=False, cert_gap=0.0)
        if self._n_alive == 0 or k <= 0 or q_valid == 0:
            return (np.zeros((q_valid, 0), np.int64),
                    np.zeros((q_valid, 0), np.float32))
        qs = prune_score_host(np.asarray(query_weights)[:q_valid], self.d,
                              self.metric)
        st = None if (self._obs_off and info_out is None
                      and deadline is None) else {}
        pos, vals = allpairs.topk_rows_banded(
            queries_padded, self.matrix, k, d=self.d, metric=self.metric,
            q_scores=qs, band_lo=self.band_lo, band_hi=self.band_hi,
            band_rows=self.band_rows, n_valid=self.n, order_by=self.ids,
            block=block, mode=mode, q_valid=q_valid, alive=self._mask(),
            stats_out=st, deadline=deadline, init_kth=init_kth)
        if st is not None and not self._obs_off:
            self._c_queries.inc()
            self._c_visited.inc(st["bands_visited"])
            self._c_pruned.inc(st["n_bands"] - st["bands_visited"])
            if st["early_stop"]:
                self._c_early.inc()
        if info_out is not None and st is not None:
            info_out.update(partial=st["partial"],
                            cert_gap=st["cert_gap"],
                            bands_visited=st["bands_visited"],
                            rows_visited=st["rows_visited"])
        # a budget-stopped walk — or a cross-partition bound proving rows
        # here can't enter the merged top-k — can leave columns unfilled
        # (pos == -1); map them to the KBEST pad id instead of wrapping
        # through ids[-1]
        if (pos < 0).any():
            ids = np.full(pos.shape, KBEST_KEY_PAD, np.int64)
            real = pos >= 0
            ids[real] = self.ids[pos[real]]
            return ids, vals
        return self.ids[pos], vals

    def select(self, band_mask: np.ndarray
               ) -> tuple[jnp.ndarray, int, np.ndarray]:
        """Gather the surviving bands' alive rows: (matrix (pow2, w),
        n_selected, ids (n_selected,)).  Bands are contiguous runs of the
        sorted matrix, so selection is a single padded device take."""
        kept = np.flatnonzero(band_mask)
        if len(kept) == 0:
            return self.matrix[:0], 0, self.ids[:0]
        rows = np.concatenate([
            np.arange(b * self.band_rows,
                      min((b + 1) * self.band_rows, self.n))
            for b in kept])
        mask = self._mask()
        if mask is not None:
            rows = rows[mask[rows]]
        if len(rows) == 0:
            return self.matrix[:0], 0, self.ids[:0]
        return padded_take(self.matrix, rows), len(rows), self.ids[rows]


def __getattr__(name: str):
    # back-compat lazy re-exports: the LSM tier layer moved to
    # repro.index.partition (TieredLayout is PartitionSet's n_shards=1
    # face, merge_topk_parts is the shared cross-partition merge rule).
    # PEP 562 indirection instead of a top-level import keeps
    # bands -> partition -> bands from becoming an import cycle.
    if name in ("TieredLayout", "merge_topk_parts"):
        from repro.index import partition
        return getattr(partition, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
