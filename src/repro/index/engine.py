"""QueryEngine: batched online similarity serving over a SketchStore.

The public boundary of the index subsystem.  Raw categorical rows — dense
(k, n) matrices or padded-COO (indices, values) pairs — go in; external ids
and distances come out.  Sketching happens inside (`core.cabin.sketch_dense`
/ `sketch_sparse`, which auto-dispatch to the fused Pallas kernels on TPU),
so callers never handle packed words, seeds, or layouts.

Serving disciplines (DESIGN.md section 8.3):

  * Micro-batch shape bucketing.  Every ingest and query batch is padded to
    a power-of-two row count (and nnz width for COO) before touching a jit
    boundary; together with the store's traced valid-row counts this keeps
    the number of compiled graphs O(log N + log Q) across arbitrary
    request mixes.  Padding rows are all-zero categorical vectors, whose
    sketches are all-zero and which every reduction masks out — they can
    never contaminate a result.
  * Tiered serving.  Queries serve through a TieredLayout (DESIGN.md 8.5):
    a big weight-sorted base tier that SURVIVES mutations, a small delta
    tier of fresh adds scanned brute-force, and per-tier alive masks for
    removes.  `_layout()` syncs the layout across the version RANGE since
    it was built — a mutation costs the next query O(delta), not the
    O(N log N) rebuild the old version-equality invalidation paid.
  * Bit-identity.  `topk` serves through the base tier's progressive band
    expansion (allpairs.topk_rows_banded — nearest bands first, stop at the
    exactness certificate) merged with the delta tier by (value, id), and
    `radius` through threshold_pairs per tier; both are bit-identical to
    running the batch engine on a freshly built matrix of the same vectors
    — across any interleaving of add/remove/compact, after checkpoint
    restore, and under both metrics.  Ties in topk resolve to the lower
    id, matching topk_rows' stable merge.
  * LRU result cache.  Results are memoised on (op, args, store version,
    query-sketch bytes); any mutation bumps the version, so stale hits are
    impossible by construction.

Persistence snapshots flow through checkpoint.Checkpointer (flat-tree save
of the store buffers + hash seeds + metadata), and `shard` opt-in places the
store rows across the data axes of a mesh via distributed.sharding.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np
import jax.numpy as jnp

from repro.core import allpairs, packing
from repro.core.cabin import (CabinParams, sketch_dense_jit,
                              sketch_sparse_jit)
from repro.core.packing import pad_rows_pow2, pow2_bucket
from repro.index.bands import BandedLayout, TieredLayout
from repro.index.store import SketchStore

_METRICS = ("cham", "hamming")


class QueryEngine:
    """Online k-NN / radius serving over Cabin sketches.

    Parameters
    ----------
    params : CabinParams — hash seeds + dims; all ingested and queried rows
        must share them (they define the sketch space).
    metric : "cham" (estimated categorical HD) or "hamming" (exact sketch
        HD) — fixed per engine so cached results and layouts stay coherent.
    block / mode : tile size and backend forwarded to core.allpairs.
    band_rows : rows per weight band (radius-query pruning granularity).
    cache_entries : LRU result-cache capacity (0 disables caching).
    merge_ratio : tiered-layout merge policy (DESIGN.md 8.5).  Fresh adds
        accumulate in a small unsorted delta tier and fold into the sorted
        base tier once the live delta exceeds `merge_ratio * base_alive`
        rows; until then a mutation costs the next query O(delta) instead
        of a full O(N log N) layout rebuild.  0 merges on every mutation
        (the pre-tiered rebuild-per-version behaviour — the bench baseline);
        None never auto-merges (fold only on `compact()`).
    """

    def __init__(self, params: CabinParams, *, metric: str = "cham",
                 block: int = 2048, mode: str | None = None,
                 band_rows: int = 1024, cache_entries: int = 256,
                 merge_ratio: float | None = 0.125):
        if metric not in _METRICS:
            raise ValueError(f"metric must be one of {_METRICS}")
        self.params = params
        self.metric = metric
        self.block = block
        self.mode = mode
        self.band_rows = band_rows
        self.merge_ratio = merge_ratio
        self.store = SketchStore(params.sketch_dim)
        self._tiered: TieredLayout | None = None
        self._cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._cache_entries = cache_entries
        self.cache_hits = 0
        self.cache_misses = 0

    # -- basics -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.store)

    @property
    def d(self) -> int:
        return self.params.sketch_dim

    def ids(self) -> np.ndarray:
        return self.store.ids()

    def stats(self) -> dict:
        t = self._tiered
        return {
            "n_alive": len(self.store),
            "size": self.store.size,
            "capacity": self.store.capacity,
            "version": self.store.version,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "n_bands": t.base.n_bands if t else None,
            "base_rows": t.base.n if t else None,
            "base_alive": t.base.n_alive if t else None,
            "delta_rows": t.delta_n if t else None,
            "tier_merges": t.n_merges if t else None,
        }

    # -- sketching (shape-bucketed) ----------------------------------------

    def _sketch(self, queries) -> tuple[jnp.ndarray, int]:
        """Raw categorical input -> (packed sketches (pow2-padded, w), k).

        `queries` is a dense (k, n_dims) int array, or an (indices, values)
        padded-COO pair.  Both layouts are padded to power-of-two buckets
        (rows, and nnz width for COO) so the sketch jits are reused across
        request sizes; zero padding is inert under psi/pi by construction.
        """
        if isinstance(queries, (tuple, list)):
            idx_host, val_host = queries
            # validate on host BEFORE the device transfer: no sync on the
            # serving path when (as usual) the input is already numpy
            idx_host = np.asarray(idx_host)
            if idx_host.shape != np.shape(val_host) or idx_host.ndim != 2:
                raise ValueError("COO input needs matching (k, m) "
                                 "indices/values")
            if idx_host.size and (idx_host.max() >= self.params.n_dims
                                  or idx_host.min() < 0):
                raise ValueError(
                    f"COO indices out of range [0, {self.params.n_dims})")
            indices = jnp.asarray(idx_host, jnp.int32)
            values = jnp.asarray(val_host, jnp.int32)
            k = indices.shape[0]
            if k == 0:
                return jnp.zeros((0, self.store.w), jnp.int32), 0
            mpad = pow2_bucket(indices.shape[1])
            wpad = ((0, pow2_bucket(k) - k), (0, mpad - indices.shape[1]))
            sk = sketch_sparse_jit(self.params, jnp.pad(indices, wpad),
                                   jnp.pad(values, wpad))
            return sk, k
        x = jnp.asarray(queries, jnp.int32)
        if x.ndim != 2 or x.shape[1] != self.params.n_dims:
            raise ValueError(
                f"expected dense (k, {self.params.n_dims}) rows, "
                f"got {x.shape}")
        k = x.shape[0]
        if k == 0:
            return jnp.zeros((0, self.store.w), jnp.int32), 0
        return sketch_dense_jit(self.params, pad_rows_pow2(x)), k

    # -- ingestion ----------------------------------------------------------

    def add_dense(self, x) -> np.ndarray:
        """Ingest dense categorical rows (k, n_dims); returns ids (k,)."""
        sk, k = self._sketch(x)
        return self.store.add(sk, n_valid=k)

    def add_sparse(self, indices, values) -> np.ndarray:
        """Ingest padded-COO categorical rows; returns ids (k,)."""
        sk, k = self._sketch((indices, values))
        return self.store.add(sk, n_valid=k)

    def add_packed(self, packed) -> np.ndarray:
        """Ingest pre-sketched packed rows (k, w).  The rows MUST come from
        this engine's CabinParams — used by streaming ingest after an
        in-window dedup pass already paid for the sketches."""
        packed = jnp.asarray(packed)
        return self.store.add(pad_rows_pow2(packed),
                              n_valid=packed.shape[0])

    def remove(self, ids) -> int:
        return self.store.remove(ids)

    def compact(self) -> None:
        self.store.compact()

    # -- result cache -------------------------------------------------------

    def _cached(self, key):
        if key is not None and key in self._cache:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            return self._cache[key]
        return None

    def _remember(self, key, value) -> None:
        """Store a PRIVATE copy of `value` (key=None: caching disabled) —
        both hit and miss paths hand callers arrays they may freely
        mutate without corrupting later hits."""
        self.cache_misses += 1
        if key is None:
            return
        if isinstance(value, tuple):
            self._cache[key] = tuple(a.copy() for a in value)
        else:
            self._cache[key] = [a.copy() for a in value]
        if len(self._cache) > self._cache_entries:
            self._cache.popitem(last=False)

    # -- queries ------------------------------------------------------------

    def topk(self, queries, k: int) -> tuple[np.ndarray, np.ndarray]:
        """k nearest stored rows per query: (ids (Q, k'), dists (Q, k')),
        ascending by distance, k' = min(k, len(store)).  Accepts dense rows
        or an (indices, values) COO pair; `topk_packed` skips sketching.
        Raises ValueError for k < 0 (k = 0 is a valid empty query)."""
        if k < 0:
            raise ValueError(f"topk: k must be >= 0, got {k}")
        sk, q = self._sketch(queries)
        return self.topk_packed(sk, k, n_valid=q)

    def topk_packed(self, sk, k: int, n_valid: int | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Served through the tiered layout (TieredLayout.topk): the base
        tier's progressive band expansion visits bands nearest-first and
        stops at the exactness certificate, the delta tier of fresh adds is
        scanned brute-force, and the two merge by (value, id) — so a query
        touches O(answer neighbourhood + delta) rows, not O(N), while
        returning bit-identical results to topk_rows over the alive
        membership.  The LRU is consulted on the query-sketch bytes BEFORE
        the layout or any device gather is touched: a cache hit costs O(1)
        host work regardless of store size."""
        if k < 0:
            raise ValueError(f"topk: k must be >= 0, got {k}")
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        kk = min(k, len(self.store))
        if q == 0 or kk == 0:
            return (np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32))
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None  # caching disabled: skip the device sync for the key
        if self._cache_entries:
            key = ("topk", kk, self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                return hit[0].copy(), hit[1].copy()
        layout = self._layout()
        q_weights = packing.np_popcount_rows(q_host)
        out = layout.topk(pad_rows_pow2(sk), q_weights, kk, q_valid=q,
                          block=self.block, mode=self.mode)
        self._remember(key, out)
        return out

    def radius(self, queries, r: float) -> list[np.ndarray]:
        """All stored rows within distance < r of each query: a list of Q
        id arrays (ascending).  Weight bands whose score interval is out of
        reach are pruned on host before any tile is computed; the delta
        tier of fresh adds is scanned brute-force.  Accepts dense rows or
        an (indices, values) COO pair; `radius_packed` skips sketching.

        Distances are nonnegative and the test is strict (`dist < r`), so
        r <= 0 returns an empty id array for every query — an explicit
        contract, not an error (negative radii short-circuit before any
        layout or device work)."""
        sk, q = self._sketch(queries)
        return self.radius_packed(sk, r, n_valid=q)

    def radius_packed(self, sk, r: float, n_valid: int | None = None
                      ) -> list[np.ndarray]:
        """Pre-sketched twin of `radius` (same r <= 0 -> empty contract)."""
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        if q == 0:
            return []
        if r <= 0:  # dist >= 0 and the test is strict: provably no hits
            return [np.zeros(0, np.int64) for _ in range(q)]
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None
        if self._cache_entries:
            key = ("radius", float(r), self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                return [a.copy() for a in hit]
        hits: list[list[np.ndarray]] = [[] for _ in range(q)]
        if len(self.store):
            layout = self._layout()
            q_weights = packing.np_popcount_rows(q_host)
            # tier memberships partition the alive set: per-tier hits union
            # to exactly the batch engine's answer on the full membership
            for sel, n_sel, sel_ids in layout.radius_tiers(q_weights, r):
                pairs = allpairs.threshold_pairs(
                    pad_rows_pow2(sk), sel, d=self.d, threshold=r,
                    metric=self.metric, block=min(self.block, 256),
                    mode=self.mode, n_valid=q, m_valid=n_sel)
                # one sort/group pass instead of a pairs scan per query
                by_q = pairs[np.argsort(pairs[:, 0], kind="stable")]
                splits = np.searchsorted(by_q[:, 0], np.arange(q + 1))
                for qi in range(q):
                    seg = sel_ids[by_q[splits[qi]: splits[qi + 1], 1]]
                    if seg.size:
                        hits[qi].append(seg)
        out = [np.sort(np.concatenate(h)) if h else np.zeros(0, np.int64)
               for h in hits]
        self._remember(key, out)
        return out

    def pairwise(self, queries, ids=None) -> tuple[np.ndarray, np.ndarray]:
        """Engine-metric distance matrix (Q, N') between queries and the
        given stored ids (default: all alive rows, id order) — the
        re-ranking path, served by the kernels.hamming query-vs-store tiles.
        Returns (ids (N',), dists (Q, N') f32).  Under "hamming" entries are
        exact integers; under "cham" they agree with topk/radius distances
        to cross-graph libm noise (~1e-7 relative), not bit-for-bit — the
        bit-identity contract belongs to topk/radius, which always go
        through core.allpairs."""
        from repro.kernels.hamming import ops as hamming_ops

        sk, q = self._sketch(queries)
        view = self.store.gather_alive()
        # cheap stale-view guard BEFORE anything dereferences the matrix
        # (the id-subset padded_take below, then the kernel call): a view
        # predating a mutation (re-entrant callback, another thread) fails
        # here with a clear message instead of jax's opaque "Array has
        # been deleted" after a donated append
        self.store.check_fresh(view)
        mat, m, all_ids = view
        # keep everything pow2-bucketed (sk and mat already are; id subsets
        # go through padded_take) so the kernel's compile cache stays
        # O(log N) across mutations — same discipline as topk/radius
        if ids is None:
            sel_ids = all_ids
            sel, n_sel = mat, m
        else:
            sel_ids = np.atleast_1d(np.asarray(ids, np.int64))
            if len(np.unique(sel_ids)) != len(sel_ids):
                # consistent with SketchStore.remove: duplicate ids are a
                # caller bug, not a request for duplicated columns
                raise ValueError("pairwise: duplicate ids in batch")
            pos = np.searchsorted(all_ids, sel_ids)
            if m == 0 or (pos >= m).any() or (all_ids[np.minimum(pos, m - 1)]
                                              != sel_ids).any():
                raise KeyError("pairwise: id not in store")
            sel = packing.padded_take(mat, pos)
            n_sel = len(pos)
        dists = np.asarray(hamming_ops.dist_matrix(
            sk, sel, self.d, metric=self.metric))[:q, :n_sel]
        return sel_ids, dists

    def cluster(self, k: int, **kwargs) -> "object":
        """Attach a `repro.cluster.ClusterIndex` maintaining k-medoid
        centres and per-row labels over this engine's store: fresh adds are
        assigned to their nearest centre as they arrive (through this
        engine's own serving path), removes update the per-cluster
        bookkeeping, and `refit()` re-clusters the live membership with the
        device k-mode engine.  Keyword args (seed/n_iter/block/refit_every)
        forward to ClusterIndex; see repro/cluster/online.py.  The store
        keeps a strong reference to the attached index — `detach()` an old
        one before attaching a replacement."""
        from repro.cluster import ClusterIndex  # local: repro.cluster
        # imports this module, so the hook resolves the cycle lazily

        return ClusterIndex(self, k, **kwargs)

    def sync_layout(self) -> TieredLayout:
        """Sync the serving layout to the store's current version and
        return it — the maintenance the next query would otherwise pay
        inline.  Validity is a version RANGE, not version equality: within
        a slot epoch the sync absorbs adds into the delta tier and removes
        into the alive masks in O(delta); only compaction (epoch bump) or
        the merge policy pays a rebuild.  Calling this after an ingest
        burst keeps tail latency flat; queries call it implicitly."""
        if self._tiered is None:
            self._tiered = TieredLayout(self.store, self.metric,
                                        band_rows=self.band_rows,
                                        merge_ratio=self.merge_ratio)
        return self._tiered.sync(self.store)

    _layout = sync_layout  # internal alias used by the query paths

    def _banded_layout(self) -> BandedLayout:
        """The synced layout's BASE tier (introspection + tests; serving
        goes through `_layout`, which also covers the delta tier)."""
        return self._layout().base

    # -- persistence --------------------------------------------------------

    def save(self, directory: str, step: int = 0, keep: int = 3) -> None:
        """Snapshot the full index (store buffers + hash params + metadata)
        via checkpoint.Checkpointer — same atomic-publish layout as model
        checkpoints, so index snapshots ride the existing retention/GC."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, keep=keep, async_save=False)
        meta = {
            "format": "repro.index.v1",
            "metric": self.metric,
            "n_dims": self.params.n_dims,
            "sketch_dim": self.params.sketch_dim,
            "psi_seed": self.params.psi_seed,
            "pi_seed": self.params.pi_seed,
            **self.store.state_meta(),
        }
        ckpt.save(step, self.store.state_tree(), extra_meta=meta, block=True)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **engine_kwargs) -> "QueryEngine":
        """Rebuild an engine from a snapshot; queries against the restored
        engine are bit-identical to the engine that saved it."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, async_save=False)
        if step is None:
            step = ckpt.latest_step()
            if step is None:
                raise FileNotFoundError(f"no index snapshots in {directory}")
        meta = ckpt.meta(step)
        if meta.get("format") != "repro.index.v1":
            raise ValueError(f"not an index snapshot: {directory}")
        if "metric" in engine_kwargs:
            raise ValueError("metric is fixed by the snapshot "
                             f"({meta['metric']!r}); it cannot be overridden "
                             "on restore")
        w = packing.packed_width(int(meta["sketch_dim"]))
        like = {
            "sk": np.zeros((0, w), np.int32),
            "ids": np.zeros(0, np.int64),
            "alive": np.zeros(0, bool),
            "weights": np.zeros(0, np.int64),
        }
        tree, _ = ckpt.restore(like, step=step)
        params = CabinParams(
            n_dims=int(meta["n_dims"]), sketch_dim=int(meta["sketch_dim"]),
            psi_seed=int(meta["psi_seed"]), pi_seed=int(meta["pi_seed"]))
        eng = cls(params, metric=meta["metric"], **engine_kwargs)
        eng.store = SketchStore.from_state(tree, meta)
        return eng

    # -- placement ----------------------------------------------------------

    def shard(self, mesh=None) -> None:
        """Opt-in: place the store's row buffers across the data-parallel
        axes of `mesh` (default: the ambient mesh).  Query math is
        unchanged — the tiled reductions run under GSPMD with the rows
        split across devices; integer pair statistics keep results
        bit-identical to the unsharded engine."""
        from repro.distributed import sharding as shd

        mesh = mesh if mesh is not None else shd.current_mesh()
        if mesh is None:
            raise ValueError("shard() needs a mesh (none active)")
        self.store.place(
            lambda shape: shd.batch_sharding_for(mesh, shape))
