"""QueryEngine: batched online similarity serving over a SketchStore.

The public boundary of the index subsystem.  Raw categorical rows — dense
(k, n) matrices or padded-COO (indices, values) pairs — go in; external ids
and distances come out.  Sketching happens inside (`core.cabin.sketch_dense`
/ `sketch_sparse`, which auto-dispatch to the fused Pallas kernels on TPU),
so callers never handle packed words, seeds, or layouts.

Serving disciplines (DESIGN.md section 8.3):

  * Micro-batch shape bucketing.  Every ingest and query batch is padded to
    a power-of-two row count (and nnz width for COO) before touching a jit
    boundary; together with the store's traced valid-row counts this keeps
    the number of compiled graphs O(log N + log Q) across arbitrary
    request mixes.  Padding rows are all-zero categorical vectors, whose
    sketches are all-zero and which every reduction masks out — they can
    never contaminate a result.
  * Bit-identity.  `topk` serves through BandedLayout's progressive band
    expansion (allpairs.topk_rows_banded — nearest bands first, stop at the
    exactness certificate) and `radius` through threshold_pairs over the
    band-pruned rows; both are bit-identical to running the batch engine on
    a freshly built matrix of the same vectors — across any interleaving of
    add/remove/compact, after checkpoint restore, and under both metrics.
    Ties in topk resolve to the lower id, matching topk_rows' stable merge.
  * LRU result cache.  Results are memoised on (op, args, store version,
    query-sketch bytes); any mutation bumps the version, so stale hits are
    impossible by construction.

Persistence snapshots flow through checkpoint.Checkpointer (flat-tree save
of the store buffers + hash seeds + metadata), and `shard` opt-in places the
store rows across the data axes of a mesh via distributed.sharding.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np
import jax.numpy as jnp

from repro.core import allpairs, packing
from repro.core.cabin import (CabinParams, sketch_dense_jit,
                              sketch_sparse_jit)
from repro.core.packing import pad_rows_pow2, pow2_bucket
from repro.index.bands import BandedLayout
from repro.index.store import SketchStore

_METRICS = ("cham", "hamming")


class QueryEngine:
    """Online k-NN / radius serving over Cabin sketches.

    Parameters
    ----------
    params : CabinParams — hash seeds + dims; all ingested and queried rows
        must share them (they define the sketch space).
    metric : "cham" (estimated categorical HD) or "hamming" (exact sketch
        HD) — fixed per engine so cached results and layouts stay coherent.
    block / mode : tile size and backend forwarded to core.allpairs.
    band_rows : rows per weight band (radius-query pruning granularity).
    cache_entries : LRU result-cache capacity (0 disables caching).
    """

    def __init__(self, params: CabinParams, *, metric: str = "cham",
                 block: int = 2048, mode: str | None = None,
                 band_rows: int = 1024, cache_entries: int = 256):
        if metric not in _METRICS:
            raise ValueError(f"metric must be one of {_METRICS}")
        self.params = params
        self.metric = metric
        self.block = block
        self.mode = mode
        self.band_rows = band_rows
        self.store = SketchStore(params.sketch_dim)
        self._banded: BandedLayout | None = None
        self._cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._cache_entries = cache_entries
        self.cache_hits = 0
        self.cache_misses = 0

    # -- basics -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.store)

    @property
    def d(self) -> int:
        return self.params.sketch_dim

    def ids(self) -> np.ndarray:
        return self.store.ids()

    def stats(self) -> dict:
        return {
            "n_alive": len(self.store),
            "size": self.store.size,
            "capacity": self.store.capacity,
            "version": self.store.version,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "n_bands": self._banded.n_bands if self._banded else None,
        }

    # -- sketching (shape-bucketed) ----------------------------------------

    def _sketch(self, queries) -> tuple[jnp.ndarray, int]:
        """Raw categorical input -> (packed sketches (pow2-padded, w), k).

        `queries` is a dense (k, n_dims) int array, or an (indices, values)
        padded-COO pair.  Both layouts are padded to power-of-two buckets
        (rows, and nnz width for COO) so the sketch jits are reused across
        request sizes; zero padding is inert under psi/pi by construction.
        """
        if isinstance(queries, (tuple, list)):
            idx_host, val_host = queries
            # validate on host BEFORE the device transfer: no sync on the
            # serving path when (as usual) the input is already numpy
            idx_host = np.asarray(idx_host)
            if idx_host.shape != np.shape(val_host) or idx_host.ndim != 2:
                raise ValueError("COO input needs matching (k, m) "
                                 "indices/values")
            if idx_host.size and (idx_host.max() >= self.params.n_dims
                                  or idx_host.min() < 0):
                raise ValueError(
                    f"COO indices out of range [0, {self.params.n_dims})")
            indices = jnp.asarray(idx_host, jnp.int32)
            values = jnp.asarray(val_host, jnp.int32)
            k = indices.shape[0]
            if k == 0:
                return jnp.zeros((0, self.store.w), jnp.int32), 0
            mpad = pow2_bucket(indices.shape[1])
            wpad = ((0, pow2_bucket(k) - k), (0, mpad - indices.shape[1]))
            sk = sketch_sparse_jit(self.params, jnp.pad(indices, wpad),
                                   jnp.pad(values, wpad))
            return sk, k
        x = jnp.asarray(queries, jnp.int32)
        if x.ndim != 2 or x.shape[1] != self.params.n_dims:
            raise ValueError(
                f"expected dense (k, {self.params.n_dims}) rows, "
                f"got {x.shape}")
        k = x.shape[0]
        if k == 0:
            return jnp.zeros((0, self.store.w), jnp.int32), 0
        return sketch_dense_jit(self.params, pad_rows_pow2(x)), k

    # -- ingestion ----------------------------------------------------------

    def add_dense(self, x) -> np.ndarray:
        """Ingest dense categorical rows (k, n_dims); returns ids (k,)."""
        sk, k = self._sketch(x)
        return self.store.add(sk, n_valid=k)

    def add_sparse(self, indices, values) -> np.ndarray:
        """Ingest padded-COO categorical rows; returns ids (k,)."""
        sk, k = self._sketch((indices, values))
        return self.store.add(sk, n_valid=k)

    def add_packed(self, packed) -> np.ndarray:
        """Ingest pre-sketched packed rows (k, w).  The rows MUST come from
        this engine's CabinParams — used by streaming ingest after an
        in-window dedup pass already paid for the sketches."""
        packed = jnp.asarray(packed)
        return self.store.add(pad_rows_pow2(packed),
                              n_valid=packed.shape[0])

    def remove(self, ids) -> int:
        return self.store.remove(ids)

    def compact(self) -> None:
        self.store.compact()

    # -- result cache -------------------------------------------------------

    def _cached(self, key):
        if key is not None and key in self._cache:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            return self._cache[key]
        return None

    def _remember(self, key, value) -> None:
        """Store a PRIVATE copy of `value` (key=None: caching disabled) —
        both hit and miss paths hand callers arrays they may freely
        mutate without corrupting later hits."""
        self.cache_misses += 1
        if key is None:
            return
        if isinstance(value, tuple):
            self._cache[key] = tuple(a.copy() for a in value)
        else:
            self._cache[key] = [a.copy() for a in value]
        if len(self._cache) > self._cache_entries:
            self._cache.popitem(last=False)

    # -- queries ------------------------------------------------------------

    def topk(self, queries, k: int) -> tuple[np.ndarray, np.ndarray]:
        """k nearest stored rows per query: (ids (Q, k'), dists (Q, k')),
        ascending by distance, k' = min(k, len(store)).  Accepts dense rows
        or an (indices, values) COO pair; `topk_packed` skips sketching."""
        sk, q = self._sketch(queries)
        return self.topk_packed(sk, k, n_valid=q)

    def topk_packed(self, sk, k: int, n_valid: int | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Served via progressive band expansion (BandedLayout.topk): bands
        are visited nearest-first and the scan stops at the exactness
        certificate, so a query touches O(answer neighbourhood) rows, not
        O(N) — while returning bit-identical results to topk_rows over the
        alive membership.  The LRU is consulted on the query-sketch bytes
        BEFORE the layout or any device gather is touched: a cache hit costs
        O(1) host work regardless of store size."""
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        kk = min(k, len(self.store))
        if q == 0 or kk == 0:
            return (np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32))
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None  # caching disabled: skip the device sync for the key
        if self._cache_entries:
            key = ("topk", kk, self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                return hit[0].copy(), hit[1].copy()
        banded = self._banded_layout()
        q_weights = packing.np_popcount_rows(q_host)
        out = banded.topk(pad_rows_pow2(sk), q_weights, kk, q_valid=q,
                          block=self.block, mode=self.mode)
        self._remember(key, out)
        return out

    def radius(self, queries, r: float) -> list[np.ndarray]:
        """All stored rows within distance < r of each query: a list of Q
        id arrays (ascending).  Weight bands whose score interval is out of
        reach are pruned on host before any tile is computed.  Accepts
        dense rows or an (indices, values) COO pair; `radius_packed` skips
        sketching."""
        sk, q = self._sketch(queries)
        return self.radius_packed(sk, r, n_valid=q)

    def radius_packed(self, sk, r: float, n_valid: int | None = None
                      ) -> list[np.ndarray]:
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        if q == 0:
            return []
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None
        if self._cache_entries:
            key = ("radius", float(r), self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                return [a.copy() for a in hit]
        out = [np.zeros(0, np.int64) for _ in range(q)]
        n_sel = 0
        if len(self.store):
            banded = self._banded_layout()
            q_weights = packing.np_popcount_rows(q_host)
            mask = banded.candidate_bands(q_weights, r)
            sel, n_sel, sel_ids = banded.select(mask)
        if n_sel:
            pairs = allpairs.threshold_pairs(
                pad_rows_pow2(sk), sel, d=self.d, threshold=r,
                metric=self.metric, block=min(self.block, 256),
                mode=self.mode, n_valid=q, m_valid=n_sel)
            # one sort/group pass instead of a pairs-array scan per query
            by_q = pairs[np.argsort(pairs[:, 0], kind="stable")]
            splits = np.searchsorted(by_q[:, 0], np.arange(q + 1))
            out = [np.sort(sel_ids[by_q[splits[qi]: splits[qi + 1], 1]])
                   for qi in range(q)]
        self._remember(key, out)
        return out

    def pairwise(self, queries, ids=None) -> tuple[np.ndarray, np.ndarray]:
        """Engine-metric distance matrix (Q, N') between queries and the
        given stored ids (default: all alive rows, id order) — the
        re-ranking path, served by the kernels.hamming query-vs-store tiles.
        Returns (ids (N',), dists (Q, N') f32).  Under "hamming" entries are
        exact integers; under "cham" they agree with topk/radius distances
        to cross-graph libm noise (~1e-7 relative), not bit-for-bit — the
        bit-identity contract belongs to topk/radius, which always go
        through core.allpairs."""
        from repro.kernels.hamming import ops as hamming_ops

        sk, q = self._sketch(queries)
        mat, m, all_ids = self.store.gather_alive()
        # keep everything pow2-bucketed (sk and mat already are; id subsets
        # go through padded_take) so the kernel's compile cache stays
        # O(log N) across mutations — same discipline as topk/radius
        if ids is None:
            sel_ids = all_ids
            sel, n_sel = mat, m
        else:
            sel_ids = np.atleast_1d(np.asarray(ids, np.int64))
            pos = np.searchsorted(all_ids, sel_ids)
            if m == 0 or (pos >= m).any() or (all_ids[np.minimum(pos, m - 1)]
                                              != sel_ids).any():
                raise KeyError("pairwise: id not in store")
            sel = packing.padded_take(mat, pos)
            n_sel = len(pos)
        dists = np.asarray(hamming_ops.dist_matrix(
            sk, sel, self.d, metric=self.metric))[:q, :n_sel]
        return sel_ids, dists

    def _banded_layout(self) -> BandedLayout:
        if self._banded is None or self._banded.version != self.store.version:
            self._banded = BandedLayout(self.store, self.metric,
                                        band_rows=self.band_rows)
        return self._banded

    # -- persistence --------------------------------------------------------

    def save(self, directory: str, step: int = 0, keep: int = 3) -> None:
        """Snapshot the full index (store buffers + hash params + metadata)
        via checkpoint.Checkpointer — same atomic-publish layout as model
        checkpoints, so index snapshots ride the existing retention/GC."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, keep=keep, async_save=False)
        meta = {
            "format": "repro.index.v1",
            "metric": self.metric,
            "n_dims": self.params.n_dims,
            "sketch_dim": self.params.sketch_dim,
            "psi_seed": self.params.psi_seed,
            "pi_seed": self.params.pi_seed,
            **self.store.state_meta(),
        }
        ckpt.save(step, self.store.state_tree(), extra_meta=meta, block=True)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **engine_kwargs) -> "QueryEngine":
        """Rebuild an engine from a snapshot; queries against the restored
        engine are bit-identical to the engine that saved it."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, async_save=False)
        if step is None:
            step = ckpt.latest_step()
            if step is None:
                raise FileNotFoundError(f"no index snapshots in {directory}")
        meta = ckpt.meta(step)
        if meta.get("format") != "repro.index.v1":
            raise ValueError(f"not an index snapshot: {directory}")
        if "metric" in engine_kwargs:
            raise ValueError("metric is fixed by the snapshot "
                             f"({meta['metric']!r}); it cannot be overridden "
                             "on restore")
        w = packing.packed_width(int(meta["sketch_dim"]))
        like = {
            "sk": np.zeros((0, w), np.int32),
            "ids": np.zeros(0, np.int64),
            "alive": np.zeros(0, bool),
            "weights": np.zeros(0, np.int64),
        }
        tree, _ = ckpt.restore(like, step=step)
        params = CabinParams(
            n_dims=int(meta["n_dims"]), sketch_dim=int(meta["sketch_dim"]),
            psi_seed=int(meta["psi_seed"]), pi_seed=int(meta["pi_seed"]))
        eng = cls(params, metric=meta["metric"], **engine_kwargs)
        eng.store = SketchStore.from_state(tree, meta)
        return eng

    # -- placement ----------------------------------------------------------

    def shard(self, mesh=None) -> None:
        """Opt-in: place the store's row buffers across the data-parallel
        axes of `mesh` (default: the ambient mesh).  Query math is
        unchanged — the tiled reductions run under GSPMD with the rows
        split across devices; integer pair statistics keep results
        bit-identical to the unsharded engine."""
        from repro.distributed import sharding as shd

        mesh = mesh if mesh is not None else shd.current_mesh()
        if mesh is None:
            raise ValueError("shard() needs a mesh (none active)")
        self.store.place(
            lambda shape: shd.batch_sharding_for(mesh, shape))
