"""QueryEngine: batched online similarity serving over a SketchStore.

The public boundary of the index subsystem.  Raw categorical rows — dense
(k, n) matrices or padded-COO (indices, values) pairs — go in; external ids
and distances come out.  Sketching happens inside (`core.cabin.sketch_dense`
/ `sketch_sparse`, which auto-dispatch to the fused Pallas kernels on TPU),
so callers never handle packed words, seeds, or layouts.

Serving disciplines (DESIGN.md section 8.3):

  * Micro-batch shape bucketing.  Every ingest and query batch is padded to
    a power-of-two row count (and nnz width for COO) before touching a jit
    boundary; together with the store's traced valid-row counts this keeps
    the number of compiled graphs O(log N + log Q) across arbitrary
    request mixes.  Padding rows are all-zero categorical vectors, whose
    sketches are all-zero and which every reduction masks out — they can
    never contaminate a result.
  * Partitioned serving.  Queries serve through a PartitionSet
    (repro.index.partition, DESIGN.md 8.5/13): per shard, a big
    weight-sorted base partition that SURVIVES mutations, a small
    brute-delta partition of fresh adds, and per-partition alive masks for
    removes.  `_layout()` syncs the set across the version RANGE since it
    was built — a mutation costs the next query O(delta), not the
    O(N log N) rebuild the old version-equality invalidation paid.  All of
    that discipline lives in partition.py; the engine only sketches,
    routes, and caches.
  * Bit-identity.  `topk` serves through each base partition's progressive
    band expansion (allpairs.topk_rows_banded — nearest bands first, stop
    at the exactness certificate, seeded with the cross-partition running
    k-th bound) merged with the deltas by (value, id), and `radius`
    through threshold_pairs per partition; both are bit-identical to
    running the batch engine on a freshly built matrix of the same vectors
    — across any interleaving of add/remove/compact, at every shard count,
    after checkpoint restore, and under both metrics.  Ties in topk
    resolve to the lower id, matching topk_rows' stable merge.
  * LRU result cache.  Results are memoised on (op, args, store version,
    query-sketch bytes); any mutation bumps the version, so stale hits are
    impossible by construction.

Persistence snapshots flow through checkpoint.Checkpointer (flat-tree save
of the store buffers + hash seeds + metadata), and `shard` opt-in re-homes
the serving layout as one PartitionSet per mesh device — rows routed by
``id % n_shards``, per-shard matrices placed per device, answers merged
cross-shard (see `shard`).
"""

from __future__ import annotations

from collections import OrderedDict, deque

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core import allpairs, packing, theory
from repro.core.cabin import (CabinParams, sketch_dense_jit,
                              sketch_sparse_jit)
from repro.core.packing import pad_rows_pow2, pow2_bucket
from repro.index import partition
from repro.index.bands import BandedLayout
from repro.index.mergeable import MergeIncompatible, check_spec_compatible
from repro.index.migrate import Migration, RawArchive
from repro.index.partition import PartitionSet
from repro.index.store import SketchSpec, SketchStore

_METRICS = ("cham", "hamming")


def compile_cache_entries() -> int:
    """Total jit-cache entries across the serving stack's compiled
    reductions — the O(log N) graph-count discipline as a LIVE number.
    The engine exports it as a gauge, and tests/test_obs.py pins that the
    REPRO_OBS=0 path adds zero entries to it."""
    from repro.core import cabin as _cabin
    from repro.index import store as _store_mod

    total = 0
    for fn in (allpairs._threshold_pairs_impl, allpairs._banded_pairs_impl,
               allpairs._argmin_rows_impl, allpairs._topk_rows_impl,
               allpairs._rowsum_impl, _cabin.sketch_dense_jit,
               _cabin.sketch_sparse_jit, _store_mod._append_rows):
        size = getattr(fn, "_cache_size", None)
        if callable(size):
            total += size()
    return total


class QueryEngine:
    """Online k-NN / radius serving over Cabin sketches.

    Parameters
    ----------
    params : CabinParams — hash seeds + dims; all ingested and queried rows
        must share them (they define the sketch space).
    metric : "cham" (estimated categorical HD) or "hamming" (exact sketch
        HD) — fixed per engine so cached results and layouts stay coherent.
    block / mode : tile size and backend forwarded to core.allpairs.
    band_rows : rows per weight band (radius-query pruning granularity).
    cache_entries : LRU result-cache capacity (0 disables caching).
    merge_ratio : tiered-layout merge policy (DESIGN.md 8.5).  Fresh adds
        accumulate in a small unsorted delta tier and fold into the sorted
        base tier once the live delta exceeds `merge_ratio * base_alive`
        rows; until then a mutation costs the next query O(delta) instead
        of a full O(N log N) layout rebuild.  0 merges on every mutation
        (the pre-tiered rebuild-per-version behaviour — the bench baseline);
        None never auto-merges (fold only on `compact()`).
    keep_raw : archive each ingested row's raw COO form (host-side,
        index/migrate.RawArchive) so the index can be re-sketched under a
        new spec.  Default True — without it `migrate()` is impossible and
        the index is frozen at its birth spec.
    auto_migrate : start a lazy spec migration automatically when the
        observed row-density percentile (`drift_pct` over the last
        `drift_window` ingested rows) crosses the density bound
        `theory.max_density_for_dim(d, drift_delta)` for the current sketch
        dim — the Theorem 1/2 accuracy cliff.  The new dim is
        `theory.sketch_dim(percentile, drift_delta)`, same hash seeds.
    """

    def __init__(self, params: CabinParams, *, metric: str = "cham",
                 block: int = 2048, mode: str | None = None,
                 band_rows: int = 1024, cache_entries: int = 256,
                 merge_ratio: float | None = 0.125, keep_raw: bool = True,
                 auto_migrate: bool = False, drift_delta: float = 0.1,
                 drift_window: int = 512, drift_pct: float = 95.0,
                 registry=None):
        if metric not in _METRICS:
            raise ValueError(f"metric must be one of {_METRICS}")
        if auto_migrate and not keep_raw:
            raise ValueError("auto_migrate needs keep_raw=True: a drift "
                             "migration re-sketches from the raw archive")
        self.params = params
        self.metric = metric
        self.block = block
        self.mode = mode
        self.band_rows = band_rows
        self.merge_ratio = merge_ratio
        self.spec = SketchSpec(0, params)
        self.raw: RawArchive | None = RawArchive() if keep_raw else None
        self.auto_migrate = auto_migrate
        self.drift_delta = float(drift_delta)
        self.drift_pct = float(drift_pct)
        self.drift_window = int(drift_window)
        self._nnz_window: deque[int] = deque(maxlen=self.drift_window)
        self._mig: Migration | None = None
        self._subs: list = []
        self.store = SketchStore(params.sketch_dim, spec=self.spec)
        self._attach_relay(self.store)
        self._n_shards = 1
        self._devices: list | None = None
        self._tiered: PartitionSet | None = None
        self._cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._cache_entries = cache_entries
        self.cache_hits = 0
        self.cache_misses = 0
        # per-engine flight recorder (repro.obs): NULL_REGISTRY under
        # REPRO_OBS=0, so every instrument below is a shared no-op.  Hot
        # paths cache their instruments HERE, once — queries never pay a
        # registry lookup.
        self.obs = obs.new_registry() if registry is None else registry
        self.store.set_registry(self.obs)
        self._h_lat = {
            op: self.obs.histogram("engine_query_latency_ms", op=op)
            for op in ("topk", "radius", "pairwise")}
        self._c_hits = self.obs.counter("engine_cache_hits_total")
        self._c_misses = self.obs.counter("engine_cache_misses_total")
        self._register_obs_gauges()

    def _register_obs_gauges(self) -> None:
        """Structural state as read-time callbacks: tier depths, cache
        sizes, compile-graph count, density drift, migration progress —
        always live, never a stale sample."""
        reg = self.obs
        reg.gauge_fn("engine_rows_alive", lambda: float(len(self)))
        reg.gauge_fn("engine_store_size",
                     lambda: float(self.store.size))
        reg.gauge_fn("engine_store_capacity",
                     lambda: float(self.store.capacity))
        reg.gauge_fn("engine_lru_entries",
                     lambda: float(len(self._cache)))
        reg.gauge_fn("engine_tier_base_rows",
                     lambda: float(self._tiered.base_alive
                                   if self._tiered else 0))
        reg.gauge_fn("engine_tier_delta_rows",
                     lambda: float(self._tiered.delta_n
                                   if self._tiered else 0))
        reg.gauge_fn("engine_tier_merges",
                     lambda: float(self._tiered.n_merges
                                   if self._tiered else 0))
        reg.gauge_fn("engine_shards",
                     lambda: float(self._n_shards))
        reg.gauge_fn("engine_compile_cache_entries",
                     lambda: float(compile_cache_entries()))
        reg.gauge_fn("engine_sketch_dim", lambda: float(self.d))
        reg.gauge_fn("engine_observed_density_pct", self._observed_density)
        reg.gauge_fn("engine_density_dim_needed", self._density_dim_needed)
        reg.gauge_fn("engine_migration_progress", self._migration_progress)
        reg.gauge_fn("engine_migration_cursor",
                     lambda: float(self._mig.cursor) if self._mig else -1.0)

    def _observed_density(self) -> float:
        """The `drift_pct` percentile of per-row nnz over the drift window
        — the live half of the density-drift gauge pair (the other half is
        `engine_density_dim_needed`; when it exceeds `engine_sketch_dim`
        the Theorem 1/2 accuracy bound no longer covers the data)."""
        if not self._nnz_window:
            return 0.0
        return float(np.percentile(
            np.fromiter(self._nnz_window, np.int64), self.drift_pct))

    def _density_dim_needed(self) -> float:
        if not self._nnz_window:
            return 0.0
        p = max(1, int(np.ceil(self._observed_density())))
        return float(theory.sketch_dim(p, self.drift_delta))

    def _migration_progress(self) -> float:
        """Fraction of old-spec rows re-sketched: 1.0 when no migration is
        in flight (the steady state IS fully migrated), monotone 0 -> 1
        across batches, and exact at every crash/resume point (the
        faultinject matrix in tests/test_obs.py pins this)."""
        if self._mig is None:
            return 1.0
        done = self._mig.rows_migrated
        total = done + len(self._mig.src)
        return done / total if total else 1.0

    # -- mutation observers (engine level) ----------------------------------

    def subscribe(self, callback) -> None:
        """Register `callback(event, ids, slots, store)` — the engine-level
        twin of `SketchStore.subscribe` that per-id sidecars (ClusterIndex)
        should use instead of subscribing to `engine.store` directly: a
        spec migration swaps stores under the engine, and only the engine
        knows which store an event belongs to.  Store events ("add",
        "remove", "compact") relay with the ORIGINATING store; the engine
        adds two of its own: "migrate_start" (a migration just began;
        `store` is the new-spec destination — re-sketch any private packed
        state from raw now) and "migrate" (the migration published;
        `store` is the engine's new serving store)."""
        self._subs.append(callback)

    def unsubscribe(self, callback) -> None:
        self._subs.remove(callback)

    def _attach_relay(self, store: SketchStore) -> None:
        def relay(event, ids, slots, _store=store):
            for cb in list(self._subs):
                cb(event, ids, slots, _store)

        store.subscribe(relay)

    def _emit(self, event: str, store: SketchStore) -> None:
        z = np.zeros(0, np.int64)
        for cb in list(self._subs):
            cb(event, z, z, store)

    # -- basics -------------------------------------------------------------

    def __len__(self) -> int:
        n = len(self.store)
        if self._mig is not None:
            n += len(self._mig.dst) + len(self._mig.fresh)
        return n

    @property
    def d(self) -> int:
        return self.params.sketch_dim

    def ids(self) -> np.ndarray:
        if self._mig is None:
            return self.store.ids()
        return np.sort(np.concatenate([
            self.store.ids(), self._mig.dst.ids(), self._mig.fresh.ids()]))

    def stats(self) -> dict:
        t = self._tiered
        out = {
            "n_alive": len(self),
            "size": self.store.size,
            "capacity": self.store.capacity,
            "version": self.store.version,
            "spec_version": self.spec.version,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "n_bands": t.n_bands if t else None,
            "base_rows": t.base_rows if t else None,
            "base_alive": t.base_alive if t else None,
            "delta_rows": t.delta_n if t else None,
            "tier_merges": t.n_merges if t else None,
            "n_shards": self._n_shards,
        }
        if self._mig is not None:
            m = self._mig
            out["migration"] = {
                "phase": m.phase,
                "to_version": m.new_spec.version,
                "to_dim": m.new_spec.d,
                "rows_migrated": m.rows_migrated,
                "rows_remaining": len(m.src),
                "fresh_rows": len(m.fresh),
                "progress": self._migration_progress(),
            }
        lat = {}
        for op, h in self._h_lat.items():
            if h.count:
                lat[op] = {"count": h.count, "p50": h.quantile(50),
                           "p95": h.quantile(95), "p99": h.quantile(99)}
        if lat:
            out["latency_ms"] = lat
        return out

    def render_prom(self) -> str:
        """This engine's registry in Prometheus text exposition format —
        point a scraper (or `curl`) at whatever endpoint serves it."""
        return self.obs.render_prom()

    def obs_snapshot(self) -> dict:
        """Plain-dict snapshot of this engine's registry: every counter,
        gauge (evaluated live), and histogram with p50/p95/p99."""
        return self.obs.snapshot()

    # -- sketching (shape-bucketed) ----------------------------------------

    def _sketch(self, queries, params: CabinParams | None = None
                ) -> tuple[jnp.ndarray, int]:
        """Raw categorical input -> (packed sketches (pow2-padded, w), k).

        `queries` is a dense (k, n_dims) int array, or an (indices, values)
        padded-COO pair.  Both layouts are padded to power-of-two buckets
        (rows, and nnz width for COO) so the sketch jits are reused across
        request sizes; zero padding is inert under psi/pi by construction.
        `params` overrides the engine's CabinParams — the cross-version
        serving and migration paths sketch the same rows under another
        spec's params through exactly this path, which is what makes a
        completed migration bit-identical to a fresh build.
        """
        if params is None:
            params = self.params
        w = params.packed_width
        if isinstance(queries, (tuple, list)):
            idx_host, val_host = queries
            # validate on host BEFORE the device transfer: no sync on the
            # serving path when (as usual) the input is already numpy
            idx_host = np.asarray(idx_host)
            if idx_host.shape != np.shape(val_host) or idx_host.ndim != 2:
                raise ValueError("COO input needs matching (k, m) "
                                 "indices/values")
            if idx_host.size and (idx_host.max() >= params.n_dims
                                  or idx_host.min() < 0):
                raise ValueError(
                    f"COO indices out of range [0, {params.n_dims})")
            indices = jnp.asarray(idx_host, jnp.int32)
            values = jnp.asarray(val_host, jnp.int32)
            k = indices.shape[0]
            if k == 0:
                return jnp.zeros((0, w), jnp.int32), 0
            mpad = pow2_bucket(indices.shape[1])
            wpad = ((0, pow2_bucket(k) - k), (0, mpad - indices.shape[1]))
            sk = sketch_sparse_jit(params, jnp.pad(indices, wpad),
                                   jnp.pad(values, wpad))
            return sk, k
        x = jnp.asarray(queries, jnp.int32)
        if x.ndim != 2 or x.shape[1] != params.n_dims:
            raise ValueError(
                f"expected dense (k, {params.n_dims}) rows, "
                f"got {x.shape}")
        k = x.shape[0]
        if k == 0:
            return jnp.zeros((0, w), jnp.int32), 0
        return sketch_dense_jit(params, pad_rows_pow2(x)), k

    # -- ingestion ----------------------------------------------------------

    def _ingest_target(self) -> tuple[SketchStore, CabinParams]:
        """Where adds land and which spec sketches them: the serving store
        normally, the new-spec fresh store while a migration is in flight —
        acked mutations during migration must never need re-migration."""
        if self._mig is not None:
            return self._mig.fresh, self._mig.new_spec.params
        return self.store, self.params

    def add_dense(self, x) -> np.ndarray:
        """Ingest dense categorical rows (k, n_dims); returns ids (k,)."""
        self._drive()
        store, params = self._ingest_target()
        sk, k = self._sketch(x, params=params)
        ids = store.add(sk, n_valid=k)
        if k:
            x_host = np.asarray(x)
            if self.raw is not None:
                self.raw.put_dense(ids, x_host)
            self._track_drift(np.count_nonzero(x_host, axis=1))
        return ids

    def add_sparse(self, indices, values) -> np.ndarray:
        """Ingest padded-COO categorical rows; returns ids (k,)."""
        self._drive()
        store, params = self._ingest_target()
        sk, k = self._sketch((indices, values), params=params)
        ids = store.add(sk, n_valid=k)
        if k:
            if self.raw is not None:
                self.raw.put(ids, indices, values)
            self._track_drift(
                np.count_nonzero(np.asarray(values), axis=1))
        return ids

    def add_packed(self, packed, raw=None,
                   spec: SketchSpec | None = None) -> np.ndarray:
        """Ingest pre-sketched packed rows (k, w).  The rows MUST come from
        this engine's CURRENT CabinParams — used by streaming ingest after
        an in-window dedup pass already paid for the sketches.  `spec`
        (optional) names the SketchSpec the rows were sketched under; a
        mismatch raises MergeIncompatible naming both specs, which is the
        only way to catch wrong hash seeds — they are undetectable from
        the bits alone.  `raw` is the rows' (indices, values) COO pair;
        pass it to keep the rows re-sketchable (without it they cannot
        survive a `migrate()`).  While a migration is in flight the packed
        rows are spec-ambiguous: with `raw` the engine re-sketches them
        under the live spec, without it the call raises."""
        self._drive()
        if self._mig is not None:
            if raw is None:
                raise RuntimeError(
                    "add_packed mid-migration needs raw=(indices, values): "
                    "the supplied sketches are under the OLD spec, but new "
                    "rows must land in the new-spec tier")
            return self.add_sparse(*raw)
        packed = jnp.asarray(packed)
        ids = self.store.add_packed(pad_rows_pow2(packed), spec,
                                    n_valid=packed.shape[0])
        if raw is not None and self.raw is not None and len(ids):
            self.raw.put(ids, *raw)
        return ids

    def remove(self, ids) -> int:
        self._drive()
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if self._mig is None:
            n = self.store.remove(ids)
        else:
            if len(np.unique(ids)) != len(ids):
                raise ValueError("duplicate ids in remove batch")
            # validate membership BEFORE mutating any store, so a bad id
            # cannot leave a partial cross-store remove behind
            groups: dict[int, tuple[SketchStore, list[int]]] = {}
            for id_ in ids.tolist():
                store = self._mig.store_of(id_)  # KeyError on unknown
                groups.setdefault(id(store), (store, []))[1].append(id_)
            for store, grp in groups.values():
                store.remove(np.asarray(grp, np.int64))
            n = len(ids)
        if self.raw is not None:
            self.raw.drop(ids)
        return n

    def compact(self) -> None:
        self._drive()
        self.store.compact()
        if self._mig is not None:
            self._mig.dst.compact()
            self._mig.fresh.compact()

    # -- merge (the Mergeable contract, repro.index.mergeable) --------------

    def merge(self, other: "QueryEngine") -> "QueryEngine":
        """Absorb `other`'s membership into this engine and return self —
        the engine face of the Mergeable contract (DESIGN.md section 14)
        and the combine step of `index.merge_tree.bulk_ingest`.

        Requirements, all validated before anything mutates: same metric,
        same sketch spec (cross-spec merge fails loudly through the same
        compatibility check the spec-migration machinery uses — migrate
        one engine to the other's spec first), matching keep_raw, disjoint
        external ids, and NO migration in flight on either side (a
        mid-migration membership spans two sketch spaces).

        What merges: the store (device buffers, through `SketchStore.merge`
        — the ``merge.combine`` crash point fires there, before any
        mutation), the raw archive, the density-drift window, the serving
        layout (merged rows absorbed as shard-routed delta when the id
        ranges don't interleave), and the obs registries (counters sum,
        histograms union — `MetricsRegistry.merge`).  The LRU clears: its
        keys version a membership that just changed.  Store subscribers
        see ONE "merge" event carrying the absorbed alive rows.  `other`
        is left readable but must be discarded — its ids are absorbed, so
        a re-merge raises the disjointness check."""
        if other is self:
            raise MergeIncompatible(
                "QueryEngine.merge: cannot merge an engine with itself")
        if self._mig is not None or other._mig is not None:
            raise RuntimeError(
                "QueryEngine.merge: a spec migration is in flight; drive "
                "it to completion (migrate_all()) on both engines before "
                "merging — a mid-migration membership spans two sketch "
                "spaces")
        if other.metric != self.metric:
            raise MergeIncompatible(
                f"QueryEngine.merge: metric mismatch ({self.metric!r} vs "
                f"{other.metric!r}) — cached results and layouts would "
                "not be comparable")
        check_spec_compatible(other.spec, self.spec,
                              what="QueryEngine.merge")
        if (self.raw is None) != (other.raw is None):
            raise MergeIncompatible(
                "QueryEngine.merge: keep_raw mismatch — merging a raw-less "
                "engine would leave part of the membership un-migratable")
        with obs.span("engine.merge", rows=len(other)):
            self.store.merge(other.store)
            if self.raw is not None:
                self.raw.merge(other.raw)
            self._nnz_window.extend(other._nnz_window)
            self.cache_hits += other.cache_hits
            self.cache_misses += other.cache_misses
            # counters sum, histograms union; callback gauges freeze to
            # their merge-time values — re-register ours so the live
            # structural windows stay live
            self.obs.merge(other.obs)
            self._register_obs_gauges()
            if self._tiered is not None:
                self._tiered.merge(other._tiered)
            self._cache.clear()
        return self

    # -- spec migration ------------------------------------------------------

    @property
    def migrating(self) -> bool:
        return self._mig is not None

    @property
    def migration(self) -> Migration | None:
        return self._mig

    def migrate(self, new_params: CabinParams | None = None, *,
                d: int | None = None, batch_rows: int = 1024,
                drive: str = "lazy", journal_dir: str | None = None,
                journal_every: int = 1, journal_keep: int = 3) -> Migration:
        """Begin an incremental re-sketch of the index to a new spec.

        `new_params` is the target CabinParams (same n_dims; typically a new
        sketch_dim after density drift), or pass `d` to keep the current
        hash seeds and change only the dim.  Old-spec rows are re-sketched
        from the raw archive in `batch_rows` batches; serving stays live
        throughout, answering across the old- and new-spec tiers.  `drive`:

          * "lazy"  — each engine call (add/remove/query/compact) advances
            the migration one batch before doing its own work; no separate
            driver needed, progress rides the request stream.
          * "manual" — only `migration_step()` / `migrate_all()` advance it.
          * "eager" — run to completion before returning.

        `journal_dir` checkpoints the full engine (both tiers + cursor)
        through checkpoint.Checkpointer every `journal_every` batches —
        `QueryEngine.restore(journal_dir)` after a crash resumes the
        migration without losing any acked mutation.  A completed migration
        is bit-identical to an engine freshly built at the new spec."""
        if self._mig is not None:
            raise RuntimeError("a migration is already in flight")
        if new_params is None:
            if d is None:
                raise ValueError("migrate() needs new_params or d")
            new_params = CabinParams(
                n_dims=self.params.n_dims, sketch_dim=int(d),
                psi_seed=self.params.psi_seed, pi_seed=self.params.pi_seed)
        new_spec = self.spec.successor(new_params)
        mig = Migration(self, new_spec, batch_rows=batch_rows, drive=drive,
                        journal_dir=journal_dir, journal_every=journal_every,
                        journal_keep=journal_keep)
        self._mig = mig
        # fresh holds REAL ingest (acked adds mid-migration) — it shares
        # the engine's counters; dst holds re-sketched copies of existing
        # rows, counted separately by the migration's own instruments so
        # store_rows_added_total keeps meaning "rows ingested".
        mig.fresh.set_registry(self.obs)
        self._attach_relay(mig.dst)
        self._attach_relay(mig.fresh)
        self._emit("migrate_start", mig.dst)
        if drive == "eager":
            mig.run()
        return mig

    def migration_step(self, rows: int | None = None) -> bool:
        """Advance an in-flight migration by one batch (default
        `batch_rows`); returns True while more work remains."""
        if self._mig is None:
            return False
        self._mig.step(rows)
        return self._mig is not None

    def migrate_all(self) -> None:
        """Drive an in-flight migration to completion."""
        while self.migration_step():
            pass

    def _drive(self) -> None:
        """Lazy-mode pacing: one migration batch per engine call."""
        if self._mig is not None and self._mig.drive == "lazy":
            self._mig.step()

    def _publish_migration(self, mig: Migration) -> None:
        """Called by Migration._finish once every row is under the new
        spec: atomically (w.r.t. the Python API) swap the serving store."""
        self.store = mig.dst
        self.store.set_registry(self.obs)
        self.params = mig.new_spec.params
        self.spec = mig.new_spec
        self._tiered = None
        self._cache.clear()
        self._mig = None
        self._emit("migrate", self.store)

    def _track_drift(self, nnz_counts: np.ndarray) -> None:
        """Feed per-row density observations into the drift window; when
        the `drift_pct` percentile needs a bigger sketch dim than we have
        (theory.sketch_dim at `drift_delta`), auto-start a lazy migration
        to that dim.  No-op unless auto_migrate."""
        self._nnz_window.extend(int(c) for c in nnz_counts)
        if not self.auto_migrate or self._mig is not None:
            return
        if len(self._nnz_window) < min(64, self.drift_window):
            return  # too few observations to call a drift
        p = max(1, int(np.ceil(np.percentile(
            np.fromiter(self._nnz_window, np.int64), self.drift_pct))))
        need = theory.sketch_dim(p, self.drift_delta)
        if need > self.d:
            self.migrate(d=need, drive="lazy")

    # -- result cache -------------------------------------------------------

    def _cached(self, key):
        if key is not None and key in self._cache:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            self._c_hits.inc()
            return self._cache[key]
        return None

    def _remember(self, key, value) -> None:
        """Store a PRIVATE copy of `value` (key=None: caching disabled) —
        both hit and miss paths hand callers arrays they may freely
        mutate without corrupting later hits."""
        self.cache_misses += 1
        self._c_misses.inc()
        if key is None:
            return
        if isinstance(value, tuple):
            self._cache[key] = tuple(a.copy() for a in value)
        else:
            self._cache[key] = [a.copy() for a in value]
        if len(self._cache) > self._cache_entries:
            self._cache.popitem(last=False)

    # -- queries ------------------------------------------------------------

    def topk(self, queries, k: int) -> tuple[np.ndarray, np.ndarray]:
        """k nearest stored rows per query: (ids (Q, k'), dists (Q, k')),
        ascending by distance, k' = min(k, len(store)).  Accepts dense rows
        or an (indices, values) COO pair; `topk_packed` skips sketching.
        Raises ValueError for k < 0 (k = 0 is a valid empty query)."""
        if k < 0:
            raise ValueError(f"topk: k must be >= 0, got {k}")
        self._drive()  # migration pacing stays OUTSIDE the query timer
        with self._h_lat["topk"].time(), obs.span("engine.topk", k=k):
            if self._mig is not None:
                return self._topk_migrating(queries, k)
            sk, q = self._sketch(queries)
            return self._topk_packed_impl(sk, k, q)

    def topk_budgeted(self, queries, k: int, deadline=None
                      ) -> tuple[np.ndarray, np.ndarray, dict]:
        """`topk` under a latency budget: (ids, dists, info), where info
        carries {"partial", "cert_gap"}.  `deadline` is any object with an
        `expired` property (repro.serve.Deadline); when it fires before the
        band walk's exactness certificate closes, the walk stops, the best
        candidates seen so far come back with info["partial"]=True, and
        info["cert_gap"] is the residual certificate gap (DESIGN.md 8.4) —
        how far the k-th bound would have to move for the answer to be
        provably exact.  With deadline=None (or when the walk finishes in
        budget) the result is bit-identical to `topk` and partial is False.

        Unfilled slots in a partial answer carry id -1 and distance inf
        (fewer than k candidates were reachable in budget).  Mid-migration,
        queries fall back to the exact dual-version path — a migration
        already bounds its own per-batch work, so budgets do not compound.
        """
        if k < 0:
            raise ValueError(f"topk: k must be >= 0, got {k}")
        self._drive()  # migration pacing stays OUTSIDE the query timer
        info: dict = {"partial": False, "cert_gap": 0.0}
        with self._h_lat["topk"].time(), obs.span("engine.topk", k=k):
            if self._mig is not None:
                ids, dists = self._topk_migrating(queries, k)
                return ids, dists, info
            sk, q = self._sketch(queries)
            ids, dists = self._topk_packed_impl(sk, k, q, deadline=deadline,
                                                info_out=info)
            if info["partial"]:
                ids = np.where(ids == allpairs.KBEST_KEY_PAD, -1, ids)
            return ids, dists, info

    def topk_packed(self, sk, k: int, n_valid: int | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Served through the partition layer (PartitionSet.topk): each
        shard's base partition runs a progressive band expansion that
        visits bands nearest-first and stops at the exactness certificate
        (seeded with the cross-partition running k-th bound), the delta
        partitions of fresh adds are scanned brute-force, and everything
        merges by (value, id) — so a query touches O(answer neighbourhood
        + delta) rows, not O(N), while returning bit-identical results to
        topk_rows over the alive membership at every shard count.  The LRU
        is consulted on the query-sketch bytes BEFORE the layout or any
        device gather is touched: a cache hit costs O(1) host work
        regardless of store size."""
        if k < 0:
            raise ValueError(f"topk: k must be >= 0, got {k}")
        if self._mig is not None:
            raise RuntimeError(
                "topk_packed is unavailable mid-migration (packed queries "
                "are spec-ambiguous); use topk() with raw rows")
        with self._h_lat["topk"].time(), obs.span("engine.topk", k=k):
            return self._topk_packed_impl(sk, k, n_valid)

    def _topk_packed_impl(self, sk, k: int, n_valid: int | None,
                          deadline=None, info_out: dict | None = None
                          ) -> tuple[np.ndarray, np.ndarray]:
        if info_out is not None:
            info_out.update(partial=False, cert_gap=0.0)
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        kk = min(k, len(self.store))
        if q == 0 or kk == 0:
            return (np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32))
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None  # caching disabled: skip the device sync for the key
        if self._cache_entries:
            key = ("topk", kk, self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                # cached answers are always exact: partial results never
                # enter the LRU (below), so a budgeted call served from
                # cache is a free upgrade to the full answer
                return hit[0].copy(), hit[1].copy()
        layout = self._layout()
        q_weights = packing.np_popcount_rows(q_host)
        out = layout.topk(pad_rows_pow2(sk), q_weights, kk, q_valid=q,
                          block=self.block, mode=self.mode,
                          deadline=deadline, info_out=info_out)
        if info_out is not None and info_out.get("partial"):
            key = None  # a partial answer must not shadow the exact one
        self._remember(key, out)
        return out

    def radius(self, queries, r: float) -> list[np.ndarray]:
        """All stored rows within distance < r of each query: a list of Q
        id arrays (ascending).  Weight bands whose score interval is out of
        reach are pruned on host before any tile is computed; the delta
        tier of fresh adds is scanned brute-force.  Accepts dense rows or
        an (indices, values) COO pair; `radius_packed` skips sketching.

        Distances are nonnegative and the test is strict (`dist < r`), so
        r <= 0 returns an empty id array for every query — an explicit
        contract, not an error (negative radii short-circuit before any
        layout or device work)."""
        self._drive()  # migration pacing stays OUTSIDE the query timer
        with self._h_lat["radius"].time(), obs.span("engine.radius", r=r):
            if self._mig is not None:
                return self._radius_migrating(queries, r)
            sk, q = self._sketch(queries)
            return self._radius_packed_impl(sk, r, q)

    def radius_packed(self, sk, r: float, n_valid: int | None = None
                      ) -> list[np.ndarray]:
        """Pre-sketched twin of `radius` (same r <= 0 -> empty contract)."""
        if self._mig is not None:
            raise RuntimeError(
                "radius_packed is unavailable mid-migration (packed queries "
                "are spec-ambiguous); use radius() with raw rows")
        with self._h_lat["radius"].time(), obs.span("engine.radius", r=r):
            return self._radius_packed_impl(sk, r, n_valid)

    def _radius_packed_impl(self, sk, r: float, n_valid: int | None
                            ) -> list[np.ndarray]:
        sk = jnp.asarray(sk)
        q = sk.shape[0] if n_valid is None else n_valid
        if not 0 <= q <= sk.shape[0]:
            raise ValueError(
                f"n_valid={q} outside the {sk.shape[0]} supplied rows")
        if q == 0:
            return []
        if r <= 0:  # dist >= 0 and the test is strict: provably no hits
            return [np.zeros(0, np.int64) for _ in range(q)]
        q_host = np.asarray(sk[:q])  # needed for band planning regardless
        key = None
        if self._cache_entries:
            key = ("radius", float(r), self.store.version, q_host.tobytes())
            hit = self._cached(key)
            if hit is not None:
                return [a.copy() for a in hit]
        hits: list[list[np.ndarray]] = [[] for _ in range(q)]
        if len(self.store):
            layout = self._layout()
            q_weights = packing.np_popcount_rows(q_host)
            # partition memberships partition the alive set: per-partition
            # hits union to exactly the batch engine's answer on the full
            # membership (partition.radius_hits — the one collection pass)
            partition.radius_hits(
                layout, pad_rows_pow2(sk), q_weights, q, r,
                metric=self.metric, block=min(self.block, 256),
                mode=self.mode, hits=hits)
        out = [np.sort(np.concatenate(h)) if h else np.zeros(0, np.int64)
               for h in hits]
        self._remember(key, out)
        return out

    # -- cross-version serving (mid-migration) -------------------------------

    def _sketch_per_spec(self, queries, specs) -> dict:
        """Sketch the same raw queries once under every distinct spec in
        `specs` — the cross-version serving discipline: each tier is
        queried in its OWN sketch space, results merge in id/distance
        space (which both specs estimate for "cham")."""
        out: dict[int, tuple[jnp.ndarray, int]] = {}
        for spec in specs:
            if spec.version not in out:
                out[spec.version] = self._sketch(queries, params=spec.params)
        return out

    def _topk_migrating(self, queries, k: int
                        ) -> tuple[np.ndarray, np.ndarray]:
        """topk across the migration's live tiers (old-spec remainder,
        new-spec migrated rows, new-spec fresh mutations) — each tier a
        whole PartitionSet, sharded or not, under its own spec.  Tier
        memberships partition the alive ids and the cross-set merge is
        partition.topk_across_tiers (the same (value, id)-lex rule, with
        the running k-th bound threaded across sets) — so the result
        equals merging per-store reference answers, each under its own
        spec.  The LRU is bypassed: mid-migration versions span three
        stores and the window is transient."""
        tiers = self._mig.serving_tiers()
        kk = min(k, len(self))
        if not tiers or kk == 0:
            _, q = self._sketch(queries)
            return (np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32))
        sketched = self._sketch_per_spec(queries, [s for _, s in tiers])
        q = next(iter(sketched.values()))[1]
        if q == 0:
            return (np.zeros((0, 0), np.int64), np.zeros((0, 0), np.float32))
        staged = []
        for layout, spec in tiers:
            sk, _ = sketched[spec.version]
            q_host = np.asarray(sk[:q])
            staged.append((layout, pad_rows_pow2(sk),
                           packing.np_popcount_rows(q_host)))
        return partition.topk_across_tiers(kk, staged, q_valid=q,
                                           block=self.block, mode=self.mode)

    def _radius_migrating(self, queries, r: float) -> list[np.ndarray]:
        """radius across the migration's live tiers — per-tier hits union
        to the answer over the full alive membership (strict `dist < r`,
        each tier scored in its own sketch space)."""
        tiers = self._mig.serving_tiers()
        if not tiers:
            _, q = self._sketch(queries)
            return [np.zeros(0, np.int64) for _ in range(q)]
        sketched = self._sketch_per_spec(queries, [s for _, s in tiers])
        q = next(iter(sketched.values()))[1]
        if q == 0:
            return []
        if r <= 0:
            return [np.zeros(0, np.int64) for _ in range(q)]
        hits: list[list[np.ndarray]] = [[] for _ in range(q)]
        for layout, spec in tiers:
            sk, _ = sketched[spec.version]
            q_host = np.asarray(sk[:q])
            partition.radius_hits(
                layout, pad_rows_pow2(sk), packing.np_popcount_rows(q_host),
                q, r, metric=self.metric, block=min(self.block, 256),
                mode=self.mode, hits=hits)
        return [np.sort(np.concatenate(h)) if h else np.zeros(0, np.int64)
                for h in hits]

    def pairwise(self, queries, ids=None) -> tuple[np.ndarray, np.ndarray]:
        """Engine-metric distance matrix (Q, N') between queries and the
        given stored ids (default: all alive rows, id order) — the
        re-ranking path, served by the kernels.hamming query-vs-store tiles.
        Returns (ids (N',), dists (Q, N') f32).  Under "hamming" entries are
        exact integers; under "cham" they agree with topk/radius distances
        to cross-graph libm noise (~1e-7 relative), not bit-for-bit — the
        bit-identity contract belongs to topk/radius, which always go
        through core.allpairs."""
        from repro.kernels.hamming import ops as hamming_ops

        if self._mig is not None:
            raise RuntimeError(
                "pairwise is unavailable mid-migration: rows live under two "
                "specs and a single distance matrix would mix sketch spaces; "
                "drive the migration to completion first (migrate_all())")
        with self._h_lat["pairwise"].time(), obs.span("engine.pairwise"):
            return self._pairwise_impl(hamming_ops, queries, ids)

    def _pairwise_impl(self, hamming_ops, queries, ids
                       ) -> tuple[np.ndarray, np.ndarray]:
        sk, q = self._sketch(queries)
        # empty-traffic fast paths: an empty store or a 0-row query batch
        # answers from host metadata alone — well-typed empty matrices,
        # no device gather and no kernel call on degenerate pow2-padded
        # shapes.  Explicit ids still get full validation (duplicates,
        # membership) so the contract does not weaken at q == 0.
        if q == 0 or (ids is None and len(self.store) == 0):
            all_ids = self.store.ids()
            if ids is None:
                sel_ids = all_ids
            else:
                sel_ids = np.atleast_1d(np.asarray(ids, np.int64))
                if len(np.unique(sel_ids)) != len(sel_ids):
                    raise ValueError("pairwise: duplicate ids in batch")
                m = len(all_ids)
                pos = np.searchsorted(all_ids, sel_ids)
                if m == 0 or (pos >= m).any() or (
                        all_ids[np.minimum(pos, m - 1)] != sel_ids).any():
                    raise KeyError("pairwise: id not in store")
            return sel_ids, np.zeros((q, len(sel_ids)), np.float32)
        view = self.store.gather_alive()
        # cheap stale-view guard BEFORE anything dereferences the matrix
        # (the id-subset padded_take below, then the kernel call): a view
        # predating a mutation (re-entrant callback, another thread) fails
        # here with a clear message instead of jax's opaque "Array has
        # been deleted" after a donated append
        self.store.check_fresh(view)
        mat, m, all_ids = view
        # keep everything pow2-bucketed (sk and mat already are; id subsets
        # go through padded_take) so the kernel's compile cache stays
        # O(log N) across mutations — same discipline as topk/radius
        if ids is None:
            sel_ids = all_ids
            sel, n_sel = mat, m
        else:
            sel_ids = np.atleast_1d(np.asarray(ids, np.int64))
            if len(np.unique(sel_ids)) != len(sel_ids):
                # consistent with SketchStore.remove: duplicate ids are a
                # caller bug, not a request for duplicated columns
                raise ValueError("pairwise: duplicate ids in batch")
            pos = np.searchsorted(all_ids, sel_ids)
            if m == 0 or (pos >= m).any() or (all_ids[np.minimum(pos, m - 1)]
                                              != sel_ids).any():
                raise KeyError("pairwise: id not in store")
            sel = packing.padded_take(mat, pos)
            n_sel = len(pos)
        dists = np.asarray(hamming_ops.dist_matrix(
            sk, sel, self.d, metric=self.metric))[:q, :n_sel]
        return sel_ids, dists

    def cluster(self, k: int, **kwargs) -> "object":
        """Attach a `repro.cluster.ClusterIndex` maintaining k-medoid
        centres and per-row labels over this engine's store: fresh adds are
        assigned to their nearest centre as they arrive (through this
        engine's own serving path), removes update the per-cluster
        bookkeeping, and `refit()` re-clusters the live membership with the
        device k-mode engine.  Keyword args (seed/n_iter/block/refit_every)
        forward to ClusterIndex; see repro/cluster/online.py.  The store
        keeps a strong reference to the attached index — `detach()` an old
        one before attaching a replacement."""
        from repro.cluster import ClusterIndex  # local: repro.cluster
        # imports this module, so the hook resolves the cycle lazily

        return ClusterIndex(self, k, **kwargs)

    def _new_layout(self, store: SketchStore, role: str = "serve"
                    ) -> PartitionSet:
        """Build a PartitionSet over `store` under this engine's serving
        config (band rows, merge policy, registry) AND its shard topology —
        the one layout factory every serving structure goes through, so a
        sharded engine's migration tiers are sharded too."""
        return PartitionSet(store, self.metric, band_rows=self.band_rows,
                            merge_ratio=self.merge_ratio, registry=self.obs,
                            n_shards=self._n_shards, devices=self._devices,
                            role=role)

    def sync_layout(self) -> PartitionSet:
        """Sync the serving layout (a PartitionSet — one (base, delta)
        group per shard) to the store's current version and return it —
        the maintenance the next query would otherwise pay inline.
        Validity is a version RANGE, not version equality: within a slot
        epoch the sync absorbs adds into the per-shard delta partitions
        and removes into the alive masks in O(delta); only compaction
        (epoch bump) or the per-shard merge policy pays a rebuild.
        Calling this after an ingest burst keeps tail latency flat;
        queries call it implicitly."""
        if self._tiered is None:
            self._tiered = self._new_layout(self.store)
        return self._tiered.sync(self.store)

    _layout = sync_layout  # internal alias used by the query paths

    def _banded_layout(self) -> BandedLayout:
        """The synced layout's BASE partition (single-shard introspection +
        tests; serving goes through `_layout`, which also covers the delta
        partitions and all shards)."""
        return self._layout().base

    # -- persistence --------------------------------------------------------

    def _set_store(self, store: SketchStore) -> None:
        """Install a restored serving store: reset the layout and wire the
        engine-level event relay (restore builds stores outside __init__)."""
        self.store = store
        store.set_registry(self.obs)
        self._tiered = None
        self._attach_relay(store)

    def save(self, directory: str, step: int = 0, keep: int = 3) -> None:
        """Snapshot the full index via checkpoint.Checkpointer — same
        atomic-publish layout as model checkpoints, so index snapshots ride
        the existing retention/GC, integrity records, and fault-injection
        crash points.  One step holds the serving store, the raw archive,
        and — mid-migration — BOTH new-spec tiers plus the cursor/spec-pair
        journal record: the unit of atomicity is the whole engine, which is
        what makes crash recovery unable to lose an acked mutation."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, keep=keep, async_save=False)
        # one snapshot subtree per backing store (partition.snapshot_subtrees
        # — layouts are derived state; a restored engine, sharded or not,
        # rebuilds them from the stores alone)
        tree = partition.snapshot_subtrees(self.store, raw=self.raw,
                                           migration=self._mig)
        meta = {
            "format": "repro.index.v2",
            "metric": self.metric,
            "spec": self.spec.meta(),
            "store_meta": self.store.state_meta(),
            "keep_raw": self.raw is not None,
        }
        if self._mig is not None:
            meta["migration"] = self._mig.meta()
        ckpt.save(step, tree, extra_meta=meta, block=True)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **engine_kwargs) -> "QueryEngine":
        """Rebuild an engine from a snapshot; queries against the restored
        engine are bit-identical to the engine that saved it.  step=None
        restores the NEWEST INTACT step — corrupt or partially-written
        snapshots are verified against their integrity records and skipped
        (checkpoint.CheckpointCorruptError if none survive).  A snapshot
        taken mid-migration resumes the migration exactly where the journal
        left it: already-migrated rows stay migrated, acked mutations stay
        acked, and serving continues cross-version."""
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory, async_save=False)
        if ckpt.latest_step() is None:
            raise FileNotFoundError(f"no index snapshots in {directory}")
        flat, step = ckpt.restore(step=step)
        meta = ckpt.meta(step)
        fmt = meta.get("format")
        if fmt == "repro.index.v1":
            return cls._restore_v1(flat, meta, engine_kwargs)
        if fmt != "repro.index.v2":
            raise ValueError(f"not an index snapshot: {directory}")
        if "metric" in engine_kwargs:
            raise ValueError("metric is fixed by the snapshot "
                             f"({meta['metric']!r}); it cannot be overridden "
                             "on restore")
        if "keep_raw" in engine_kwargs:
            raise ValueError("keep_raw is fixed by the snapshot "
                             f"({meta['keep_raw']}); it cannot be overridden "
                             "on restore")

        def sub(prefix: str) -> dict:
            return {k[len(prefix):]: v for k, v in flat.items()
                    if k.startswith(prefix)}

        spec = SketchSpec.from_meta(meta["spec"])
        eng = cls(spec.params, metric=meta["metric"],
                  keep_raw=meta["keep_raw"], **engine_kwargs)
        eng.spec = spec
        eng._set_store(SketchStore.from_state(
            sub("store/"), meta["store_meta"], spec=spec))
        if meta["keep_raw"]:
            eng.raw = RawArchive.from_state(sub("raw/"))
        if "migration" in meta:
            mmeta = meta["migration"]
            new_spec = SketchSpec.from_meta(mmeta["new_spec"])
            dst = SketchStore.from_state(
                sub("mig_dst/"), mmeta["dst_meta"], spec=new_spec)
            fresh = SketchStore.from_state(
                sub("mig_fresh/"), mmeta["fresh_meta"], spec=new_spec)
            eng._mig = Migration.resume(eng, mmeta, dst, fresh)
            eng._attach_relay(dst)
            eng._attach_relay(fresh)
        return eng

    @classmethod
    def _restore_v1(cls, flat: dict, meta: dict,
                    engine_kwargs: dict) -> "QueryEngine":
        """Pre-migration snapshot format: one store, no raw archive (the
        restored engine starts an empty one — rows saved under v1 cannot be
        re-sketched until re-ingested)."""
        if "metric" in engine_kwargs:
            raise ValueError("metric is fixed by the snapshot "
                             f"({meta['metric']!r}); it cannot be overridden "
                             "on restore")
        params = CabinParams(
            n_dims=int(meta["n_dims"]), sketch_dim=int(meta["sketch_dim"]),
            psi_seed=int(meta["psi_seed"]), pi_seed=int(meta["pi_seed"]))
        eng = cls(params, metric=meta["metric"], **engine_kwargs)
        eng._set_store(SketchStore.from_state(flat, meta, spec=eng.spec))
        return eng

    # -- placement ----------------------------------------------------------

    def shard(self, mesh=None, *, n_shards: int | None = None) -> None:
        """Opt-in scale-out: re-home the serving layout as one partition
        group per device of `mesh` (default: the ambient mesh), or as
        `n_shards` logical shards on the default device (no mesh needed —
        what single-device tests and CI exercise).  Rows route by
        ``id % n_shards`` — deterministic and stable across compaction —
        each shard keeps its own base+delta partitions with its matrices
        committed to its device, per-shard band walks share the global
        running k-th bound, and answers merge by (value, id) cross-shard.
        Every query stays bit-identical to the unsharded engine on the
        same history (partition.py's exactness argument); ClusterIndex,
        migrations, and the serving front door work unchanged.  Calling
        shard() again (or with a different mesh) re-shards; in-flight
        migrations pick the new topology up on their next layout build."""
        from repro.distributed import sharding as shd

        if n_shards is not None:
            if mesh is not None:
                raise ValueError("shard(): pass a mesh OR n_shards")
            if int(n_shards) < 1:
                raise ValueError(f"n_shards must be >= 1, got {n_shards}")
            devices = None
            n = int(n_shards)
        else:
            mesh = mesh if mesh is not None else shd.current_mesh()
            if mesh is None:
                raise ValueError("shard() needs a mesh (none active)")
            devices = shd.mesh_devices(mesh)
            n = len(devices)
        self._n_shards = n
        self._devices = devices
        # layouts are derived: drop them (serving and migration tiers) and
        # let the next query rebuild under the new topology.  Cached
        # RESULTS stay valid — answers are placement-independent — but the
        # cache is cleared anyway so a re-shard behaves like the fresh
        # engine it is equivalent to.
        self._tiered = None
        if self._mig is not None:
            self._mig.invalidate_serving_tiers()
        self._cache.clear()
