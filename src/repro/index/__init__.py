"""repro.index: online sketch index + query serving (DESIGN.md section 8).

Turns the batch primitives (core.cabin sketching, core.allpairs streaming
reductions) into a serveable system: a persistent, incrementally updated
collection of packed Cabin sketches with batched k-NN and radius queries,
checkpointing, and opt-in sharding.

Public API:
    SketchStore        — pow2-capacity device buffers; add / remove(tomb-
                         stone) / compact without per-call recompiles
    BandedLayout       — weight-banded snapshot; radius-query band pruning
    TieredLayout       — LSM-style base + delta tiers; O(delta) sync after
                         mutations instead of per-version rebuilds
    QueryEngine        — add_dense / add_sparse / topk / radius / pairwise,
                         save / restore, shard, migrate
    SketchSpec         — versioned (dims, seeds) sketch-space identity
    Migration          — in-flight lazy re-sketch state machine (DESIGN.md
                         section 10); RawArchive is its raw-row store
    ingest_documents   — data.pipeline document stream -> engine

Results are bit-identical to the batch engine on the same membership; see
tests/test_index.py for the pinned contracts, and tests/test_migrate.py /
tests/test_faultinject.py for the drift-migration and crash-safety ones.
"""

from repro.index.bands import (BandedLayout, TieredLayout,  # noqa: F401
                               merge_topk_parts)
from repro.index.engine import QueryEngine  # noqa: F401
from repro.index.ingest import ingest_documents  # noqa: F401
from repro.index.migrate import Migration, RawArchive  # noqa: F401
from repro.index.store import SketchSpec, SketchStore  # noqa: F401
