"""repro.index: online sketch index + query serving (DESIGN.md section 8).

Turns the batch primitives (core.cabin sketching, core.allpairs streaming
reductions) into a serveable system: a persistent, incrementally updated
collection of packed Cabin sketches with batched k-NN and radius queries,
checkpointing, and opt-in sharding.

Public API:
    SketchStore        — pow2-capacity device buffers; add / remove(tomb-
                         stone) / compact without per-call recompiles
    BandedLayout       — weight-banded snapshot; radius-query band pruning
    Partition          — one serving unit: slot subset x device x layout
                         kind (sorted-banded | brute-delta) x spec
    PartitionSet       — per-shard base+delta partition groups; O(delta)
                         sync, shard-local merge policy, global k-th bound
                         (TieredLayout is its n_shards=1 alias)
    QueryEngine        — add_dense / add_sparse / topk / radius / pairwise,
                         save / restore, shard, migrate
    SketchSpec         — versioned (dims, seeds) sketch-space identity
    Migration          — in-flight lazy re-sketch state machine (DESIGN.md
                         section 10); RawArchive is its raw-row store
    ingest_documents   — data.pipeline document stream -> engine
    bulk_ingest        — merge-tree parallel bulk load: N workers sketch
                         shards in parallel, log-depth combine
                         (index/merge_tree.py, DESIGN.md section 14)
    Mergeable          — the shared combine contract (mergeable.py):
                         associative, id-disjoint, spec-checked merge();
                         MergeIncompatible is its refusal error

Results are bit-identical to the batch engine on the same membership — at
every shard count; see tests/test_index.py and tests/test_partition.py for
the pinned contracts, and tests/test_migrate.py / tests/test_faultinject.py
for the drift-migration and crash-safety ones.
"""

from repro.index.bands import BandedLayout  # noqa: F401
from repro.index.engine import QueryEngine  # noqa: F401
from repro.index.ingest import ingest_documents  # noqa: F401
from repro.index.merge_tree import bulk_ingest, merge_tree  # noqa: F401
from repro.index.mergeable import (Mergeable,  # noqa: F401
                                   MergeIncompatible, check_id_disjoint,
                                   check_spec_compatible)
from repro.index.migrate import Migration, RawArchive  # noqa: F401
from repro.index.partition import (Partition, PartitionSet,  # noqa: F401
                                   TieredLayout, merge_topk_parts)
from repro.index.store import SketchSpec, SketchStore  # noqa: F401
