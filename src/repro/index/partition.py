"""The partition model: tiers, shards, and spec tiers as ONE object.

Three subsystems grew the same serving discipline independently: the
tiered layout's base+delta tiers (PR 4), the migration's per-spec
src/dst/fresh tiers (PR 6), and mesh sharding's per-device row placement
(ROADMAP item 1).  All three answer a query as "(value, id)-lex-mergeable
partial results over disjoint slot ranges" — the mergeable-summary
structure of the streaming sketch literature.  This module is that shared
layer (DESIGN.md section 13):

  * `Partition` — one unit of serving state: a slot subset of one store,
    a device placement, a layout kind (``sorted-banded``: a weight-banded
    `BandedLayout` snapshot served through the progressive band walk;
    ``brute-delta``: an unsorted slot list scanned brute-force), the
    SketchSpec its rows were sketched under, and an alive mask.  Version
    RANGE stamps live on the owning set — validity is "the store moved
    from stamp A to stamp B and the set absorbed the difference", not
    version equality.
  * `PartitionSet` — the serving object the engine holds: `n_shards`
    groups of (base, delta) partitions over one store, rows routed by
    ``id % n_shards`` (deterministic, history-independent, stable across
    compaction).  It owns the disciplines that used to be smeared across
    engine.py / bands.py / migrate.py: pow2 micro-batch bucketing (every
    gather goes through `padded_take`), version-range invalidation
    (`sync`), per-partition band pruning with a GLOBAL running k-th bound
    (a tight bound from shard 0 prunes bands in shard 7 — threaded as
    `init_kth` into `allpairs.topk_rows_banded`), shard-local compaction
    / merge policy (each shard folds its own delta independently),
    cross-partition `merge_topk_parts`, per-partition deadline budgets,
    and per-partition obs gauges.
  * module functions — `merge_topk_parts` (THE one cross-partition merge
    rule), `topk_across_tiers` (the cross-spec mid-migration merge, same
    bound threading), `radius_hits` (the shared per-tier threshold-scan
    collection), `snapshot_subtrees` (one checkpoint subtree per backing
    store).

Exactness: partitions are DISJOINT and exhaustive over the alive
membership, each returns an exact — or, under the running bound, a
provably sufficient — (value, id)-lex k-best over its rows, and the merge
is the same lexicographic rule `topk_rows_banded` uses across chunks.  So
a PartitionSet at ANY shard count is bit-identical to a single batch scan
of the same membership, for every mutation history and both metrics —
sharding, like tiering, is a pure serving optimisation with zero
bit-identity risk.  `TieredLayout` is the ``n_shards=1`` face of this
object, kept as an alias.

Crash safety: layouts are DERIVED state.  A sharded rebuild fires the
``shard.rebalance`` faultinject point before any group is replaced, so an
injected crash leaves the previous groups intact and the next sync simply
retries — the crash-matrix entry in tests/test_faultinject.py pins that
serving stays exact through it.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import allpairs
from repro.core.allpairs import KBEST_KEY_PAD, kbest_lex_merge
from repro.core.packing import padded_take
from repro.index.bands import BandedLayout
from repro.index.mergeable import MergeIncompatible, check_spec_compatible
from repro.index.store import SketchStore
from repro.obs.registry import NULL_REGISTRY
from repro.runtime import faultinject

_CP_REBALANCE = faultinject.declare("shard.rebalance")

PARTITION_KINDS = ("sorted-banded", "brute-delta")


# ---------------------------------------------------------------------------
# the one cross-partition merge rule
# ---------------------------------------------------------------------------


def merge_topk_parts(kk: int, parts: list[tuple[np.ndarray, np.ndarray]]
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-partition k-best lists into THE exact (value, id)-lex
    k-best: `parts` is a list of (ids (Q, <=kk), vals (Q, <=kk)) answers
    over DISJOINT row partitions, each already exact (or provably
    sufficient under a running k-th bound) over its partition.  Shared by
    the base+delta tier merge, the cross-shard merge, and the migration's
    cross-spec (old store / new store / fresh store) merge — one rule, so
    partitioned serving is bit-identical to a single scan by construction.
    Short lists are padded with (KBEST_KEY_PAD, inf), which sorts after any
    real candidate; pads survive only when the union holds < kk rows.

    kk must be >= 0; an empty `parts` list returns the well-typed empty
    answer ((0, kk) ids / vals) — there are zero queries to answer for."""
    if kk < 0:
        raise ValueError(f"merge_topk_parts: k must be >= 0, got {kk}")
    if len(parts) == 0:
        return (np.zeros((0, kk), np.int64), np.zeros((0, kk), np.float32))
    if len(parts) == 1:
        return parts[0]  # a lone partition is already the exact k'-best

    def pad_cols(ids: np.ndarray, vals: np.ndarray):
        have = ids.shape[1]
        if have == kk:
            return ids, vals
        padw = ((0, 0), (0, kk - have))
        return (np.pad(ids, padw, constant_values=KBEST_KEY_PAD),
                np.pad(vals, padw, constant_values=np.inf))

    padded = [pad_cols(i, v) for i, v in parts]
    vals, ids = kbest_lex_merge(
        kk, np.concatenate([v for _, v in padded], axis=1),
        np.concatenate([i for i, _ in padded], axis=1))
    return ids, vals


def _tighten(running: np.ndarray | None, vals: np.ndarray, kk: int
             ) -> np.ndarray | None:
    """Fold a merged candidate list into the running global k-th bound.
    The bound only ever tightens; lists still short of kk columns carry
    no bound (and inf pads inside a full-width list are harmless — the
    min just keeps the previous bound there)."""
    if vals.shape[1] < kk:
        return running
    kth = vals[:, kk - 1]
    return kth.copy() if running is None else np.minimum(running, kth)


def shard_of(ids: np.ndarray, n_shards: int) -> np.ndarray:
    """THE row-routing rule: ``id % n_shards``.  Deterministic and
    history-independent, so the same membership shards identically no
    matter how it was built, and stable across compaction (ids survive,
    slots don't).  Slot-level routing lives on the store
    (`SketchStore.route_slots`, the same rule)."""
    return np.asarray(ids, np.int64) % int(n_shards)


# ---------------------------------------------------------------------------
# Partition: one tier of one shard
# ---------------------------------------------------------------------------


class Partition:
    """One unit of partitioned serving state (see module docstring).

    ``sorted-banded`` wraps a `BandedLayout` over the given slot subset
    (weight-sorted, banded, progressive-walk served); ``brute-delta``
    holds an unsorted slot list in ascending id order, gathered lazily to
    a pow2-padded device matrix and scanned brute-force.  Both carry the
    device they are placed on and the SketchSpec their rows were sketched
    under; alive masks thread through without rebuilds (`refresh`).
    """

    __slots__ = ("kind", "shard", "device", "spec", "banded",
                 "slots", "ids", "_cache", "_store")

    def __init__(self, kind: str, shard: int, store: SketchStore, *,
                 device=None, metric: str | None = None,
                 band_rows: int = 1024, registry=None,
                 slots: np.ndarray | None = None):
        if kind not in PARTITION_KINDS:
            raise ValueError(
                f"partition kind must be one of {PARTITION_KINDS}, "
                f"got {kind!r}")
        self.kind = kind
        self.shard = int(shard)
        self.device = device
        self.spec = store.spec
        self._store = store
        if kind == "sorted-banded":
            self.banded = BandedLayout(store, metric, band_rows=band_rows,
                                       registry=registry, slots=slots,
                                       device=device)
            self.slots = self.banded.slots
            self.ids = self.banded.ids
        else:
            self.banded = None
            self.slots = (np.zeros(0, np.int64) if slots is None
                          else np.asarray(slots, np.int64))
            self.ids = store.ids_at(self.slots)
        self._cache: jnp.ndarray | None = None

    @property
    def n_rows(self) -> int:
        """Alive rows this partition serves."""
        if self.banded is not None:
            return self.banded.n_alive
        return len(self.slots)

    # -- brute-delta maintenance (O(delta) host work, no device traffic) ----

    def extend(self, slots: np.ndarray) -> None:
        """Append fresh store slots (brute-delta only) — the gathered view
        is invalidated, not rebuilt: a burst of adds between two queries
        pays for one gather, not one per mutation."""
        if len(slots):
            self.slots = np.concatenate([self.slots, slots])
            self._cache = None

    def refresh(self, store: SketchStore,
                mask: np.ndarray | None = None) -> None:
        """Drop tombstoned slots (they never resurrect; `mask` is the
        alive bitmap the owning set's sync already read, when it read one)
        and re-read the id map — the brute-delta twin of
        `BandedLayout.refresh_alive`."""
        changed = False
        if mask is not None and not mask.all():
            self.slots = self.slots[mask]
            changed = True
        if changed or len(self.slots) != len(self.ids):
            self._cache = None
        self.ids = store.ids_at(self.slots)
        self._store = store

    @property
    def matrix(self) -> jnp.ndarray | None:
        """The pow2-padded device matrix, gathered lazily at first use
        after a sync and committed to this partition's device (so the
        distance tiles against it run THERE — uncommitted query arrays
        follow committed operands).  jnp.take copies, so the view survives
        later donated appends to the store buffer."""
        if self.banded is not None:
            return self.banded.matrix
        if self._cache is None and len(self.slots):
            m = padded_take(self._store.sk_buf, self.slots)
            if self.device is not None:
                m = jax.device_put(m, self.device)
            self._cache = m
        return self._cache


class _ShardGroup:
    """One shard's (base, delta) partition pair."""

    __slots__ = ("shard", "device", "base", "delta")

    def __init__(self, shard: int, device, base: Partition,
                 delta: Partition):
        self.shard = shard
        self.device = device
        self.base = base
        self.delta = delta


# ---------------------------------------------------------------------------
# PartitionSet: the serving object
# ---------------------------------------------------------------------------


class PartitionSet:
    """`n_shards` (base, delta) partition groups over one store — the
    engine's serving structure (DESIGN.md sections 8.5 and 13).

    Per shard, the base partition is a `BandedLayout` over the shard's
    membership at the last fold; fresh adds route by ``id % n_shards``
    into per-shard brute-delta partitions; removes flip per-partition
    alive masks.  `sync` advances the set across any version range of the
    same slot epoch in O(delta); compaction (an epoch bump) rebuilds, and
    the size-ratio merge policy folds each shard's delta into its base
    INDEPENDENTLY (shard-local compaction — one hot shard folding does
    not touch its siblings).

    `topk` walks the groups accumulating a global running k-th bound:
    each banded walk receives the bound as `init_kth` and prunes against
    it, each partial answer merges through `merge_topk_parts`, and the
    bound tightens after every merge.  Exactness per partition + disjoint
    memberships + the shared lex merge = bit-identical to one batch scan,
    at every shard count, for every mutation history and both metrics.

    With ``n_shards=1`` this is exactly the old TieredLayout (the alias
    below); `devices` places shard s's matrices on
    ``devices[s % len(devices)]`` (None: default device — logical
    sharding, which CI exercises without a mesh).
    """

    def __init__(self, store: SketchStore, metric: str,
                 band_rows: int = 1024, merge_ratio: float | None = 0.125,
                 registry=None, n_shards: int = 1, devices=None,
                 role: str = "serve"):
        if int(n_shards) < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.metric = metric
        self.d = store.d
        self.band_rows = int(band_rows)
        self.merge_ratio = merge_ratio
        self.registry = NULL_REGISTRY if registry is None else registry
        self.n_shards = int(n_shards)
        self.devices = list(devices) if devices else None
        self.role = role
        self.n_merges = -1  # the initial build below is not a merge
        self._groups: list[_ShardGroup] = []
        self._rebuild(store)
        self._register_gauges()

    def _device_for(self, shard: int):
        if not self.devices:
            return None
        return self.devices[shard % len(self.devices)]

    # -- construction / synchronisation ------------------------------------

    def _build_group(self, shard: int, store: SketchStore,
                     slots: np.ndarray) -> _ShardGroup:
        dev = self._device_for(shard)
        base = Partition("sorted-banded", shard, store, device=dev,
                         metric=self.metric, band_rows=self.band_rows,
                         registry=self.registry, slots=slots)
        delta = Partition("brute-delta", shard, store, device=dev)
        return _ShardGroup(shard, dev, base, delta)

    def _rebuild(self, store: SketchStore) -> None:
        """Re-route the alive membership to shards and fold every shard
        into a freshly sorted base partition (the O(N log N) path `sync`
        exists to avoid paying per mutation).  The groups are built into a
        local list and swapped in at the end: an injected crash at the
        ``shard.rebalance`` point (or a real one) leaves the previous —
        stale but internally consistent — groups in place, and the next
        sync retries.  Layouts are derived state; the store is never
        touched."""
        if self.n_shards > 1:
            faultinject.crash_point(_CP_REBALANCE)
        slots = store.alive_slots()
        groups = [self._build_group(s, store, sh_slots)
                  for s, sh_slots in enumerate(
                      store.route_slots(slots, self.n_shards))]
        self._groups = groups
        self._store = store
        # per-set spec record: every row this set serves was sketched
        # under it, and the cross-version merge keys the query sketch on it
        self.spec = store.spec
        st = store.stamp()
        self.version, self.epoch, self.seen_size = (
            st.version, st.epoch, st.size)
        self.seen_removed = store.removed_count
        self.n_merges += 1

    def _fold_group(self, g: _ShardGroup, store: SketchStore) -> None:
        """Shard-local merge: fold ONE shard's delta back into its base.
        Siblings keep their layouts (and their band walks' warm device
        matrices) untouched — the policy that makes a hot shard's churn a
        local cost."""
        slots = store.alive_slots()
        if self.n_shards > 1:
            keep = shard_of(store.ids_at(slots), self.n_shards) == g.shard
            slots = slots[keep]
        fresh = self._build_group(g.shard, store, slots)
        g.base, g.delta = fresh.base, fresh.delta
        self.n_merges += 1

    def sync(self, store: SketchStore) -> "PartitionSet":
        """Advance to the store's current (version, epoch) — THE entry the
        engine calls before serving.  Version unchanged: free.  Adds
        within the epoch: route the new slots to the per-shard delta
        partitions (O(delta)).  Removes: refresh the per-partition alive
        masks (O(n) host bitmap reads).  Epoch change (compaction) or
        merge_ratio=0: full rebuild; the merge policy tripping folds only
        the shard that tripped it."""
        st = store.stamp()
        self._store = store
        if (st.version, st.epoch) == (self.version, self.epoch):
            return self
        if st.epoch != self.epoch or self.merge_ratio == 0:
            # epoch bump (compaction renumbered slots), or merge_ratio=0:
            # the pre-tiered rebuild-per-version baseline, which rebuilt
            # on EVERY mutation — removes included
            self._rebuild(store)
            return self
        added = st.size > self.seen_size
        new_by_shard = None
        if added:
            new_by_shard = store.route_slots(
                store.tail_slots(self.seen_size), self.n_shards)
            self.seen_size = st.size
        removed = store.removed_count != self.seen_removed
        if removed:
            self.seen_removed = store.removed_count
        for g in self._groups:
            if added:
                g.delta.extend(new_by_shard[g.shard])
            delta_mask = None
            if removed:
                # only a version range that actually contains removes pays
                # the O(n) host bitmap re-read — append-heavy traffic skips
                g.base.banded.refresh_alive(store)
                delta_mask = store.alive_at(g.delta.slots)
                live_delta = int(np.count_nonzero(delta_mask))
            else:
                live_delta = len(g.delta.slots)
            base_alive = g.base.banded.n_alive
            dead_base = g.base.banded.n - base_alive
            # merge policy (per shard): fold when the delta outgrows its
            # share of the base (brute-force delta scans stop being cheap),
            # or when tombstones outnumber alive base rows.  None never
            # auto-folds (the caller manages folding via compact()).
            if (self.merge_ratio is not None
                    and (live_delta > self.merge_ratio * max(base_alive, 1)
                         or dead_base > max(base_alive, 1))):
                self._fold_group(g, store)
                continue
            if added or removed:
                g.delta.refresh(store, delta_mask)
        self.version = st.version
        return self

    # -- merge (the Mergeable contract, repro.index.mergeable) --------------

    def merge(self, other: "PartitionSet | None" = None) -> "PartitionSet":
        """Absorb the backing store's just-merged rows and return self —
        the layout half of the Mergeable contract, called by
        `QueryEngine.merge` AFTER `SketchStore.merge` committed.

        Layouts are DERIVED state, so the merge IS a sync against the
        already-merged store: an append-path store merge arrives as
        ordinary tail slots, re-routed by ``id % n_shards`` into each
        shard's brute-delta partition (shard-local absorption — no base
        rebuild, sibling shards untouched until their own fold policy
        trips); an interleave-path merge bumped the store epoch, so the
        set rebuilds, exactly as after a compaction.  `other` (the
        discarded set of the absorbed store, when one exists) is only
        VALIDATED — metric/spec compatibility — never read: its
        partitions index a store that no longer serves.  Gauges re-point
        at the live groups afterwards (a registry merge may have frozen
        them to snapshot values)."""
        if other is not None:
            if other.metric != self.metric:
                raise MergeIncompatible(
                    f"PartitionSet.merge: metric mismatch "
                    f"({self.metric!r} vs {other.metric!r})")
            if self.spec is not None or other.spec is not None:
                check_spec_compatible(other.spec, self.spec,
                                      what="PartitionSet.merge")
        self.sync(self._store)
        self._register_gauges()
        return self

    # -- introspection ------------------------------------------------------

    def partitions(self) -> list[Partition]:
        """Every partition in shard order, base before delta — the
        introspection surface obs gauges and tests read."""
        out: list[Partition] = []
        for g in self._groups:
            out.append(g.base)
            out.append(g.delta)
        return out

    @property
    def base(self) -> BandedLayout:
        """The single-shard base tier (introspection + tests).  A sharded
        set has one base PER SHARD — use `partitions()` there."""
        if len(self._groups) != 1:
            raise AttributeError(
                f"a {self.n_shards}-shard PartitionSet has no single base "
                "tier; iterate partitions()")
        return self._groups[0].base.banded

    @property
    def delta_n(self) -> int:
        return sum(g.delta.n_rows for g in self._groups)

    @property
    def n_alive(self) -> int:
        return sum(g.base.n_rows + g.delta.n_rows for g in self._groups)

    @property
    def base_rows(self) -> int:
        return sum(g.base.banded.n for g in self._groups)

    @property
    def base_alive(self) -> int:
        return sum(g.base.banded.n_alive for g in self._groups)

    @property
    def n_bands(self) -> int:
        return sum(g.base.banded.n_bands for g in self._groups)

    # -- obs ----------------------------------------------------------------

    def _register_gauges(self) -> None:
        """Per-partition structural gauges: `partition_rows` labelled by
        (shard, kind, role, device) — read-time callbacks onto the live
        groups, so a fold or rebalance is visible at the next scrape.
        Re-registering the same labels (a successor set after a migration
        publish) swaps the callback to the newest set."""
        if self.registry.is_null:
            return
        for g in self._groups:
            dev = "host" if g.device is None else str(g.device)
            for kind in PARTITION_KINDS:
                self.registry.gauge_fn(
                    "partition_rows",
                    (lambda s=g.shard, k=kind: float(self._rows_of(s, k))),
                    shard=str(g.shard), kind=kind, role=self.role,
                    device=dev)

    def _rows_of(self, shard: int, kind: str) -> int:
        if shard >= len(self._groups):
            return 0
        g = self._groups[shard]
        return g.base.n_rows if kind == "sorted-banded" else g.delta.n_rows

    # -- serving ------------------------------------------------------------

    def topk(self, queries_padded: jnp.ndarray, query_weights: np.ndarray,
             k: int, *, q_valid: int, block: int = 2048,
             mode: str | None = None, deadline=None,
             info_out: dict | None = None,
             init_kth: np.ndarray | None = None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Cross-partition k-NN: (ids (Q, k'), dists (Q, k')), k' = min(k,
        n_alive), ascending by (distance, id) — bit-identical to
        core.allpairs.topk_rows over the full alive membership in id
        order, at every shard count.

        Groups are walked in shard order, base partition then delta; the
        running global k-th bound tightens after every merge and enters
        the next banded walk as its `init_kth`, so a tight bound from an
        early shard prunes (possibly ALL of) a later shard's bands.
        `deadline` budgets every banded walk (per-partition budgets — the
        brute-delta scans are already O(delta) and exact); any partial
        walk makes the merged answer partial, with the max residual
        cert_gap.  `init_kth` seeds the bound from partitions OUTSIDE this
        set (the cross-spec mid-migration merge)."""
        if info_out is not None:
            info_out.update(partial=False, cert_gap=0.0)
        kk = min(k, self.n_alive)
        if kk <= 0 or q_valid == 0:
            return (np.zeros((q_valid, 0), np.int64),
                    np.zeros((q_valid, 0), np.float32))
        best: tuple[np.ndarray, np.ndarray] | None = None
        running = (None if init_kth is None
                   else np.asarray(init_kth, np.float32)[:q_valid])
        partial, cert_gap = False, 0.0
        bands_visited = rows_visited = 0
        want_info = info_out is not None or deadline is not None
        with obs.span("partition.merge", shards=self.n_shards, k=kk,
                      role=self.role):
            for g in self._groups:
                if g.base.banded.n_alive:
                    st: dict | None = {} if want_info else None
                    part = g.base.banded.topk(
                        queries_padded, query_weights, kk, q_valid=q_valid,
                        block=block, mode=mode, deadline=deadline,
                        info_out=st, init_kth=running)
                    if st is not None:
                        partial |= bool(st.get("partial"))
                        cert_gap = max(cert_gap, st.get("cert_gap", 0.0))
                        bands_visited += st.get("bands_visited", 0)
                        rows_visited += st.get("rows_visited", 0)
                    best = (part if best is None
                            else merge_topk_parts(kk, [best, part]))
                    running = _tighten(running, best[1], kk)
                if g.delta.n_rows:
                    # pad_k keeps k == kk even while the delta holds fewer
                    # rows: k is a static jit arg, so letting it track the
                    # delta size would recompile on every add (tail pads
                    # merge away below)
                    pos, vals = allpairs.topk_rows(
                        queries_padded, g.delta.matrix, kk, d=self.d,
                        metric=self.metric, block=block, mode=mode,
                        m_valid=g.delta.n_rows, pad_k=True)
                    pos, vals = pos[:q_valid], vals[:q_valid]
                    ids = np.full(pos.shape, KBEST_KEY_PAD, np.int64)
                    real = pos >= 0
                    ids[real] = g.delta.ids[pos[real]]
                    part = (ids, vals)
                    best = (part if best is None
                            else merge_topk_parts(kk, [best, part]))
                    running = _tighten(running, best[1], kk)
        if info_out is not None:
            info_out.update(partial=partial, cert_gap=cert_gap,
                            bands_visited=bands_visited,
                            rows_visited=rows_visited)
        assert best is not None  # kk > 0 implies some non-empty partition
        return best

    def radius_tiers(self, query_weights: np.ndarray, radius: float
                     ) -> list[tuple[jnp.ndarray, int, np.ndarray]]:
        """Per-partition (matrix, n_selected, ids) selections for a radius
        query: each shard's base after its band prune, each delta whole
        (it is small by the merge policy — brute-force is the prune).
        Partition memberships partition the alive set, so the per-tier
        `threshold_pairs` hits union to exactly the batch engine's answer
        on the full membership."""
        out = []
        for g in self._groups:
            bl = g.base.banded
            if bl.n_alive:
                mask = bl.candidate_bands(query_weights, radius)
                if not self.registry.is_null:
                    kept = int(np.count_nonzero(mask))
                    bl._c_queries.inc()
                    bl._c_visited.inc(kept)
                    bl._c_pruned.inc(bl.n_bands - kept)
                sel, n_sel, sel_ids = bl.select(mask)
                if n_sel:
                    out.append((sel, n_sel, sel_ids))
            if g.delta.n_rows:
                out.append((g.delta.matrix, g.delta.n_rows, g.delta.ids))
        return out


# the n_shards=1 face of PartitionSet — the name the LSM-tier PRs used
TieredLayout = PartitionSet


# ---------------------------------------------------------------------------
# cross-set serving helpers (the mid-migration / cross-spec paths)
# ---------------------------------------------------------------------------


def topk_across_tiers(kk: int, tiers, *, q_valid: int, block: int,
                      mode: str | None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Global (value, id)-lex k-best across PARTITION SETS — the
    mid-migration path, where each tier is a whole PartitionSet over one
    store under one spec and the query was sketched once per spec.
    `tiers` is a list of (layout, queries_padded, query_weights); the
    running k-th bound threads ACROSS sets too (each set receives it as
    `init_kth` and returns a sufficient part), so the merged answer equals
    merging per-store reference answers, each under its own spec."""
    best: tuple[np.ndarray, np.ndarray] | None = None
    running: np.ndarray | None = None
    with obs.span("partition.merge", tiers=len(tiers), k=kk):
        for layout, queries_padded, query_weights in tiers:
            part = layout.topk(queries_padded, query_weights, kk,
                               q_valid=q_valid, block=block, mode=mode,
                               init_kth=running)
            best = (part if best is None
                    else merge_topk_parts(kk, [best, part]))
            running = _tighten(running, best[1], kk)
    if best is None:
        return (np.zeros((q_valid, 0), np.int64),
                np.zeros((q_valid, 0), np.float32))
    return best


def radius_hits(layout, queries_padded: jnp.ndarray,
                query_weights: np.ndarray, q: int, r: float, *,
                metric: str, block: int, mode: str | None,
                hits: list[list[np.ndarray]]) -> None:
    """Accumulate one PartitionSet's radius hits into per-query buckets —
    the shared half of `QueryEngine.radius` and its mid-migration twin:
    per-partition threshold scans, then ONE sort/group pass per selection
    instead of a pairs scan per query."""
    for sel, n_sel, sel_ids in layout.radius_tiers(query_weights, r):
        pairs = allpairs.threshold_pairs(
            queries_padded, sel, d=layout.d, threshold=r, metric=metric,
            block=block, mode=mode, n_valid=q, m_valid=n_sel)
        by_q = pairs[np.argsort(pairs[:, 0], kind="stable")]
        splits = np.searchsorted(by_q[:, 0], np.arange(q + 1))
        for qi in range(q):
            seg = sel_ids[by_q[splits[qi]: splits[qi + 1], 1]]
            if seg.size:
                hits[qi].append(seg)


def snapshot_subtrees(store: SketchStore, raw=None, migration=None) -> dict:
    """Per-partition snapshot subtrees: one checkpoint subtree per backing
    store (layouts are derived state and are never persisted — a restored
    engine rebuilds them, sharded or not, from the stores alone).  The
    subtree names are the `repro.index.v2` on-disk contract
    `QueryEngine.restore` reads."""
    tree: dict = {"store": store.state_tree()}
    if raw is not None:
        tree["raw"] = raw.state_tree()
    if migration is not None:
        tree["mig_dst"] = migration.dst.state_tree()
        tree["mig_fresh"] = migration.fresh.state_tree()
    return tree
