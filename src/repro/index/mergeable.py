"""The Mergeable contract: one combine discipline for every index layer.

BinSketch sketches are OR-mergeable by construction — a sketch of A ∪ B is
the bitwise OR of the sketches of A and B — and the streaming literature
("Binary Coding in Stream", PAPERS.md) treats that mergeability as THE
property that turns a sketch into a distributed-systems primitive: build
partial summaries anywhere, combine them in any tree shape, serve the
result as if it had been built sequentially.  Before this module each
layer above the sketch grew its own private notion of "combine two
states" (obs.MetricsRegistry.merge) or none at all; this module is the
shared contract they all implement (DESIGN.md section 14):

  * `Mergeable` — the protocol: ``merge(other) -> self`` absorbs `other`'s
    state into `self` and returns `self`.  `other` is never mutated, but
    it must be DISCARDED after a successful merge: re-merging it raises
    the id-disjointness check (double-absorption is the classic
    merge-tree corruption, and ids are how we make it impossible).
  * associativity — ``a.merge(b).merge(c)`` equals ``a.merge(b.merge(c))``
    bit-for-bit, which is what lets `index.merge_tree.bulk_ingest` reduce
    N worker shards in log depth and any order.
  * id-disjointness — merge inputs must cover disjoint external-id sets
    (`check_id_disjoint`).  Disjoint ids are what make the merged slot
    order well-defined (slot order == id order survives the merge) and
    what make a merge idempotence bug loud instead of silent.
  * spec compatibility — packed bits are meaningless across sketch specs
    (different dims or hash seeds), and a seed mismatch is UNDETECTABLE
    from the bits alone: same shapes, silently wrong distances.  Every
    merge therefore starts with `check_spec_compatible`, the same guard
    the spec-migration machinery (index/migrate.py) runs on its own
    tiers — cross-spec merge fails loudly, naming both specs, with the
    fix (migrate one side) in the message.

Implementations, bottom-up: `SketchStore.merge` (device buffer combine),
`RawArchive.merge` (raw-row locator union), `PartitionSet.merge` (derived
layout re-sync; merged rows absorbed as shard-routed delta),
`QueryEngine.merge` (store + archive + drift window + obs registries),
`ClusterIndex.merge` (engines merge, centres re-seed from the union via
refit), `obs.MetricsRegistry.merge` (the pre-existing exemplar).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


class MergeIncompatible(ValueError):
    """Two states cannot be merged: spec mismatch, overlapping ids, or
    differing serving configuration.  A ValueError because the caller
    passed an unusable operand — nothing about either input was mutated."""


def _fmt_spec(spec) -> str:
    """One-line spec identity for error messages: version + dims + seeds
    (SketchSpec.meta() when available, repr otherwise — None included)."""
    meta = getattr(spec, "meta", None)
    if callable(meta):
        m = meta()
        return (f"spec(v{m['version']}, n_dims={m['n_dims']}, "
                f"d={m['sketch_dim']}, psi_seed={m['psi_seed']}, "
                f"pi_seed={m['pi_seed']})")
    return repr(spec)


def check_spec_compatible(a, b, *, what: str, hint: str | None = None) -> None:
    """Raise MergeIncompatible unless `a` and `b` are the SAME sketch-space
    identity (SketchSpec equality: version AND CabinParams — dims and both
    hash seeds).  `what` names the operation for the message; `hint` adds
    a remedy line.  None specs are compatible only with None (a spec-less
    store merging into a spec'd one would launder unknown bits into a
    known space)."""
    if a == b:
        return
    msg = (f"{what}: incompatible sketch specs — {_fmt_spec(a)} vs "
           f"{_fmt_spec(b)}.  Packed rows are only comparable under one "
           "spec; a hash-seed mismatch is undetectable from the bits "
           "alone and would silently corrupt every distance.")
    if hint is None and getattr(a, "params", 0) != getattr(b, "params", 1):
        hint = ("Re-sketch one side under the other's spec "
                "(QueryEngine.migrate) before merging")
    if hint:
        msg += f"  {hint}."
    raise MergeIncompatible(msg)


def check_id_disjoint(a_ids: np.ndarray, b_ids: np.ndarray, *,
                      what: str) -> None:
    """Raise MergeIncompatible if the two (ascending) external-id sets
    overlap.  Overlap means the inputs are not independent partial builds
    — most often one of them was already merged (the Mergeable contract
    says discard `other` after absorbing it)."""
    common = np.intersect1d(np.asarray(a_ids, np.int64),
                            np.asarray(b_ids, np.int64))
    if len(common):
        raise MergeIncompatible(
            f"{what}: merge inputs share {len(common)} external id(s) "
            f"(e.g. id {int(common[0])}) — inputs must be id-disjoint "
            "independent builds.  Re-merging an already-absorbed input is "
            "the usual cause; discard an input after a successful merge.")


@runtime_checkable
class Mergeable(Protocol):
    """Associative, id-disjoint, spec-checked combine (module docstring).

    ``a.merge(b)`` absorbs `b` into `a` and returns `a`; `b` is left
    readable but must be discarded (its ids are now absorbed — a second
    merge raises).  Implementations validate BEFORE mutating anything, so
    a refused (or faultinject-killed) merge leaves both inputs intact and
    the call re-runnable."""

    def merge(self, other):  # pragma: no cover - protocol signature only
        ...
