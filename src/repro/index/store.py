"""SketchStore: a growing, device-resident collection of packed sketches.

The batch engine (repro.core.allpairs) answers "given these two matrices,
which pairs are close" — a one-shot question.  A serving system instead owns
a COLLECTION that mutates between queries: documents arrive, stale ones are
deleted, and every query must see the current membership without paying a
rebuild.  SketchStore is that collection, designed around two invariants
(DESIGN.md section 8.1):

  * Power-of-two buffers.  Sketches and their Hamming weights live in device
    buffers whose capacity is always a power of two; appends write through a
    single jitted dynamic_update_slice whose compile key is the (bucketed)
    buffer and batch shape.  Across any mutation history the store compiles
    O(log N) append graphs total — `add` and `remove` never trigger per-call
    recompiles, which is the difference between O(100us) and O(100ms) per
    request on a warm server.
  * Insertion-order slots.  Slot order equals id order: appends go to the
    tail, deletes only tombstone (a host-side bitmap — the device buffer is
    untouched), and compaction preserves relative order.  Alive rows are
    therefore always a stable, id-sorted sequence, which is what makes query
    results bit-identical to a fresh batch build no matter how the store
    got to its current membership (the tier-1 property tests pin this).

Host mirrors (ids, alive bitmap, weights) ride along for planning work that
is latency-bound rather than bandwidth-bound: band layout, capacity checks,
and id translation all happen on host without touching the device buffers.

Stores are MERGEABLE (repro.index.mergeable, DESIGN.md section 14): the
collection is no longer single-writer-only.  N workers may build private
stores in parallel and `merge` combines them — id-disjoint, spec-checked,
and through the same jitted append graph as `add` when the inputs' id
ranges don't interleave (the merge-tree bulk-load case), so a combine
costs one device concat, not a recompile or a re-sketch.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.cabin import CabinParams
from repro.core.packing import pow2_bucket  # the shared bucketing rule
from repro import obs
from repro.index.mergeable import (MergeIncompatible, check_id_disjoint,
                                   check_spec_compatible)
from repro.obs.registry import NULL_REGISTRY
from repro.runtime import faultinject

_CP_COMPACT = faultinject.declare("store.compact")
_CP_MERGE = faultinject.declare("merge.combine")


@dataclass(frozen=True)
class SketchSpec:
    """A VERSIONED sketch-space identity: the CabinParams every row in a
    store was sketched under, plus a monotone generation counter.

    The params alone already define the sketch space; the version exists so
    serving code can ask the cheap question "same space?" without comparing
    seeds, and so snapshots/journals can name which generation a tier
    belongs to.  index/migrate.py moves an engine from spec v to v+1 by
    re-sketching rows — two stores with different specs hold incomparable
    bits, and the cross-version serving path must sketch each query once
    per spec it touches.
    """

    version: int
    params: CabinParams

    @property
    def d(self) -> int:
        return self.params.sketch_dim

    def successor(self, params: CabinParams) -> "SketchSpec":
        if params.n_dims != self.params.n_dims:
            raise ValueError(
                f"spec migration cannot change n_dims "
                f"({self.params.n_dims} -> {params.n_dims}): the raw rows "
                "live in the original categorical space")
        return SketchSpec(self.version + 1, params)

    def meta(self) -> dict:
        return {"version": self.version, "n_dims": self.params.n_dims,
                "sketch_dim": self.params.sketch_dim,
                "psi_seed": self.params.psi_seed,
                "pi_seed": self.params.pi_seed}

    @classmethod
    def from_meta(cls, m: dict) -> "SketchSpec":
        return cls(int(m["version"]), CabinParams(
            n_dims=int(m["n_dims"]), sketch_dim=int(m["sketch_dim"]),
            psi_seed=int(m["psi_seed"]), pi_seed=int(m["pi_seed"])))


class VersionStamp(NamedTuple):
    """A store snapshot identity for layout synchronisation.

    `version` counts every mutation; `epoch` counts only the mutations that
    invalidate SLOT identity (compaction — slots shuffle); `size` is the
    append watermark.  Within one epoch, the rows added between two stamps
    are exactly the slots [old.size, new.size) (`tail_slots`), which is what
    lets the tiered layout absorb adds as an O(delta) delta tier instead of
    rebuilding on every version bump.
    """

    version: int
    epoch: int
    size: int


class AliveView(tuple):
    """The (matrix, n_alive, ids) triple from `gather_alive`, stamped with
    the store version it was taken at.

    Unpacks like the plain 3-tuple it always was; the extra `.version`
    attribute lets consumers (`SketchStore.check_fresh`) reject a view held
    across a mutation with a clear error instead of the accelerator
    backends' late "Array has been deleted" (the append fast path returns
    the live buffer, which the next `add` donates)."""

    def __new__(cls, matrix, n_alive, ids, version: int):
        self = tuple.__new__(cls, (matrix, n_alive, ids))
        self.version = version
        return self

    @property
    def matrix(self):
        return self[0]

    @property
    def n_alive(self) -> int:
        return self[1]

    @property
    def ids(self) -> np.ndarray:
        return self[2]


def _append_rows_fn(sk_buf, wt_buf, rows, start):
    """Write a (kpad, w) batch at a traced offset.  Rows past the caller's
    valid count land in slots beyond `size` — they are never alive and the
    next append overwrites them, so they never escape."""
    sk_buf = jax.lax.dynamic_update_slice(sk_buf, rows, (start, 0))
    wt_buf = jax.lax.dynamic_update_slice(
        wt_buf, packing.popcount_rows(rows), (start,))
    return sk_buf, wt_buf


# donate the buffers so accelerator appends update in place (no O(capacity)
# copy per request); CPU has no donation — skip it there to avoid the
# per-call "donated buffers were not usable" warning
_append_rows = jax.jit(
    _append_rows_fn,
    donate_argnums=(0, 1) if jax.default_backend() != "cpu" else ())


class SketchStore:
    """Append/tombstone/compact container for packed d-bit sketches.

    Rows are addressed by EXTERNAL ids (monotone int64, assigned at `add`,
    stable across compaction and checkpoint restore) — never by slot.
    """

    def __init__(self, d: int, spec: SketchSpec | None = None):
        self.spec = spec  # which sketch space the rows live in (may be None
        # for spec-agnostic uses; the engine always sets it)
        if spec is not None and spec.d != int(d):
            raise ValueError(f"d={d} disagrees with spec.d={spec.d}")
        self.d = int(d)
        self.w = packing.packed_width(self.d)
        cap = pow2_bucket(0)
        self._sk_buf = jnp.zeros((cap, self.w), jnp.int32)
        self._wt_buf = jnp.zeros((cap,), jnp.int32)
        self._ids = np.zeros(cap, np.int64)
        self._alive = np.zeros(cap, bool)
        self._weights = np.zeros(cap, np.int64)
        self._size = 0  # slots in use (alive + tombstoned)
        self._n_alive = 0
        self._next_id = 0
        self.version = 0  # bumped on every mutation; caches key on it
        self._epoch = 0  # bumped only when slot identity changes (compact)
        self._n_removed_total = 0  # monotone; lets layouts skip mask work
        self._placement = None  # opt-in sharding callback (see `place`)
        self._gather_cache: tuple | None = None
        self._listeners: list = []  # mutation observers (see `subscribe`)
        self.set_registry(None)

    def set_registry(self, registry) -> None:
        """Point the store's mutation counters at a MetricsRegistry (None
        resets to the shared no-op registry).  The engine calls this with
        its per-engine registry so ingest/tombstone/compaction volume shows
        up next to the query histograms it drives."""
        reg = NULL_REGISTRY if registry is None else registry
        self._c_added = reg.counter("store_rows_added_total")
        self._c_removed = reg.counter("store_rows_removed_total")
        self._c_compactions = reg.counter("store_compactions_total")
        self._c_merges = reg.counter("store_merges_total")

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return self._n_alive

    @property
    def capacity(self) -> int:
        return self._sk_buf.shape[0]

    @property
    def size(self) -> int:
        """Slots in use, including tombstones (compact() to reclaim)."""
        return self._size

    @property
    def epoch(self) -> int:
        """Slot-identity generation: stable across add/remove (slots only
        append or tombstone), bumped by `compact` (slots shuffle).  Layouts
        that cache slot positions are valid exactly while it holds."""
        return self._epoch

    def stamp(self) -> VersionStamp:
        """(version, epoch, size) — the identity a layout snapshot records
        so a later `tail_slots`/alive-mask sync can replay just the delta."""
        return VersionStamp(self.version, self._epoch, self._size)

    @property
    def removed_count(self) -> int:
        """Monotone count of rows ever tombstoned.  A layout that recorded
        it at its last sync can tell "this version range contains no
        removes" without touching the bitmap — the common mutation mix
        (append-heavy) then pays zero alive-mask work per sync."""
        return self._n_removed_total

    def tail_slots(self, since_size: int) -> np.ndarray:
        """Slots appended since a stamp taken at `since_size` — the
        per-version row range a delta tier is built from.  Only valid
        within the stamp's epoch (compaction renumbers slots; compare
        `epoch` first)."""
        if not 0 <= since_size <= self._size:
            raise ValueError(
                f"since_size={since_size} outside the store's slot range "
                f"[0, {self._size}] (stale stamp from another epoch?)")
        return np.arange(since_size, self._size, dtype=np.int64)

    def alive_at(self, slots: np.ndarray) -> np.ndarray:
        """Alive bitmap at the given slots (host, no device sync)."""
        return self._alive[slots]

    def ids_at(self, slots: np.ndarray) -> np.ndarray:
        """External ids at the given slots (host, no device sync)."""
        return self._ids[slots]

    def weights_at(self, slots: np.ndarray) -> np.ndarray:
        """Host sketch Hamming weights at the given slots."""
        return self._weights[slots]

    @property
    def sk_buf(self) -> jnp.ndarray:
        """The live packed-sketch buffer.  On accelerator backends the next
        `add` donates it — do not hold across mutations (see
        gather_alive)."""
        return self._sk_buf

    def alive_slots(self) -> np.ndarray:
        """Slots of alive rows, in slot (= insertion = id) order."""
        return np.flatnonzero(self._alive[: self._size])

    def route_slots(self, slots: np.ndarray, n_shards: int
                    ) -> list[np.ndarray]:
        """Split `slots` by shard assignment — THE row-routing rule is
        ``id % n_shards``: deterministic, history-independent (the same
        membership shards identically no matter how it was built), and
        stable across compaction (ids survive, slots don't).  Within each
        shard the incoming ascending-id order is preserved, which is what
        keeps sharded and unsharded layout builds bit-comparable."""
        if int(n_shards) == 1:
            return [slots]
        shard = self._ids[slots] % int(n_shards)
        return [slots[shard == s] for s in range(int(n_shards))]

    def ids(self) -> np.ndarray:
        """External ids of alive rows, ascending."""
        return self._ids[self.alive_slots()]

    def weights(self) -> np.ndarray:
        """Host sketch Hamming weights of alive rows, in id order."""
        return self._weights[self.alive_slots()]

    def contains(self, id_: int) -> bool:
        slot = np.searchsorted(self._ids[: self._size], id_)
        return (slot < self._size and self._ids[slot] == id_
                and bool(self._alive[slot]))

    # -- mutation observers -------------------------------------------------

    def subscribe(self, callback) -> None:
        """Register `callback(event, ids, slots)` to run after every
        mutation commits — the hook per-row SIDECARS (repro.cluster's
        ClusterIndex labels, or any structure keyed on membership) use to
        stay in sync even when the store is mutated directly, not through
        them.  Events: "add" (ids/slots of the appended rows — the slots
        are valid immediately, so the callback may gather the new sketches
        before any later append donates the buffer), "remove" (ids/slots
        tombstoned), "merge" (ids/slots of another store's ALIVE rows just
        absorbed by `merge` — same freshness guarantee as "add"; absorbed
        tombstones fire no event), "compact" (empty arrays; slot identity
        changed — read fresh state from the store).  Callbacks run
        synchronously inside
        the mutation, in subscription order; they must not mutate the
        store re-entrantly.  Pair with `unsubscribe` when the observer is
        discarded — the store holds a strong reference."""
        self._listeners.append(callback)

    def unsubscribe(self, callback) -> None:
        """Remove a `subscribe`d callback (ValueError if absent)."""
        self._listeners.remove(callback)

    def _notify(self, event: str, ids: np.ndarray, slots: np.ndarray) -> None:
        for cb in self._listeners:
            cb(event, ids, slots)

    # -- mutation -----------------------------------------------------------

    def _bump(self) -> None:
        self.version += 1
        self._gather_cache = None

    def _place(self, arr: jnp.ndarray) -> jnp.ndarray:
        if self._placement is None:
            return arr
        return jax.device_put(arr, self._placement(arr.shape))

    def _grow_to(self, cap: int) -> None:
        pad = cap - self.capacity
        self._sk_buf = self._place(jnp.pad(self._sk_buf, ((0, pad), (0, 0))))
        self._wt_buf = self._place(jnp.pad(self._wt_buf, ((0, pad),)))
        self._ids = np.pad(self._ids, (0, pad))
        self._alive = np.pad(self._alive, (0, pad))
        self._weights = np.pad(self._weights, (0, pad))

    def add(self, packed, n_valid: int | None = None) -> np.ndarray:
        """Append packed rows; returns their assigned ids (k,) int64.

        `packed` is (kp, w) int32; `n_valid` (default kp) marks how many
        leading rows are real — the engine hands over its power-of-two
        padded sketch batches unchanged, so no reshape happens here.
        """
        packed, k = self._check_batch(packed, n_valid)
        if k == 0:
            return np.zeros(0, np.int64)
        new_ids = np.arange(self._next_id, self._next_id + k, dtype=np.int64)
        return self._append(packed, k, new_ids, notify=True)

    def add_with_ids(self, packed, ids, n_valid: int | None = None,
                     *, notify: bool = False) -> np.ndarray:
        """Append packed rows under EXPLICIT external ids — the migration
        path (index/migrate.py), which rebuilds a store row-by-row while
        preserving the original id assignment.  `ids` must be strictly
        ascending and greater than every id already appended, so the
        slot-order == id-order invariant survives by construction.
        Defaults to notify=False: a migrated row is not new membership, and
        per-id sidecars (ClusterIndex labels) must NOT double-count it."""
        packed, k = self._check_batch(packed, n_valid)
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(ids) != k:
            raise ValueError(f"{len(ids)} ids for {k} valid rows")
        if k == 0:
            return np.zeros(0, np.int64)
        floor = self._ids[self._size - 1] if self._size else -1
        if ids[0] <= floor or (k > 1 and (np.diff(ids) <= 0).any()):
            raise ValueError(
                "add_with_ids requires strictly ascending ids above the "
                f"store's last id ({floor}); got head {ids[:4]}")
        return self._append(packed, k, ids, notify=notify)

    def add_packed(self, packed, spec: SketchSpec | None,
                   n_valid: int | None = None) -> np.ndarray:
        """Spec-checked `add`: the caller names the SketchSpec its packed
        rows were sketched under, and a mismatch with the store's spec
        raises MergeIncompatible naming BOTH specs — before any device
        work.  The check exists because a wrong `d` only fails later as an
        opaque jax shape error, and wrong hash seeds never fail at all
        (same shapes, silently corrupt distances).  `spec=None` asserts
        nothing beyond the width check (the trusting legacy path)."""
        if spec is not None:
            check_spec_compatible(spec, self.spec,
                                  what="SketchStore.add_packed")
        return self.add(packed, n_valid=n_valid)

    def _check_batch(self, packed, n_valid) -> tuple[jnp.ndarray, int]:
        packed = jnp.asarray(packed)
        if packed.ndim != 2 or packed.shape[1] != self.w:
            whose = "" if self.spec is None else \
                f" (store spec: d={self.spec.d}, v{self.spec.version})"
            raise ValueError(
                f"expected (k, {self.w}) packed rows, got "
                f"{packed.shape}{whose}")
        k = packed.shape[0] if n_valid is None else int(n_valid)
        if not 0 <= k <= packed.shape[0]:
            raise ValueError(
                f"n_valid={k} outside the {packed.shape[0]} supplied rows")
        return packed, k

    def _append(self, packed: jnp.ndarray, k: int, new_ids: np.ndarray,
                *, notify: bool) -> np.ndarray:
        kpad = pow2_bucket(k)
        if packed.shape[0] < kpad:
            packed = jnp.pad(packed, ((0, kpad - packed.shape[0]), (0, 0)))
        elif packed.shape[0] > kpad:
            packed = packed[:kpad]
        if self._size + kpad > self.capacity:
            self._grow_to(pow2_bucket(self._size + kpad))
        self._sk_buf, self._wt_buf = _append_rows(
            self._sk_buf, self._wt_buf, packed, jnp.int32(self._size))
        if self._placement is not None:
            self._sk_buf = self._place(self._sk_buf)
            self._wt_buf = self._place(self._wt_buf)
        sl = slice(self._size, self._size + k)
        self._ids[sl] = new_ids
        self._alive[sl] = True
        # host weight mirror reads back the device popcounts just written by
        # _append_rows — k ints, cheaper than re-deriving from the packed
        # batch on host
        self._weights[sl] = np.asarray(self._wt_buf[sl], np.int64)
        self._size += k
        self._n_alive += k
        self._next_id = max(self._next_id, int(new_ids[-1]) + 1)
        self._c_added.inc(k)
        self._bump()
        if notify:
            self._notify("add", new_ids,
                         np.arange(self._size - k, self._size,
                                   dtype=np.int64))
        return new_ids

    def remove(self, ids, *, notify: bool = True) -> int:
        """Tombstone rows by id (device buffers untouched).  Raises KeyError
        on unknown or already-removed ids.  Returns the number removed.

        notify=False is the QUIET tombstone the migration uses when a row
        leaves this store because it moved to the new-spec store: membership
        is unchanged globally, so per-id sidecars must not see a "remove" —
        but version/removed_count still bump so layouts resync."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(np.unique(ids)) != len(ids):
            raise ValueError("duplicate ids in remove batch")
        slots = np.searchsorted(self._ids[: self._size], ids)
        for id_, slot in zip(ids.tolist(), slots.tolist()):
            if (slot >= self._size or self._ids[slot] != id_
                    or not self._alive[slot]):
                raise KeyError(f"id {id_} not in store")
        self._alive[slots] = False
        self._n_alive -= len(ids)
        self._n_removed_total += len(ids)
        self._c_removed.inc(len(ids))
        self._bump()
        if notify:
            self._notify("remove", ids, slots.astype(np.int64))
        return len(ids)

    def compact(self) -> None:
        """Drop tombstoned slots, preserving insertion order, and shrink the
        buffers to the smallest power-of-two capacity that fits."""
        with obs.span("store.compact", size=self._size,
                      n_alive=self._n_alive):
            self._compact()

    def _compact(self) -> None:
        faultinject.crash_point(_CP_COMPACT)
        self._c_compactions.inc()
        slots = self.alive_slots()
        n = len(slots)
        cap = pow2_bucket(n)
        self._sk_buf = self._place(packing.padded_take(self._sk_buf, slots))
        self._wt_buf = self._place(packing.padded_take(self._wt_buf, slots))
        ids = np.zeros(cap, np.int64)
        ids[:n] = self._ids[slots]
        weights = np.zeros(cap, np.int64)
        weights[:n] = self._weights[slots]
        alive = np.zeros(cap, bool)
        alive[:n] = True
        self._ids, self._weights, self._alive = ids, weights, alive
        self._size = n
        self._n_alive = n
        self._epoch += 1  # slots renumbered: layouts must rebuild, not sync
        self._bump()
        self._notify("compact", np.zeros(0, np.int64), np.zeros(0, np.int64))

    # -- merge (the Mergeable contract, repro.index.mergeable) --------------

    def merge(self, other: "SketchStore") -> "SketchStore":
        """Absorb `other`'s slots (alive AND tombstoned) into this store
        and return self — the device-level half of the Mergeable contract
        (DESIGN.md section 14).  Inputs must share a spec and cover
        disjoint external ids; validation runs BEFORE any mutation, so a
        refused (or faultinject-killed — the ``merge.combine`` crash
        point) merge leaves both stores intact and re-runnable.  `other`
        is never mutated but must be discarded after success: its ids are
        absorbed, and a re-merge raises the disjointness check.

        Two paths, both preserving slot order == id order:

          * append (other's smallest id above self's largest — every
            merge-tree combine, where workers build disjoint ascending id
            ranges): other's used slots ride the SAME jitted
            `_append_rows` graph as `add` — one device concat, no
            recompile, and NO epoch bump, so an existing PartitionSet
            absorbs the merged rows as ordinary shard-routed delta slots.
          * interleave (id ranges overlap without colliding): the merged
            order is the sorted-id merge of the two slot sequences, built
            via one concatenated gather; slot identity changes, so the
            epoch bumps and layouts rebuild (same contract as compact).

        Tombstones reconcile by import: other's dead slots stay dead here
        and `removed_count` advances by their number, so layout syncs see
        the mask work.  Row counters are NOT incremented (merge the obs
        registries to carry other's counts, as `QueryEngine.merge` does);
        `store_merges_total` counts the combines themselves."""
        if other is self:
            raise MergeIncompatible(
                "SketchStore.merge: cannot merge a store with itself")
        if self.spec is not None or other.spec is not None:
            check_spec_compatible(other.spec, self.spec,
                                  what="SketchStore.merge")
        if other.d != self.d:
            raise MergeIncompatible(
                f"SketchStore.merge: sketch dim mismatch "
                f"(d={self.d} vs d={other.d})")
        if other._size == 0:
            # empty input: validated no-op (no version bump — nothing a
            # layout or cache could observe has changed)
            self._next_id = max(self._next_id, other._next_id)
            return self
        check_id_disjoint(self._ids[: self._size], other._ids[: other._size],
                          what="SketchStore.merge")
        with obs.span("store.merge", rows=other._size,
                      alive=len(other)):
            self._merge(other)
        return self

    def _merge(self, other: "SketchStore") -> None:
        faultinject.crash_point(_CP_MERGE)
        size_a, size_b = self._size, other._size
        o_ids = other._ids[:size_b]
        o_alive = other._alive[:size_b]
        alive_ids = o_ids[o_alive]
        if size_a == 0 or o_ids[0] > self._ids[size_a - 1]:
            # append path: other's slots become this store's tail, through
            # the same compiled append graph as `add`
            kpad = pow2_bucket(size_b)
            if size_a + kpad > self.capacity:
                self._grow_to(pow2_bucket(size_a + kpad))
            self._sk_buf, self._wt_buf = _append_rows(
                self._sk_buf, self._wt_buf, other._sk_buf[:kpad],
                jnp.int32(size_a))
            if self._placement is not None:
                self._sk_buf = self._place(self._sk_buf)
                self._wt_buf = self._place(self._wt_buf)
            sl = slice(size_a, size_a + size_b)
            self._ids[sl] = o_ids
            self._alive[sl] = o_alive
            self._weights[sl] = other._weights[:size_b]
            self._size += size_b
            merged_slots = np.arange(size_a, size_a + size_b,
                                     dtype=np.int64)[o_alive]
        else:
            # interleave path: merged slot order is the sorted-id merge of
            # two already-sorted sequences; one gather from the
            # concatenated buffers rebuilds the tail-to-tail layout
            ids_cat = np.concatenate([self._ids[:size_a], o_ids])
            order = np.argsort(ids_cat, kind="stable")
            take = np.where(order < size_a, order,
                            order - size_a + self.capacity)
            n = size_a + size_b
            cap = pow2_bucket(n)
            sk = packing.padded_take(
                jnp.concatenate([self._sk_buf, other._sk_buf], axis=0),
                take)
            wt = packing.padded_take(
                jnp.concatenate([self._wt_buf, other._wt_buf]), take)
            ids = np.zeros(cap, np.int64)
            ids[:n] = ids_cat[order]
            alive_cat = np.concatenate([self._alive[:size_a], o_alive])
            alive = np.zeros(cap, bool)
            alive[:n] = alive_cat[order]
            w_cat = np.concatenate([self._weights[:size_a],
                                    other._weights[:size_b]])
            weights = np.zeros(cap, np.int64)
            weights[:n] = w_cat[order]
            self._sk_buf = self._place(sk)
            self._wt_buf = self._place(wt)
            self._ids, self._alive, self._weights = ids, alive, weights
            self._size = n
            self._epoch += 1  # slots renumbered: layouts rebuild, not sync
            merged_slots = np.flatnonzero(
                (order >= size_a) & alive_cat[order]).astype(np.int64)
        self._n_alive += len(alive_ids)
        # imported tombstones: dead on arrival here, but they advance the
        # monotone removed counter so layout syncs refresh alive masks
        self._n_removed_total += size_b - len(alive_ids)
        self._next_id = max(self._next_id, other._next_id)
        self._c_merges.inc()
        self._bump()
        self._notify("merge", alive_ids.copy(), merged_slots)

    # -- query-side views ---------------------------------------------------

    def gather_alive(self) -> AliveView:
        """(matrix, n_alive, ids): alive rows gathered in id order into a
        power-of-two padded device matrix.  Rows past n_alive are padding —
        callers mask them via the engines' traced valid counts.

        The result is valid ONLY until the next mutation: the append-only
        fast path returns the live buffer itself, which the next `add`
        DONATES on accelerator backends (the stale matrix then raises
        "Array has been deleted").  Finish (or copy) before mutating —
        every in-repo consumer uses it within a single query call.  The
        returned view is stamped with the store version; pass it to
        `check_fresh` before use if a mutation could have intervened."""
        if self._gather_cache is not None:
            return self._gather_cache
        if self._n_alive == self._size:
            # append-only fast path: no tombstones, so the buffer ITSELF is
            # the id-ordered pow2-padded matrix — no O(N) device gather.
            # Rows past size hold stale append padding, but every consumer
            # masks by the traced valid count, same as the gathered path.
            self._gather_cache = AliveView(
                self._sk_buf, self._size, self._ids[: self._size],
                self.version)
            return self._gather_cache
        slots = self.alive_slots()
        mat = packing.padded_take(self._sk_buf, slots)
        self._gather_cache = AliveView(mat, len(slots), self._ids[slots],
                                       self.version)
        return self._gather_cache

    def check_fresh(self, view: AliveView) -> None:
        """Raise if `view` predates the store's current version — the cheap
        consumer-side guard against the stale-view footgun above.  Views
        without a stamp (plain tuples) are rejected too."""
        version = getattr(view, "version", None)
        if version != self.version:
            raise RuntimeError(
                "stale gather: this view was taken at store version "
                f"{version}, but the store is now at {self.version} — the "
                "matrix may reference a donated buffer.  Re-call "
                "gather_alive() after any add/remove/compact.")

    # -- placement (opt-in sharding) ---------------------------------------

    def place(self, sharding_for_shape) -> None:
        """Install a shape -> jax.sharding.Sharding callback and re-place
        the buffers under it (repro.distributed: rows across the data
        axes).  Subsequent grows/appends/compactions keep the placement."""
        self._placement = sharding_for_shape
        self._sk_buf = self._place(self._sk_buf)
        self._wt_buf = self._place(self._wt_buf)
        self._bump()

    # -- snapshot / restore -------------------------------------------------

    def state_tree(self) -> dict[str, np.ndarray]:
        """Flat tree for checkpoint.Checkpointer: exactly the live slots
        (tombstones included — restore reproduces the store bit-for-bit,
        including pending-compaction state)."""
        return {
            "sk": np.asarray(self._sk_buf[: self._size]),
            "ids": self._ids[: self._size].copy(),
            "alive": self._alive[: self._size].copy(),
            "weights": self._weights[: self._size].copy(),
        }

    def state_meta(self) -> dict:
        return {"d": self.d, "size": self._size, "next_id": self._next_id}

    @classmethod
    def from_state(cls, tree: dict[str, np.ndarray], meta: dict,
                   spec: SketchSpec | None = None) -> "SketchStore":
        store = cls(int(meta["d"]), spec=spec)
        size = int(meta["size"])
        cap = pow2_bucket(size)
        sk = np.zeros((cap, store.w), np.int32)
        sk[:size] = tree["sk"]
        store._sk_buf = jnp.asarray(sk)
        wt = np.zeros(cap, np.int32)
        wt[:size] = tree["weights"]
        store._wt_buf = jnp.asarray(wt)
        store._ids = np.zeros(cap, np.int64)
        store._ids[:size] = tree["ids"]
        store._alive = np.zeros(cap, bool)
        store._alive[:size] = tree["alive"]
        store._weights = np.zeros(cap, np.int64)
        store._weights[:size] = tree["weights"]
        store._size = size
        store._n_alive = int(store._alive.sum())
        store._next_id = int(meta["next_id"])
        store._bump()
        return store
