"""Spec migration: incremental lazy re-sketch of a live index.

A Cabin sketch is a PURE function of (raw categorical row, SketchSpec) —
no training, no data-dependent state — so moving an index from spec v to
spec v+1 is not an approximation problem, only a scheduling one: re-sketch
every alive row through the same `core.cabin` path a fresh build would use,
in bounded batches, while queries keep serving.  This module owns both
halves (DESIGN.md section 10):

  * `RawArchive` — the host-side id -> trimmed-COO row store the engine
    keeps alongside the sketches (keep_raw=True).  It is what makes
    re-sketching possible at all: packed bits under one spec carry no
    information about another spec's hash bins.
  * `Migration` — the three-store state machine:

        src    engine.store, OLD spec.  Rows not yet migrated.  Migrated
               rows are QUIET-tombstoned (no "remove" event — membership
               is unchanged globally).
        dst    NEW spec.  Receives migrated rows via `add_with_ids` in
               ascending id order, so the slot-order == id-order invariant
               holds by construction and the finished store is
               bit-identical to a fresh batch build at the new spec.
        fresh  NEW spec.  Receives every row ADDED while the migration is
               in flight (its id counter starts at src's watermark, above
               every migratable id).  Folded into dst at the end — fresh
               ids all exceed dst ids, so the fold is one ascending append.

    phases: resketch (batches of src rows move to dst) -> fold (fresh
    appends onto dst) -> publish (engine swaps store/spec/params).  The
    cursor is the last migrated id; together with the (old, new) spec pair
    it fully determines progress, and `QueryEngine.save` writes all three
    stores + cursor + specs in ONE atomic checkpoint step — restore resumes
    from the last journaled batch with no acked row lost (the crash-matrix
    test in tests/test_faultinject.py kills at every crash point below and
    asserts exactly that).

Mid-migration serving stays EXACT: the three stores partition the alive
membership, each serves its own exact (value, id)-lex k-best through its
own PartitionSet (repro.index.partition — built by `engine._new_layout`,
so a SHARDED engine's migration tiers are sharded with the same topology;
the query is sketched once per spec), and `partition.topk_across_tiers` —
the same (value, id)-lex rule as the base/delta and cross-shard merges,
with the global running k-th bound threaded across the spec tiers —
combines them.  Radius queries union per-store threshold scans the same
way.
"""

from __future__ import annotations

import logging

import numpy as np

from repro import obs
from repro.core.packing import pow2_bucket
from repro.index.mergeable import MergeIncompatible, check_spec_compatible
from repro.index.store import SketchSpec, SketchStore
from repro.runtime import faultinject

_log = logging.getLogger("repro.index.migrate")

_CP_START = faultinject.declare("migrate.start")
_CP_RESKETCHED = faultinject.declare("migrate.batch.resketched")
_CP_COMMITTED = faultinject.declare("migrate.batch.committed")
_CP_FOLD = faultinject.declare("migrate.fold")
_CP_PUBLISHED = faultinject.declare("migrate.published")


class RawArchive:
    """Host-side id -> raw categorical row (trimmed COO) storage.

    Ingest batches land as whole (k, m) blocks — one list append plus a
    dict update, no per-row work on the serving path; per-row gathers are
    paid only where they are already off the hot path (migration batches,
    checkpoint save/restore).  Dropped ids just leave the locator; dead
    block rows are garbage-collected by the next save/restore cycle
    (`state_tree` serialises live rows only).
    """

    def __init__(self):
        self._blocks: list[tuple[np.ndarray, np.ndarray]] = []
        self._loc: dict[int, tuple[int, int]] = {}  # id -> (block, row)

    def __len__(self) -> int:
        return len(self._loc)

    def __contains__(self, id_) -> bool:
        return int(id_) in self._loc

    def put(self, ids: np.ndarray, indices, values) -> None:
        """Record rows as a padded-COO block (value 0 = padding)."""
        idx = np.array(indices, np.int32, copy=True, ndmin=2)
        val = np.array(values, np.int32, copy=True, ndmin=2)
        if idx.shape != val.shape or idx.shape[0] != len(ids):
            raise ValueError(f"raw block shape mismatch: {len(ids)} ids, "
                             f"indices {idx.shape}, values {val.shape}")
        b = len(self._blocks)
        self._blocks.append((idx, val))
        self._loc.update(zip(np.asarray(ids, np.int64).tolist(),
                             ((b, r) for r in range(idx.shape[0]))))

    def put_dense(self, ids: np.ndarray, x) -> None:
        """Record dense categorical rows by their nonzero entries (psi maps
        value 0 to bit 0, so a dense row and the COO of its nonzeros sketch
        bit-identically under every spec)."""
        x = np.asarray(x)
        nz = x != 0
        m = max(int(nz.sum(axis=1).max(initial=0)), 1)
        # stable argsort floats each row's nonzero columns to the front in
        # ascending-column order; surplus columns carry value 0 (inert)
        cols = np.argsort(~nz, axis=1, kind="stable")[:, :m]
        vals = np.where(np.take_along_axis(nz, cols, axis=1),
                        np.take_along_axis(x, cols, axis=1), 0)
        self.put(ids, cols, vals)

    def drop(self, ids) -> None:
        for id_ in np.atleast_1d(np.asarray(ids, np.int64)).tolist():
            self._loc.pop(id_, None)

    def merge(self, other: "RawArchive") -> "RawArchive":
        """Absorb `other`'s rows and return self (the Mergeable contract,
        repro.index.mergeable): locators union under a block offset, the
        blocks themselves are shared by reference — archives are
        append-only and rows immutable, so sharing is safe and the merge
        is O(rows) host dict work with zero copying.  Inputs must be
        id-disjoint (validated before any mutation); discard `other`
        after success."""
        if other is self:
            raise MergeIncompatible(
                "RawArchive.merge: cannot merge an archive with itself")
        common = self._loc.keys() & other._loc.keys()
        if common:
            raise MergeIncompatible(
                f"RawArchive.merge: merge inputs share {len(common)} "
                f"external id(s) (e.g. id {min(common)}) — inputs must be "
                "id-disjoint independent builds")
        base = len(self._blocks)
        self._blocks.extend(other._blocks)
        for id_, (b, r) in other._loc.items():
            self._loc[id_] = (b + base, r)
        return self

    def missing(self, ids) -> np.ndarray:
        """Subset of `ids` with no archived raw row — the rows a migration
        cannot re-sketch."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        return ids[[int(i) not in self._loc for i in ids]]

    def batch(self, ids) -> tuple[np.ndarray, np.ndarray]:
        """Gather rows into one padded-COO batch (k, mpad) — the layout
        `QueryEngine._sketch` takes.  KeyError on unarchived ids."""
        rows = []
        for id_ in np.atleast_1d(np.asarray(ids, np.int64)).tolist():
            if id_ not in self._loc:
                raise KeyError(f"id {id_} has no raw row in the archive")
            b, r = self._loc[id_]
            idx, val = self._blocks[b]
            live = val[r] != 0
            rows.append((idx[r][live], val[r][live]))
        m = pow2_bucket(max((len(i) for i, _ in rows), default=0), floor=1)
        k = len(rows)
        out_i = np.zeros((k, m), np.int32)
        out_v = np.zeros((k, m), np.int32)
        for r, (i, v) in enumerate(rows):
            out_i[r, : len(i)] = i
            out_v[r, : len(i)] = v
        return out_i, out_v

    # -- snapshot / restore -------------------------------------------------

    def state_tree(self) -> dict[str, np.ndarray]:
        """Live rows as (ids, offsets, idx_flat, val_flat) — also the
        archive's compaction: dead block rows do not survive a cycle."""
        ids = np.sort(np.fromiter(self._loc.keys(), np.int64,
                                  count=len(self._loc)))
        parts_i, parts_v, lens = [], [], []
        for id_ in ids.tolist():
            b, r = self._loc[id_]
            idx, val = self._blocks[b]
            live = val[r] != 0
            parts_i.append(idx[r][live])
            parts_v.append(val[r][live])
            lens.append(int(live.sum()))
        offsets = np.zeros(len(ids) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        cat = (lambda p: np.concatenate(p) if p else np.zeros(0, np.int32))
        return {"ids": ids, "offsets": offsets,
                "idx": cat(parts_i), "val": cat(parts_v)}

    @classmethod
    def from_state(cls, tree: dict[str, np.ndarray]) -> "RawArchive":
        self = cls()
        ids, offsets = tree["ids"], tree["offsets"]
        if len(ids) == 0:
            return self
        m = max(int(np.diff(offsets).max()), 1)
        idx = np.zeros((len(ids), m), np.int32)
        val = np.zeros((len(ids), m), np.int32)
        for r in range(len(ids)):
            lo, hi = int(offsets[r]), int(offsets[r + 1])
            idx[r, : hi - lo] = tree["idx"][lo:hi]
            val[r, : hi - lo] = tree["val"][lo:hi]
        self.put(ids, idx, val)
        return self


class Migration:
    """The in-flight re-sketch state machine (see module docstring).

    Create through `QueryEngine.migrate` — the engine wires the event
    relays, routes mutations, and serves cross-version queries; this class
    owns the batch schedule, the cursor, and the journal.
    """

    def __init__(self, engine, new_spec: SketchSpec, *,
                 batch_rows: int = 1024, drive: str = "lazy",
                 journal_dir: str | None = None, journal_every: int = 1,
                 journal_keep: int = 3):
        if batch_rows < 1:
            raise ValueError(f"batch_rows must be >= 1, got {batch_rows}")
        if drive not in ("lazy", "manual", "eager"):
            raise ValueError(
                f"drive must be 'lazy', 'manual' or 'eager', got {drive!r}")
        if engine.raw is None:
            raise RuntimeError(
                "migration needs the raw archive (keep_raw=True): packed "
                "sketches cannot be re-sketched under a new spec")
        stranded = engine.raw.missing(engine.store.ids())
        if len(stranded):
            raise RuntimeError(
                f"{len(stranded)} alive rows (e.g. id {stranded[0]}) have "
                "no raw archive entry — they were ingested via add_packed "
                "without raw=(indices, values) and cannot be re-sketched")
        self.engine = engine
        self.src: SketchStore = engine.store
        self.old_spec: SketchSpec = engine.spec
        self.new_spec = new_spec
        self.batch_rows = int(batch_rows)
        self.drive = drive
        self.journal_dir = journal_dir
        self.journal_every = int(journal_every)
        self.journal_keep = int(journal_keep)
        self.dst = SketchStore(new_spec.d, spec=new_spec)
        self.fresh = SketchStore(new_spec.d, spec=new_spec)
        # fresh ids start above every migratable id, so migrated appends
        # into dst stay ascending even with adds landing concurrently
        self.fresh._next_id = self.src._next_id
        self.phase = "resketch"
        self.cursor = -1  # last migrated id
        self.rows_migrated = 0
        self.n_batches = 0
        self._journal_step = self._next_journal_step()
        self._dst_tiered = None
        self._fresh_tiered = None
        self._wire_obs()
        _log.info(
            "migration started: spec v%d -> v%d (d %d -> %d), %d rows to "
            "re-sketch in batches of %d (drive=%s)",
            self.old_spec.version, new_spec.version, self.old_spec.d,
            new_spec.d, len(self.src), self.batch_rows, drive)
        if journal_dir is not None and self._journal_step == 0:
            # fresh journal dir: write the pre-migration engine as step 0,
            # so a crash before the first batch boundary still leaves a
            # restorable snapshot (engine._mig is not attached yet — this
            # baseline deliberately carries no migration state)
            engine.save(journal_dir, step=0, keep=journal_keep)
            self._journal_step = 1
        faultinject.crash_point(_CP_START)

    # -- resume (QueryEngine.restore) ---------------------------------------

    @classmethod
    def resume(cls, engine, mmeta: dict, dst: SketchStore,
               fresh: SketchStore) -> "Migration":
        self = cls.__new__(cls)
        self.engine = engine
        self.src = engine.store
        self.old_spec = engine.spec
        self.new_spec = dst.spec
        self.batch_rows = int(mmeta["batch_rows"])
        # a crashed eager run resumes as lazy: it rides the request stream
        # to completion instead of blocking the restore call
        drive = mmeta.get("drive", "lazy")
        self.drive = "lazy" if drive == "eager" else drive
        self.journal_dir = mmeta.get("journal_dir")
        self.journal_every = int(mmeta.get("journal_every", 1))
        self.journal_keep = int(mmeta.get("journal_keep", 3))
        self.dst = dst
        self.fresh = fresh
        # the same compatibility guard merges run (repro.index.mergeable):
        # a journal that pairs tiers from different sketch specs would
        # corrupt every distance the fold produces — refuse it loudly
        check_spec_compatible(fresh.spec, dst.spec,
                              what="Migration.resume (fresh vs dst tier)")
        self.phase = mmeta["phase"]
        self.cursor = int(mmeta["cursor"])
        self.rows_migrated = int(mmeta["rows_migrated"])
        self.n_batches = int(mmeta.get("n_batches", 0))
        self._journal_step = self._next_journal_step()
        self._dst_tiered = None
        self._fresh_tiered = None
        self._wire_obs()
        _log.info(
            "migration resumed: phase=%s cursor=%d, %d rows migrated, "
            "%d remaining", self.phase, self.cursor, self.rows_migrated,
            len(self.src))
        return self

    def _wire_obs(self) -> None:
        """Cache this migration's instruments off the owning engine's
        registry: per-phase wall-time histograms plus the re-sketched row
        counter (dst's store counters stay on the null registry so
        store_rows_added_total keeps meaning "rows ingested")."""
        reg = self.engine.obs
        self._h_resketch = reg.histogram("migration_phase_ms",
                                         phase="resketch")
        self._h_fold = reg.histogram("migration_phase_ms", phase="fold")
        self._c_resketched = reg.counter("migration_rows_resketched_total")

    def meta(self) -> dict:
        """The journal record `QueryEngine.save` embeds next to the store
        trees: cursor + spec pair + store watermarks, atomically."""
        return {
            "phase": self.phase, "cursor": self.cursor,
            "rows_migrated": self.rows_migrated, "n_batches": self.n_batches,
            "batch_rows": self.batch_rows, "drive": self.drive,
            "journal_dir": self.journal_dir,
            "journal_every": self.journal_every,
            "journal_keep": self.journal_keep,
            "new_spec": self.new_spec.meta(),
            "dst_meta": self.dst.state_meta(),
            "fresh_meta": self.fresh.state_meta(),
        }

    # -- progress -----------------------------------------------------------

    @property
    def done(self) -> bool:
        return self.phase == "done"

    def remaining(self) -> int:
        """Alive src rows still waiting to be re-sketched."""
        return len(self.src)

    def step(self, rows: int | None = None) -> int:
        """Migrate up to `rows` (default batch_rows) src rows; returns how
        many moved.  When src drains, folds fresh into dst and publishes —
        after the call that returns with `done`, the engine serves entirely
        at the new spec."""
        if self.done:
            return 0
        rows = self.batch_rows if rows is None else max(1, int(rows))
        take = self.src.ids()[:rows]
        if len(take) == 0:
            self._finish()
            return 0
        with self._h_resketch.time(), obs.span(
                "migrate.batch", rows=len(take), cursor=int(take[-1])):
            idx, val = self.engine.raw.batch(take)
            sk, k = self.engine._sketch((idx, val),
                                        params=self.new_spec.params)
            faultinject.crash_point(_CP_RESKETCHED)
            self.dst.add_with_ids(sk, take, n_valid=k)
            # quiet tombstone: the rows MOVED, membership is unchanged — no
            # "remove" event, but version/removed_count bump so the src
            # layout resyncs its alive masks
            self.src.remove(take, notify=False)
            self.cursor = int(take[-1])
            self.rows_migrated += len(take)
            self.n_batches += 1
            self._c_resketched.inc(len(take))
            faultinject.crash_point(_CP_COMMITTED)
        self._journal()
        if len(self.src) == 0:
            self._finish()
        return len(take)

    def run(self) -> None:
        """Drive to completion (the eager path)."""
        while not self.done:
            self.step()

    def _finish(self) -> None:
        faultinject.crash_point(_CP_FOLD)
        self.phase = "fold"
        _log.info("migration phase: resketch -> fold (%d fresh rows, "
                  "%d migrated over %d batches)",
                  len(self.fresh), self.rows_migrated, self.n_batches)
        with self._h_fold.time(), obs.span("migrate.fold",
                                           fresh_rows=len(self.fresh)):
            # cross-spec guard shared with SketchStore.merge: the fold is
            # a merge of the fresh tier into dst, and it obeys the same
            # compatibility contract (repro.index.mergeable)
            check_spec_compatible(self.fresh.spec, self.dst.spec,
                                  what="migration fold (fresh -> dst)")
            mat, n, ids = self.fresh.gather_alive()
            if n:
                self.dst.add_with_ids(mat, ids, n_valid=n)
            # future ids must clear fresh's watermark even if its newest
            # rows were removed before the fold
            self.dst._next_id = max(self.dst._next_id, self.fresh._next_id)
        self.phase = "done"
        self.engine._publish_migration(self)
        _log.info("migration phase: fold -> done; published spec v%d (d=%d)",
                  self.new_spec.version, self.new_spec.d)
        faultinject.crash_point(_CP_PUBLISHED)
        if self.journal_dir is not None:
            self.engine.save(self.journal_dir, step=self._journal_step,
                             keep=self.journal_keep)

    def _journal(self) -> None:
        if self.journal_dir is None or self.n_batches % self.journal_every:
            return
        self.engine.save(self.journal_dir, step=self._journal_step,
                         keep=self.journal_keep)
        self._journal_step += 1

    def _next_journal_step(self) -> int:
        if self.journal_dir is None:
            return 0
        from repro.checkpoint.checkpointer import Checkpointer

        latest = Checkpointer(self.journal_dir,
                              async_save=False).latest_step()
        return 0 if latest is None else latest + 1

    # -- cross-version serving helpers (used by QueryEngine) ----------------

    def serving_tiers(self) -> list:
        """(layout, spec) per non-empty store — the partition a
        mid-migration query serves over.  src serves through the engine's
        own layout (old spec); dst and fresh through PartitionSets owned
        here, built by the engine's one layout factory (`_new_layout`) so
        they inherit its band rows, merge policy, AND shard topology — a
        sharded engine stays sharded, and exact, mid-migration."""
        tiers = []
        if len(self.src):
            tiers.append((self.engine._layout(), self.old_spec))
        if len(self.dst):
            if self._dst_tiered is None:
                self._dst_tiered = self.engine._new_layout(
                    self.dst, role="migrate-dst")
            tiers.append((self._dst_tiered.sync(self.dst), self.new_spec))
        if len(self.fresh):
            if self._fresh_tiered is None:
                self._fresh_tiered = self.engine._new_layout(
                    self.fresh, role="migrate-fresh")
            tiers.append((self._fresh_tiered.sync(self.fresh),
                          self.new_spec))
        return tiers

    def invalidate_serving_tiers(self) -> None:
        """Drop the dst/fresh layouts (derived state) so the next query
        rebuilds them — called by `QueryEngine.shard` on a topology
        change."""
        self._dst_tiered = None
        self._fresh_tiered = None

    def store_of(self, id_: int) -> SketchStore:
        """Which store currently serves `id_` (KeyError if none)."""
        for store in (self.fresh, self.dst, self.src):
            if store.contains(id_):
                return store
        raise KeyError(f"id {id_} not in store")
