"""Generate the EXPERIMENTS.md dry-run + roofline tables from the JSON
records produced by repro.launch.dryrun.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun \
        --out experiments/roofline.md
"""

from __future__ import annotations

import argparse
import json
import logging

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, load_records

_log = logging.getLogger("repro.launch.report")

V5E_HBM_BYTES = 16 * 1024**3


def _fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def bottleneck_note(rec: dict) -> str:
    """One sentence on what would move the dominant term down."""
    dom = rec["roofline"]["dominant"]
    mode = rec["mode"]
    by_op = rec.get("collectives_by_op", {})
    if dom == "collective":
        biggest = max(by_op.items(), key=lambda kv: kv[1]["traffic"],
                      default=(None, None))[0]
        if mode == "train":
            return (f"dominated by {biggest}: shrink activation gathers "
                    "(SP regather / MoE dispatch) or overlap with compute")
        return (f"dominated by {biggest}: reshard cache/logits to keep the "
                "softmax local")
    if dom == "memory":
        if mode == "decode":
            return "HBM-bound KV/state streaming: int8 cache or wider batch"
        return "HBM-bound: fuse/remat less, raise arithmetic intensity"
    return "compute-bound: at the MXU roofline; only algorithmic flops cuts"


def generate(directory: str) -> str:
    recs = load_records(directory)
    ok = [r for r in recs if r.get("status") == "ok" and not r.get("tag")]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errors = [r for r in recs if r.get("status") == "error"]

    lines = []
    lines.append("### Dry-run matrix\n")
    lines.append(f"{len(ok)} compiled cells, {len(skipped)} skipped "
                 f"(documented inapplicability), {len(errors)} errors.\n")
    lines.append("| arch | shape | mesh | chips | compile | args/dev | "
                 "temps/dev | fits v5e? | #coll |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r.get("memory_analysis", {})
        args_b = mem.get("argument_size_in_bytes")
        temp_b = mem.get("temp_size_in_bytes")
        tot = (args_b or 0) + (temp_b or 0)
        fits = "yes" if tot and tot < V5E_HBM_BYTES else (
            "NO (see notes)" if tot else "-")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']:.0f}s | {_fmt_bytes(args_b)} | "
            f"{_fmt_bytes(temp_b)} | {fits} | {r['collective_count']} |")
    if skipped:
        lines.append("\nSkipped cells:\n")
        for r in sorted(skipped, key=lambda r: (r["arch"], r["shape"])):
            lines.append(f"* `{r['arch']} x {r['shape']} x {r['mesh']}` — "
                         f"{r['reason']}")

    lines.append("\n### Roofline (single-pod 16x16 = 256 chips; v5e: "
                 f"{PEAK_FLOPS/1e12:.0f} TF/s bf16, {HBM_BW/1e9:.0f} GB/s "
                 f"HBM, {ICI_BW/1e9:.0f} GB/s/link ICI)\n")
    lines.append("| arch | shape | compute | memory | collective | dominant |"
                 " MODEL_FLOPS | useful ratio | roofline frac | note |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "pod":
            continue
        roof = r["roofline"]
        total = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        frac = roof["compute_s"] / total if total else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(roof['compute_s'])} | "
            f"{_fmt_s(roof['memory_s'])} | {_fmt_s(roof['collective_s'])} | "
            f"{roof['dominant']} | {roof['model_flops']:.3g} | "
            f"{roof['useful_ratio']:.2f} | {frac:.2f} | "
            f"{bottleneck_note(r)} |")

    lines.append("\n`useful ratio` = MODEL_FLOPS / HLO_FLOPs_global "
                 "(6ND train, 2ND decode/prefill); `roofline frac` = "
                 "compute_term / max(term) — the fraction of the modelled "
                 "step time spent at the FLOP roofline.\n")
    return "\n".join(lines)


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()
    text = generate(args.dir)
    with open(args.out, "w") as f:
        f.write(text)
    _log.info("wrote %s", args.out)


if __name__ == "__main__":
    main()
