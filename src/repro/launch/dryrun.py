import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

MUST be executed as its own process (python -m repro.launch.dryrun ...): the
two lines above run before any jax import so the 512 placeholder host
devices exist when jax initialises.  Smoke tests and benches never import
this module, so they keep seeing 1 device.

Per cell this driver:
  1. builds abstract params / optimizer state / batch or caches (ShapeDtype
     structs only — no allocation),
  2. jits the mode's step function with NamedShardings from the rules in
     repro.distributed.sharding,
  3. lowers + compiles under the production mesh,
  4. records memory_analysis(), cost_analysis() and the collective schedule
     parsed from the partitioned HLO into experiments/dryrun/<cell>.json
     for the roofline report (launch/roofline.py, benchmarks/).
"""

import argparse
import dataclasses
import json
import logging
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ParallelConfig, Precision, SHAPES,
                                TrainConfig)
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.serve.engine import make_serve_step
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

_log = logging.getLogger("repro.launch.dryrun")

# ---------------------------------------------------------------------------
# per-cell presets (baseline parallel/memory knobs; hillclimbing edits these
# via --set overrides and records deltas in EXPERIMENTS.md section Perf)
# ---------------------------------------------------------------------------

DEFAULT_PCFG = dict(remat="block", sequence_parallel=True, zero3=True,
                    microbatches=1)

PRESETS: dict[tuple[str, str], dict] = {
    # 671B: bf16 moments (fit analysis in EXPERIMENTS.md), dispatch groups
    ("deepseek_v3_671b", "train_4k"): {"moment_dtype": "bfloat16"},
    ("dbrx_132b", "train_4k"): {"moment_dtype": "bfloat16"},
}


def _pcfg_for(arch: str, shape_name: str, overrides: dict) -> ParallelConfig:
    kw = dict(DEFAULT_PCFG)
    preset = PRESETS.get((arch, shape_name), {})
    kw.update({k: v for k, v in preset.items() if k in ParallelConfig.__dataclass_fields__})
    kw.update({k: v for k, v in overrides.items() if k in ParallelConfig.__dataclass_fields__})
    return ParallelConfig(**kw)


def _cfg_for(arch: str, shape_name: str, overrides: dict):
    cfg = get_config(arch)
    preset = PRESETS.get((arch, shape_name), {})
    merged = {**preset, **overrides}
    mdt = merged.get("moment_dtype")
    if mdt:
        cfg = dataclasses.replace(
            cfg, precision=dataclasses.replace(cfg.precision, moment_dtype=mdt))
    if cfg.moe is not None:
        moe_kw = {k: v for k, v in merged.items()
                  if k in ("capacity_factor", "dispatch_dtype", "group_size",
                           "top_k")}
        if moe_kw:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **moe_kw))
    return cfg


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg, shape, mesh, pcfg: ParallelConfig, moe_2d: bool = False):
    """Returns (lowered, aux_info)."""
    tcfg = TrainConfig()
    shd.set_moe_2d(moe_2d)
    with shd.set_mesh(mesh):
        params_abs = sp.abstract_params(cfg)
        pspecs = shd.param_specs(params_abs)
        psh = _named(mesh, pspecs)
        if shape.mode == "train":
            opt_abs = jax.eval_shape(
                lambda p: opt.init_state(p, cfg.precision.moment_dtype),
                params_abs)
            ospecs = shd.param_specs(opt_abs)
            osh = _named(mesh, ospecs)
            batch_abs = sp.batch_specs(cfg, shape)
            bsh = {k: shd.batch_sharding_for(mesh, v.shape)
                   for k, v in batch_abs.items()}
            step = make_train_step(cfg, pcfg, tcfg)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.mode == "prefill":
            batch_abs = sp.batch_specs(cfg, shape)
            bsh = {k: shd.batch_sharding_for(mesh, v.shape)
                   for k, v in batch_abs.items()}

            def fwd(params, batch):
                logits, aux = T.forward(cfg, params, batch, pcfg)
                return logits

            jitted = jax.jit(fwd, in_shardings=(psh, bsh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            dspecs = sp.decode_specs(cfg, shape, pcfg.kv_cache_dtype)
            csh = _named(mesh, shd.cache_specs(dspecs["caches"],
                                               kv_heads=cfg.n_kv_heads))
            tok_sh = shd.batch_sharding_for(mesh, dspecs["tokens"].shape)
            pos_sh = NamedSharding(mesh, P())
            serve = make_serve_step(cfg, pcfg)
            if cfg.kind == "encdec":
                enc_sh = shd.batch_sharding_for(mesh, dspecs["enc_out"].shape)
                jitted = jax.jit(
                    serve, in_shardings=(psh, csh, tok_sh, pos_sh, enc_sh),
                    donate_argnums=(1,))
                lowered = jitted.lower(params_abs, dspecs["caches"],
                                       dspecs["tokens"], dspecs["pos"],
                                       dspecs["enc_out"])
            else:
                jitted = jax.jit(serve,
                                 in_shardings=(psh, csh, tok_sh, pos_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_abs, dspecs["caches"],
                                       dspecs["tokens"], dspecs["pos"])
    return lowered


def _depth_cfg(cfg, k: int):
    """Reduced-depth twin: first_k_dense + k repeats of the layer pattern
    (encoder reduced to k layers too — whisper scales both together)."""
    period = len(cfg.layer_pattern)
    kw = dict(n_layers=cfg.first_k_dense + k * period)
    if cfg.kind == "encdec":
        kw["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def _rest_repeats(cfg) -> int:
    return T.build_stages(cfg)[-1].n_repeat


def _cost_metrics(cfg, shape, mesh, pcfg, chips, moe_2d=False) -> dict:
    """flops/bytes/collectives via unrolled reduced-depth extrapolation.

    XLA's HloCostAnalysis counts while-loop bodies once (trip counts are
    ignored), so scanned stacks must be measured unrolled.  We lower k=1 and
    k=2 pattern repeats fully unrolled and extrapolate linearly to the full
    repeat count — exact because stage cost is linear in repeats."""
    samples = {}
    for k in (1, 2):
        cfg_k = _depth_cfg(cfg, k)
        pcfg_k = dataclasses.replace(pcfg, unroll_scan=True)
        lowered = lower_cell(cfg_k, shape, mesh, pcfg_k, moe_2d=moe_2d)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = rl.parse_collectives(hlo, default_group=chips)
        bytes_raw = float(cost.get("bytes accessed", 0.0))
        convert_b = rl.parse_convert_bytes(hlo)
        samples[k] = {
            "flops": float(cost.get("flops", 0.0)),
            # TPU-representative HBM bytes: CPU-backend f32<->bf16 convert
            # materialisation removed (see roofline.parse_convert_bytes)
            "bytes": max(bytes_raw - convert_b, 0.0),
            "bytes_raw": bytes_raw,
            "convert_bytes": convert_b,
            "coll_traffic": coll.traffic_bytes,
            "coll_raw": coll.raw_bytes,
            "coll_count": coll.count,
            "by_op": coll.by_op,
        }
    r_full = _rest_repeats(cfg)

    def extrap(key):
        m1, m2 = samples[1][key], samples[2][key]
        return m1 + max(m2 - m1, 0.0) * (r_full - 1)

    by_op = {}
    for op in set(samples[1]["by_op"]) | set(samples[2]["by_op"]):
        d1 = samples[1]["by_op"].get(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        d2 = samples[2]["by_op"].get(op, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        by_op[op] = {
            k2: d1[k2] + max(d2[k2] - d1[k2], 0) * (r_full - 1)
            for k2 in ("count", "bytes", "traffic")
        }
    return {
        "flops_per_device": extrap("flops"),
        "bytes_per_device": extrap("bytes"),
        "bytes_per_device_raw": extrap("bytes_raw"),
        "collective_traffic_bytes": extrap("coll_traffic"),
        "collective_raw_bytes": extrap("coll_raw"),
        "collective_count": int(extrap("coll_count")),
        "collectives_by_op": by_op,
        "cost_samples": {str(k): {kk: vv for kk, vv in v.items() if kk != "by_op"}
                         for k, v in samples.items()},
        "rest_repeats": r_full,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             overrides: dict, force: bool = False,
             tag: str = "") -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    shape = SHAPES[shape_name]
    cfg = _cfg_for(arch, shape_name, overrides)
    ok, reason = sp.shape_applicable(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "mode": shape.mode, "overrides": overrides,
    }
    os.makedirs(out_dir, exist_ok=True)
    if not ok:
        record.update({"status": "skipped", "reason": reason})
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    pcfg = _pcfg_for(arch, shape_name, overrides)
    moe_2d = bool(overrides.get("moe_2d", False))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        # 1. full-config compile: proves the cell lowers/partitions, and
        #    provides the per-device memory analysis.
        t0 = time.perf_counter()
        lowered = lower_cell(cfg, shape, mesh, pcfg, moe_2d=moe_2d)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        mem_rec = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)

        # 2. cost pass: unrolled reduced-depth extrapolation (see helper).
        cost_rec = _cost_metrics(cfg, shape, mesh, pcfg, chips, moe_2d=moe_2d)

        n_active = rl.active_params(cfg)
        mf = rl.model_flops(cfg, shape, n_active)

        record.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": mem_rec,
            "active_params": n_active,
            "model_flops": mf,
            "pcfg": {k: getattr(pcfg, k) for k in
                     ("microbatches", "remat", "sequence_parallel", "zero3",
                      "kv_cache_dtype")},
            **cost_rec,
        })
        roof = rl.analyze(record, chips)
        record["roofline"] = roof.as_dict()
        _log.info("[ok] %s: compile=%.1fs flops/dev=%.3g bytes/dev=%.3g "
                  "coll/dev=%.3gB dominant=%s", cell_id, t_compile,
                  record["flops_per_device"], record["bytes_per_device"],
                  record["collective_traffic_bytes"], roof.dominant)
    except Exception as e:  # record failures — they are bugs to fix
        record.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]})
        _log.error("[ERR] %s: %r", cell_id, e)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--set", action="append", default=[],
                    help="override knob, e.g. --set microbatches=8 "
                         "--set kv_cache_dtype=int8")
    args = ap.parse_args()

    overrides: dict = {}
    for item in args.set:
        k, v = item.split("=", 1)
        if v.isdigit():
            v = int(v)
        elif v in ("true", "false", "True", "False"):
            v = v in ("true", "True")
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = ARCH_IDS if args.arch == "all" else [args.arch.replace("-", "_")]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                rec = run_cell(arch, shape_name, multi_pod, args.out,
                               overrides, force=args.force, tag=args.tag)
                st = rec.get("status")
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    _log.info("done: %d ok, %d skipped, %d errors", n_ok, n_skip, n_err)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
