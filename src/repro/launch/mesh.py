"""Production mesh construction (spec'd shapes: 16x16 single pod, 2x16x16
multi-pod).  A FUNCTION, not a module constant — importing this module never
touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / degraded (elastic) configurations."""
    return jax.make_mesh(shape, axes)


def make_host_test_mesh(data: int = 2, model: int = 2):
    """Small mesh over forced host devices for CI-grade distribution tests."""
    n = jax.device_count()
    if n < data * model:
        raise RuntimeError(
            f"need {data * model} devices, have {n}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> before "
            "importing jax")
    return jax.make_mesh((data, model), ("data", "model"))
