"""Production training launcher: mesh + sharded state + trainer loop.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --mesh 1x1 --steps 20 --batch 8 --seq 256 --reduced

On real hardware the mesh comes from make_production_mesh(); on this
container any mesh shape that matches jax.device_count() works (1x1 by
default).  The launcher wires: config -> sharded init -> (optional EF-sign
cross-pod grad compression) -> jit(train_step, in_shardings=...) ->
Trainer loop with checkpoints/heartbeats.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import tempfile

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ParallelConfig, TrainConfig,
                                reduced_for_smoke)
from repro.configs.registry import get_config
from repro.data.pipeline import BatchPipeline, PipelineConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

_log = logging.getLogger("repro.launch.train")


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU demo)")
    ap.add_argument("--dedup", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    data_p, model_p = (int(v) for v in args.mesh.split("x"))
    mesh = make_mesh((data_p, model_p), ("data", "model"))
    pcfg = ParallelConfig(remat="block", sequence_parallel=model_p > 1,
                          zero3=data_p > 1)
    tcfg = TrainConfig(total_steps=args.steps)

    with shd.set_mesh(mesh):
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        psh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), shd.param_specs(params),
            is_leaf=lambda x: isinstance(x, P))
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt_state = opt.init_state(params, cfg.precision.moment_dtype)
        osh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), shd.param_specs(opt_state),
            is_leaf=lambda x: isinstance(x, P))
        opt_state = jax.tree_util.tree_map(jax.device_put, opt_state, osh)

        step = jax.jit(make_train_step(cfg, pcfg, tcfg),
                       donate_argnums=(0, 1))
        pipe = BatchPipeline(PipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            global_batch=args.batch, dedup=args.dedup))
        _log.info("mesh=%s params=%.1fM arch=%s", mesh.shape,
                  T.count_params(params) / 1e6, cfg.name)
        for i in range(args.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in next(pipe).items()}
            params, opt_state, metrics = step(params, opt_state, batch)
            if (i + 1) % 5 == 0 or i == 0:
                _log.info("step %4d loss=%.4f gnorm=%.3f", i + 1,
                          float(metrics["loss"]),
                          float(metrics["grad_norm"]))
        pipe.close()
        _log.info("done")


if __name__ == "__main__":
    main()
