"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

No device allocation — only shapes/dtypes for jit(...).lower().  Covers
train (tokens+labels), prefill (tokens) and decode (token + caches) modes,
plus the stub modality frontends (vision patches / audio frames) the [vlm]
and [audio] entries require.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T

BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input ShapeDtypeStructs for train/prefill batches."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.kind == "encdec":
        # decoder sees s tokens; encoder sees the stub frames
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), BF16)
    elif cfg.frontend is not None:
        s_text = s - cfg.n_frontend_tokens
        assert s_text > 0
        specs["tokens"] = _sds((b, s_text), jnp.int32)
        specs["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), BF16)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    if shape.mode == "train":
        specs["labels"] = _sds(specs["tokens"].shape, jnp.int32)
    return specs


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: T.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    kv_dtype: str = "bfloat16"):
    return jax.eval_shape(
        lambda: T.init_caches(cfg, batch, max_len, kv_dtype))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 kv_dtype: str = "bfloat16") -> dict:
    """Inputs for serve_step: one new token against a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": _sds((b, 1), jnp.int32),
        "caches": abstract_caches(cfg, b, s, kv_dtype),
        "pos": _sds((), jnp.int32),
    }
    if cfg.kind == "encdec":
        specs["enc_out"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), BF16)
    return specs


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 524k-token decode is the "
                       "quadratic regime long_500k excludes (DESIGN.md 6)")
    if cfg.frontend is not None and cfg.kind != "encdec" \
            and shape.seq_len <= cfg.n_frontend_tokens:
        return False, "sequence shorter than frontend patch count"
    return True, ""
