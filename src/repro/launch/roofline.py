"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all in seconds, derived from the
PER-DEVICE partitioned module (so dividing global quantities by chip count
is already done by GSPMD):

  compute    = HLO_flops_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum_ops traffic_bytes_per_device(op) / ICI_BW

Collective traffic accounting (ring algorithms, per-device bytes on the
busiest link):
  all-gather       : result_bytes * (k-1)/k          (receives the k-1 shards)
  reduce-scatter   : result_bytes * (k-1)            (streams k-1 partials)
  all-reduce       : 2 * result_bytes * (k-1)/k      (RS + AG phases)
  all-to-all       : result_bytes * (k-1)/k
  collective-permute: result_bytes

k = collective group size parsed from replica_groups.  MODEL_FLOPS uses
6*N*D for training (fwd+bwd) and 2*N*D per generated/scored token for
inference, N = active parameter count.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (conservative single-link accounting)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]' -> bytes; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


_CONVERT_RE = re.compile(
    r"^\s*%?[\w.\-]+ = (f32|bf16)\[([\d,]*)\][^=]* convert\(")


def parse_convert_bytes(hlo_text: str) -> float:
    """HBM bytes attributable to f32<->bf16 convert ops.

    The CPU backend materialises f32 converts around bf16 dots (no native
    bf16 ALU); a TPU MXU consumes bf16 directly, so these ops' traffic is a
    compile-host artifact.  The memory roofline term subtracts this estimate
    (operand+result bytes: f32 result from bf16 operand = 1.5x result bytes;
    bf16 result from f32 operand = 3x result bytes).  Both raw and corrected
    numbers are recorded in the dry-run JSON.
    """
    total = 0.0
    in_fused = False
    for line in hlo_text.splitlines():
        # track computation blocks: converts inside fusion bodies are not
        # materialised (cost analysis doesn't count them either) — only
        # top-level/while-body converts hit HBM.
        stripped = line.strip()
        if stripped.endswith("{") and "(" in stripped:
            name = stripped.split("(", 1)[0].strip().lstrip("%")
            in_fused = name.startswith(("fused_", "wide.fused",
                                        "region_fused")) or "fused_computation" in name
            continue
        if in_fused:
            continue
        m = _CONVERT_RE.match(line)
        if not m:
            continue
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if dt == "f32":
            total += n * 4 * 1.5
        else:
            total += n * 2 * 3.0
    return total


@dataclass
class CollectiveStats:
    by_op: dict
    traffic_bytes: float  # per-device busiest-link bytes
    raw_bytes: float  # sum of result bytes (no ring factors)
    count: int


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    by_op: dict[str, dict] = {}
    traffic = 0.0
    raw = 0.0
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-producing collective ops: "%name = SHAPE op-name(...)"
        m = re.match(r"%?[\w.\-]+ = ((?:\([^)]*\)|[\w\[\],{}\/ ]+?)) ([a-z\-]+)\(",
                     stripped)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "")
        if base not in _COLL_OPS:
            continue
        if op.endswith("-done"):
            continue
        nbytes = _shape_bytes(m.group(1))
        k = _group_size(stripped, default_group)
        if base == "all-gather":
            t = nbytes * (k - 1) / max(k, 1)
        elif base == "reduce-scatter":
            t = nbytes * (k - 1)
        elif base == "all-reduce":
            t = 2 * nbytes * (k - 1) / max(k, 1)
        elif base == "all-to-all":
            t = nbytes * (k - 1) / max(k, 1)
        else:  # collective-permute
            t = nbytes
        d = by_op.setdefault(base, {"count": 0, "bytes": 0.0, "traffic": 0.0})
        d["count"] += 1
        d["bytes"] += nbytes
        d["traffic"] += t
        traffic += t
        raw += nbytes
        count += 1
    return CollectiveStats(by_op=by_op, traffic_bytes=traffic, raw_bytes=raw,
                           count=count)


def active_params(cfg) -> int:
    """Active parameter count per token (MoE: top_k + shared experts only)."""
    import jax
    import numpy as np

    from repro.models import transformer as T

    shapes = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    total = 0
    moe_total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "/moe/w_" in pstr or pstr.endswith("moe/router"):
            moe_total += n
    if cfg.moe is None or moe_total == 0:
        return total
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return int(total - moe_total * (1 - frac))


def model_flops(cfg, shape, n_active: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n_active * tokens


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float

    def as_dict(self):
        return self.__dict__.copy()


def analyze(record: dict, chips: int) -> Roofline:
    """record: one dry-run JSON (per-device flops/bytes + collective stats)."""
    flops_dev = record["flops_per_device"]
    bytes_dev = record["bytes_per_device"]
    coll_dev = record["collective_traffic_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_global = flops_dev * chips
    useful = record.get("model_flops", 0.0) / hlo_global if hlo_global else 0.0
    return Roofline(compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, dominant=dominant,
                    model_flops=record.get("model_flops", 0.0),
                    hlo_flops_global=hlo_global, useful_ratio=useful)


def load_records(directory: str) -> list[dict]:
    import glob
    import os

    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out
