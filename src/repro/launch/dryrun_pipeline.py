import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN workload on the production mesh: a blocked
all-pairs Cham pass over a sketched corpus (the heatmap / dedup / clustering
hot loop), data-parallel over 256 chips.

This is the third hillclimb cell (most representative of the paper's
technique).  Variants lowered and compared in EXPERIMENTS.md section Perf:

  v0_unpacked : distances on UNPACKED {0,1} int32 bit arrays (the naive port
                of the paper's numpy reference: u != v sums).
  v1_packed   : packed int32 + SWAR popcount (the Cabin/Cham production
                representation; 32x smaller operands).
  v2_matmul   : packed popcount stats + Cham, with the sketch build fused as
                the one-hot MXU matmul formulation (kernels/cabin_build) so
                the whole step is one pass over the categorical input.

Workload: N = 65536 documents (padded-COO, max 1024 nnz over a 131072-dim
vocab), sketch_dim d = 4096, all-pairs in 8192-row blocks; each device owns
a row block and gathers the column blocks (sketches are tiny — that is the
paper's point).
"""

import argparse
import dataclasses
import json
import logging
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hashing
from repro.core.cabin import CabinParams, binem
from repro.core.cham import binhamming_from_stats, cham_matrix
from repro.core.packing import pack_bits, popcount32, unpack_bits
from repro.launch import roofline as rl
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh

_log = logging.getLogger("repro.launch.dryrun_pipeline")

N_DOCS = 65536
MAX_NNZ = 1024
VOCAB = 131072
D_SKETCH = 4096


def _sketch_bits_sparse(params: CabinParams, indices, values):
    """Unpacked {0,1} (N, d) sketch — the v0 representation."""
    bits = hashing.psi_bits(indices.astype(jnp.uint32), values,
                            params.psi_seed)
    buckets = hashing.pi_buckets(indices.astype(jnp.uint32),
                                 params.sketch_dim, params.pi_seed)
    bits = jnp.where(values != 0, bits, 0)
    out = jnp.zeros((indices.shape[0], params.sketch_dim), jnp.int32)
    return jax.vmap(lambda o, b, v: o.at[b].max(v, mode="drop"))(
        out, buckets, bits)


def make_step(variant: str, params: CabinParams):
    d = params.sketch_dim

    def step(indices, values):
        if variant == "v0_unpacked":
            sk = _sketch_bits_sparse(params, indices, values)  # (N, d) int32
            w = jnp.sum(sk, axis=-1)
            # blocked all-pairs on unpacked bits
            blocks = sk.reshape(-1, 8192, d)
            wb = w.reshape(-1, 8192)

            def pair(b_i, w_i):
                inner = jnp.einsum("nd,md->nm", b_i.astype(jnp.float32),
                                   sk.astype(jnp.float32))
                est = 2.0 * binhamming_from_stats(
                    w_i[:, None], w[None, :], inner, d)
                return jnp.sum(est < 32.0, axis=-1)  # dup candidate counts

            counts = jax.lax.map(lambda args: pair(*args), (blocks, wb))
            return counts.reshape(-1)
        # packed variants
        sk_bits = _sketch_bits_sparse(params, indices, values)
        packed = pack_bits(sk_bits)  # (N, d/32) int32
        if variant == "v2_matmul":
            # fused representation: same packed layout; difference vs v1 is
            # the sketch build path on dense inputs (kernels/cabin_build);
            # for the padded-COO corpus the scatter build is shared, so v2
            # additionally fuses weights into the pair pass.
            pass
        w = jnp.sum(popcount32(packed), axis=-1)
        blocks = packed.reshape(-1, 8192, packed.shape[-1])
        wb = w.reshape(-1, 8192)

        def pair(b_i, w_i):
            inner = jnp.sum(
                popcount32(b_i[:, None, :] & packed[None, :, :]), axis=-1)
            est = 2.0 * binhamming_from_stats(
                w_i[:, None], w[None, :], inner, d)
            return jnp.sum(est < 32.0, axis=-1)

        counts = jax.lax.map(lambda args: pair(*args), (blocks, wb))
        return counts.reshape(-1)

    return step


def run_variant(variant: str, multi_pod: bool, out_dir: str,
                force: bool = False) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    cell_id = f"cabin_pipeline__heatmap_64k__{mesh_name}__{variant}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    params = CabinParams.create(VOCAB, D_SKETCH, seed=0)
    record = {"arch": "cabin_pipeline", "shape": "heatmap_64k",
              "mesh": mesh_name, "tag": variant, "mode": "pipeline",
              "overrides": {}}
    try:
        with shd.set_mesh(mesh):
            idx = jax.ShapeDtypeStruct((N_DOCS, MAX_NNZ), jnp.int32)
            val = jax.ShapeDtypeStruct((N_DOCS, MAX_NNZ), jnp.int32)
            dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            in_sh = NamedSharding(mesh, P(dp, None))
            step = make_step(variant, params)
            t0 = time.perf_counter()
            lowered = jax.jit(step, in_shardings=(in_sh, in_sh)).lower(idx, val)
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = rl.parse_collectives(hlo, default_group=chips)
        mem = compiled.memory_analysis()
        bytes_raw = float(cost.get("bytes accessed", 0.0))
        record.update({
            "status": "ok", "chips": chips,
            "compile_s": round(t_compile, 2),
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": max(
                bytes_raw - rl.parse_convert_bytes(hlo), 0.0),
            "bytes_per_device_raw": bytes_raw,
            "collective_traffic_bytes": coll.traffic_bytes,
            "collective_count": coll.count,
            "collectives_by_op": coll.by_op,
            "memory_analysis": {
                a: int(getattr(mem, a)) for a in
                ("argument_size_in_bytes", "temp_size_in_bytes",
                 "output_size_in_bytes") if getattr(mem, a, None) is not None},
            "model_flops": 0.0,
            "active_params": 0,
        })
        roof = rl.analyze(record, chips)
        record["roofline"] = roof.as_dict()
        _log.info("[ok] %s: compile=%.1fs flops/dev=%.3g bytes/dev=%.3g "
                  "dominant=%s", cell_id, t_compile,
                  record["flops_per_device"], record["bytes_per_device"],
                  roof.dominant)
    except Exception as e:
        import traceback

        record.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-3000:]})
        _log.error("[ERR] %s: %r", cell_id, e)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all",
                    choices=["all", "v0_unpacked", "v1_packed", "v2_matmul"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun_pipeline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    variants = (["v0_unpacked", "v1_packed", "v2_matmul"]
                if args.variant == "all" else [args.variant])
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        for v in variants:
            run_variant(v, mp, args.out, force=args.force)


if __name__ == "__main__":
    main()
