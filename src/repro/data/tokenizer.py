"""Byte-level tokenizer for the LM examples (no external vocab files).

Reserved ids: 0 = pad, 1 = bos, 2 = eos; bytes map to 3..258.  Any vocab size
>= 259 works (the assigned architectures all have far larger vocabs; the
unused tail of the embedding matrix is exercised by the hashed CabinEmbed
path and by synthetic-token training).
"""

from __future__ import annotations

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
_OFFSET = 3
VOCAB_MIN = 256 + _OFFSET


def encode(text: str, add_bos: bool = True, add_eos: bool = True) -> np.ndarray:
    ids = [b + _OFFSET for b in text.encode("utf-8")]
    if add_bos:
        ids = [BOS_ID] + ids
    if add_eos:
        ids = ids + [EOS_ID]
    return np.asarray(ids, dtype=np.int32)


def decode(ids) -> str:
    data = bytes(int(i) - _OFFSET for i in ids
                 if _OFFSET <= int(i) < _OFFSET + 256)
    return data.decode("utf-8", errors="replace")


def pad_or_trim(ids: np.ndarray, length: int) -> np.ndarray:
    out = np.full(length, PAD_ID, dtype=np.int32)
    take = min(length, len(ids))
    out[:take] = ids[:take]
    return out
