"""Synthetic categorical datasets matched to the paper's Table 1.

The paper's corpora (UCI BoW, 10x Brain Cell) are not redistributable in this
offline container, so benchmarks draw from generators that match each
dataset's published statistics — dimension, #categories, sparsity/density,
#points — with Zipfian feature popularity (word frequencies are Zipf-like,
which is the property that matters for hash-collision behaviour).

Rows are produced in both layouts used by the core library:
  * dense (N, n) int32 (small n), and
  * padded-COO (indices, values) (large n, e.g. the 1.3M-dim Brain-Cell twin).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_dims: int
    n_categories: int
    density: int  # mean # non-missing features per row (paper Table 1)
    n_points: int


# Paper Table 1, verbatim statistics.
TABLE1 = {
    "kos": DatasetSpec("kos", 6906, 42, 457, 3430),
    "nips": DatasetSpec("nips", 12419, 132, 914, 1500),
    "enron": DatasetSpec("enron", 28102, 150, 2021, 39861),
    "nytimes": DatasetSpec("nytimes", 102660, 114, 871, 10000),
    "pubmed": DatasetSpec("pubmed", 141043, 47, 199, 10000),
    "braincell": DatasetSpec("braincell", 1306127, 2036, 1051, 2000),
}


def _zipf_weights(n: int, a: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** a
    return w / w.sum()


def sample_sparse(
    spec: DatasetSpec,
    n_rows: int,
    seed: int = 0,
    cluster_centers: int = 0,
    max_nnz: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded-COO rows: (indices (N, m), values (N, m), labels (N,)).

    With cluster_centers > 0, rows are noisy copies of that many prototype
    rows (for clustering benchmarks); labels give the prototype id, else -1.
    """
    rng = np.random.default_rng(seed)
    m = max_nnz or int(spec.density * 1.5)
    weights = _zipf_weights(spec.n_dims)
    indices = np.zeros((n_rows, m), dtype=np.int32)
    values = np.zeros((n_rows, m), dtype=np.int32)
    labels = np.full(n_rows, -1, dtype=np.int64)

    protos = []
    if cluster_centers:
        for _ in range(cluster_centers):
            nnz = spec.density
            idx = rng.choice(spec.n_dims, size=nnz, replace=False, p=weights)
            val = rng.integers(1, spec.n_categories + 1, size=nnz)
            protos.append((idx, val))

    for i in range(n_rows):
        # Poisson-ish density spread around the Table-1 mean.
        nnz = int(np.clip(rng.normal(spec.density, spec.density * 0.15), 1, m))
        if protos:
            ci = int(rng.integers(len(protos)))
            labels[i] = ci
            idx, val = protos[ci]
            take = min(nnz, len(idx))
            keep = rng.permutation(len(idx))[:take]
            idx, val = idx[keep].copy(), val[keep].copy()
            # category noise: resample 10% of values
            flip = rng.random(take) < 0.10
            val[flip] = rng.integers(1, spec.n_categories + 1, size=int(flip.sum()))
        else:
            idx = rng.choice(spec.n_dims, size=nnz, replace=False, p=weights)
            val = rng.integers(1, spec.n_categories + 1, size=nnz)
        indices[i, : len(idx)] = idx
        values[i, : len(val)] = val
    return indices, values, labels


def sample_dense(
    spec: DatasetSpec, n_rows: int, seed: int = 0, cluster_centers: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Dense rows (N, n_dims) int32 + labels; only for moderate n_dims."""
    indices, values, labels = sample_sparse(spec, n_rows, seed, cluster_centers)
    x = np.zeros((n_rows, spec.n_dims), dtype=np.int32)
    rows = np.repeat(np.arange(n_rows), indices.shape[1])
    x[rows, indices.ravel()] = values.ravel()
    x[:, 0] = np.where(values[:, 0] == 0, 0, x[:, 0])  # index-0 padding guard
    return x, labels


def scaled_spec(spec: DatasetSpec, scale: float) -> DatasetSpec:
    """Shrink a Table-1 spec for CPU-budget benchmarks, keeping sparsity."""
    return DatasetSpec(
        name=f"{spec.name}@{scale:g}",
        n_dims=max(64, int(spec.n_dims * scale)),
        n_categories=spec.n_categories,
        density=max(8, int(spec.density * scale)),
        n_points=spec.n_points,
    )
