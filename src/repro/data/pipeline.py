"""LM training data pipeline: document stream -> dedup -> packed batches.

Production layout: each data-parallel host materialises only its slice of the
global batch (`host_slice`), documents flow through an optional Cabin/Cham
near-duplicate filter (repro.data.dedup) before packing, and batches are
yielded as host numpy arrays ready for jax.device_put under the data
sharding.  Double-buffered prefetch via a background thread.

The synthetic corpus is a seeded Markov-ish byte source — deterministic
across restarts (checkpoint/resume replays the stream position, so training
is bitwise reproducible after failover).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterable, Iterator

import numpy as np

from repro.data import dedup as dedup_mod
from repro.data import tokenizer


@dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dedup: bool = False
    dedup_sketch_dim: int = 1024
    dedup_threshold: float = 8.0
    dedup_window: int = 512  # docs per dedup block
    n_hosts: int = 1
    host_index: int = 0
    prefetch: int = 2


def synthetic_documents(
    vocab_size: int, seed: int, mean_len: int = 512,
    dup_fraction: float = 0.0,
) -> Iterator[np.ndarray]:
    """Infinite stream of synthetic token documents (Zipfian unigram with
    per-doc topic bias; optionally emits near-duplicates for dedup tests)."""
    rng = np.random.default_rng(seed)
    base = 1.0 / np.arange(1, vocab_size - 2) ** 1.05
    last: np.ndarray | None = None
    while True:
        if last is not None and rng.random() < dup_fraction:
            doc = last.copy()
            n_edit = max(1, int(0.02 * len(doc)))
            pos = rng.integers(0, len(doc), size=n_edit)
            doc[pos] = rng.integers(3, vocab_size, size=n_edit)
            yield doc
            continue
        topic = rng.integers(0, 16)
        w = base.copy()
        lo = (topic * 977) % (vocab_size - 3)
        w[lo : lo + 500] *= 8.0
        w /= w.sum()
        n = max(8, int(rng.normal(mean_len, mean_len * 0.25)))
        body = rng.choice(vocab_size - 3, size=n, p=w).astype(np.int32) + 3
        doc = np.concatenate([[tokenizer.BOS_ID], body, [tokenizer.EOS_ID]]
                             ).astype(np.int32)
        last = doc
        yield doc


def document_windows(
    docs: Iterable[np.ndarray], window: int
) -> Iterator[list[np.ndarray]]:
    """Group a document stream into fixed-size windows.

    The unit of work for every streaming sketch consumer: the dedup stage
    below sketches one window at a time, and repro.index.ingest feeds
    windows into a live QueryEngine.  A finite stream yields its last,
    possibly short, window; an infinite stream yields forever.  Accepts any
    iterable; a re-iterable (list) is consumed once, like an iterator.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    docs = iter(docs)
    while True:
        batch: list[np.ndarray] = []
        for doc in docs:
            batch.append(doc)
            if len(batch) == window:
                break
        if not batch:
            return
        yield batch
        if len(batch) < window:  # stream exhausted mid-window
            return


def _pack_documents(
    docs: Iterator[np.ndarray], seq_len: int
) -> Iterator[np.ndarray]:
    """Greedy sequence packing: concatenate docs, emit seq_len+1 windows."""
    buf = np.zeros(0, dtype=np.int32)
    need = seq_len + 1
    while True:
        while len(buf) < need:
            buf = np.concatenate([buf, next(docs)])
        yield buf[:need].copy()
        buf = buf[seq_len:]


class BatchPipeline:
    """Iterator of {'tokens': (B_host, S), 'labels': (B_host, S)} batches."""

    def __init__(self, cfg: PipelineConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        self._steps = 0
        docs = synthetic_documents(cfg.vocab_size, cfg.seed * 1000 + cfg.host_index)
        if cfg.dedup:
            docs = self._dedup_stream(docs)
        self._windows = _pack_documents(docs, cfg.seq_len)
        self._queue: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- dedup stage --------------------------------------------------------
    def _dedup_stream(self, docs: Iterator[np.ndarray]) -> Iterator[np.ndarray]:
        cfg = self.cfg
        for window in document_windows(docs, cfg.dedup_window):
            idx, val = dedup_mod.docs_to_categorical(window, cfg.vocab_size)
            _, sketches = dedup_mod.sketch_corpus(
                idx, val, cfg.vocab_size, cfg.dedup_sketch_dim, seed=cfg.seed
            )
            result = dedup_mod.dedup_by_sketch(
                sketches, cfg.dedup_sketch_dim, cfg.dedup_threshold
            )
            for doc, keep in zip(window, result.keep_mask):
                if keep:
                    yield doc

    # -- prefetch -----------------------------------------------------------
    def _producer(self) -> None:
        while not self._stop.is_set():
            batch = self._make_batch()
            try:
                self._queue.put(batch, timeout=60)
            except queue.Full:  # consumer gone
                if self._stop.is_set():
                    return

    def _make_batch(self) -> dict[str, np.ndarray]:
        rows = np.stack([next(self._windows) for _ in range(self.host_batch)])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        self._steps += 1
        return self._queue.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
