"""Corpus near-duplicate detection with Cabin sketches + Cham distances.

This is the paper's technique deployed where a production training system
needs it: documents are categorical vectors over the vocabulary (token counts
capped at c categories — the paper treats BoW exactly this way), Cabin
compresses each document to a packed d-bit sketch, and all-pairs Cham
estimates replace exact Hamming distances in the dedup/diversity stage.

Cost: exact dedup on V-dim count vectors is O(N^2 V); sketch dedup is
O(N V) sketching + O(N^2 d/32) packed popcounts with d independent of V —
the same asymptotics that give the paper its 136x heatmap speedup.

Blocked scanning keeps the pairwise pass at O(block^2) memory; candidate
pairs under `threshold` are unioned (union-find) and one representative per
duplicate group is kept.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

import functools

import jax

from repro.core.cabin import CabinParams, sketch_sparse_jit
from repro.core.cham import cham_matrix
from repro.kernels.hamming.ops import cham_matrix_fast

_cham_matrix_jit = jax.jit(cham_matrix, static_argnums=2)


def docs_to_categorical(
    docs: list[np.ndarray], vocab_size: int, max_count: int = 15
) -> tuple[np.ndarray, np.ndarray]:
    """Token-id docs -> padded-COO categorical rows (counts capped at c)."""
    max_nnz = max((len(np.unique(d)) for d in docs if len(d)), default=1)
    n = len(docs)
    indices = np.zeros((n, max_nnz), dtype=np.int32)
    values = np.zeros((n, max_nnz), dtype=np.int32)
    for i, doc in enumerate(docs):
        if len(doc) == 0:
            continue
        ids, counts = np.unique(doc, return_counts=True)
        counts = np.minimum(counts, max_count)
        indices[i, : len(ids)] = ids
        values[i, : len(ids)] = counts
    return indices, values


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


@dataclass
class DedupResult:
    keep_mask: np.ndarray  # (N,) bool — representatives to keep
    group_ids: np.ndarray  # (N,) int — duplicate-group id per doc
    n_groups: int
    n_removed: int


def sketch_corpus(
    indices: np.ndarray, values: np.ndarray, vocab_size: int,
    sketch_dim: int = 1024, seed: int = 0,
) -> tuple[CabinParams, np.ndarray]:
    params = CabinParams.create(vocab_size, sketch_dim, seed=seed)
    sketches = np.asarray(
        sketch_sparse_jit(params, jnp.asarray(indices), jnp.asarray(values))
    )
    return params, sketches


def dedup_by_sketch(
    sketches: np.ndarray,
    sketch_dim: int,
    threshold: float,
    block: int = 1024,
    use_kernel: bool = False,
) -> DedupResult:
    """Union docs whose estimated Hamming distance < threshold."""
    n = sketches.shape[0]
    uf = _UnionFind(n)
    sk = jnp.asarray(sketches)
    for i0 in range(0, n, block):
        a = sk[i0 : i0 + block]
        for j0 in range(i0, n, block):
            b = sk[j0 : j0 + block]
            if use_kernel:
                d = np.asarray(cham_matrix_fast(a, b, sketch_dim,
                                                use_pallas=False))
            else:
                d = np.asarray(_cham_matrix_jit(a, b, sketch_dim))
            ii, jj = np.where(d < threshold)
            for di, dj in zip(ii.tolist(), jj.tolist()):
                gi, gj = i0 + di, j0 + dj
                if gi < gj:
                    uf.union(gi, gj)
    roots = np.asarray([uf.find(i) for i in range(n)])
    _, group_ids = np.unique(roots, return_inverse=True)
    keep = roots == np.arange(n)
    return DedupResult(
        keep_mask=keep,
        group_ids=group_ids,
        n_groups=int(group_ids.max()) + 1 if n else 0,
        n_removed=int((~keep).sum()),
    )


def dedup_exact(
    indices: np.ndarray, values: np.ndarray, vocab_size: int, threshold: float,
) -> DedupResult:
    """Exact-HD dedup baseline (the expensive full-dimension path)."""
    n = indices.shape[0]
    uf = _UnionFind(n)
    dense = np.zeros((n, vocab_size), dtype=np.int32)
    rows = np.repeat(np.arange(n), indices.shape[1])
    dense[rows, indices.ravel()] = values.ravel()
    for i in range(n):
        hd = (dense[i + 1 :] != dense[i]).sum(axis=1)
        for j in np.where(hd < threshold)[0]:
            uf.union(i, i + 1 + int(j))
    roots = np.asarray([uf.find(i) for i in range(n)])
    _, group_ids = np.unique(roots, return_inverse=True)
    keep = roots == np.arange(n)
    return DedupResult(keep, group_ids, int(group_ids.max()) + 1 if n else 0,
                       int((~keep).sum()))
