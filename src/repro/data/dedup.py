"""Corpus near-duplicate detection with Cabin sketches + Cham distances.

This is the paper's technique deployed where a production training system
needs it: documents are categorical vectors over the vocabulary (token counts
capped at c categories — the paper treats BoW exactly this way), Cabin
compresses each document to a packed d-bit sketch, and all-pairs Cham
estimates replace exact Hamming distances in the dedup/diversity stage.

Cost: exact dedup on V-dim count vectors is O(N^2 V); sketch dedup is
O(N V) sketching + O(N^2 d/32) packed popcounts with d independent of V —
the same asymptotics that give the paper its 136x heatmap speedup.

The pairwise pass streams through repro.core.allpairs: distance tiles are
computed, thresholded, and compacted to candidate (i, j) pairs ON DEVICE in
one fused loop — no (N, M) float matrix ever reaches the host and the only
transfer is the compact candidate list.  Duplicate groups then come from a
vectorised min-label connected-components pass over the candidate batch
(identical grouping to the per-pair union-find it replaced: both converge to
the minimum index of each connected component).

`dedup_by_sketch_blocked` keeps the pre-engine blocked scan (per-block host
sync + np.where + per-pair union feed) as the equivalence/benchmark
reference.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

import jax

from repro.core import allpairs, packing
from repro.core.cabin import CabinParams, sketch_sparse_jit
from repro.core.cham import cham_matrix

_cham_matrix_jit = jax.jit(cham_matrix, static_argnums=2)


def docs_to_categorical(
    docs: list[np.ndarray], vocab_size: int, max_count: int = 15
) -> tuple[np.ndarray, np.ndarray]:
    """Token-id docs -> padded-COO categorical rows (counts capped at c)."""
    max_nnz = max((len(np.unique(d)) for d in docs if len(d)), default=1)
    n = len(docs)
    indices = np.zeros((n, max_nnz), dtype=np.int32)
    values = np.zeros((n, max_nnz), dtype=np.int32)
    for i, doc in enumerate(docs):
        if len(doc) == 0:
            continue
        ids, counts = np.unique(doc, return_counts=True)
        counts = np.minimum(counts, max_count)
        indices[i, : len(ids)] = ids
        values[i, : len(ids)] = counts
    return indices, values


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


def _components_from_pairs(n: int, pairs: np.ndarray) -> np.ndarray:
    """Vectorised connected components: labels[i] = min index reachable
    from i over the candidate-pair graph.

    Min-label propagation with pointer jumping; converges in O(log n)
    sweeps, each a handful of vectorised scatter/gather ops over the whole
    candidate batch.  Produces exactly the roots the per-pair union-find
    yields (union-by-min makes every root the component's minimum index).
    """
    labels = np.arange(n, dtype=np.int64)
    if len(pairs) == 0:
        return labels
    pi = pairs[:, 0].astype(np.int64)
    pj = pairs[:, 1].astype(np.int64)
    while True:
        nxt = labels.copy()
        np.minimum.at(nxt, pi, labels[pj])
        np.minimum.at(nxt, pj, labels[pi])
        nxt = nxt[nxt]  # pointer jumping halves chain depth
        if np.array_equal(nxt, labels):
            return labels
        labels = nxt


@dataclass
class DedupResult:
    keep_mask: np.ndarray  # (N,) bool — representatives to keep
    group_ids: np.ndarray  # (N,) int — duplicate-group id per doc
    n_groups: int
    n_removed: int


def _result_from_roots(roots: np.ndarray, n: int) -> DedupResult:
    _, group_ids = np.unique(roots, return_inverse=True)
    keep = roots == np.arange(n)
    return DedupResult(
        keep_mask=keep,
        group_ids=group_ids,
        n_groups=int(group_ids.max()) + 1 if n else 0,
        n_removed=int((~keep).sum()),
    )


def sketch_corpus(
    indices: np.ndarray, values: np.ndarray, vocab_size: int,
    sketch_dim: int = 1024, seed: int = 0,
) -> tuple[CabinParams, np.ndarray]:
    """Sketch padded-COO docs; dispatches to the fused sparse-Cabin Pallas
    kernel on TPU for 128-aligned sketch dims (repro.kernels.cabin_build_sparse),
    the jnp scatter path otherwise."""
    params = CabinParams.create(vocab_size, sketch_dim, seed=seed)
    sketches = np.asarray(
        sketch_sparse_jit(params, jnp.asarray(indices), jnp.asarray(values))
    )
    return params, sketches


def dedup_by_sketch(
    sketches: np.ndarray,
    sketch_dim: int,
    threshold: float,
    block: int = 256,
    use_kernel: bool = False,
    capacity: int | None = None,
    metric: str = "cham",
) -> DedupResult:
    """Union docs whose distance < threshold under `metric` ("cham" =
    estimated categorical HD, the default; "hamming" = exact sketch HD —
    used by index ingest so its threshold shares units with the serving
    engine's distances).

    Streaming pass: rows are scanned in sketch-weight order so the engine's
    weight-band prune can skip tiles whose length ranges are incompatible
    with the threshold (Cham >= 2|a_hat - b_hat|, a sound bound — the
    candidate set is unchanged); surviving tiles are thresholded and
    compacted to candidate pairs on device by
    repro.core.allpairs.threshold_pairs (one compact transfer), then grouped
    by the vectorised components pass.  use_kernel=True forces the Pallas
    pair-stats tile backend on TPU (off-TPU it is ignored: the Pallas
    interpreter would be orders of magnitude slower than the jnp tiles).
    """
    n = sketches.shape[0]
    if n == 0:
        return _result_from_roots(np.arange(0), 0)
    sk = np.ascontiguousarray(sketches)
    weights = packing.np_popcount_rows(sk)
    order = np.argsort(weights, kind="stable").astype(np.int64)
    force_pallas = use_kernel and jax.default_backend() == "tpu"
    pairs = allpairs.threshold_pairs(
        sk[order],
        d=sketch_dim,
        threshold=threshold,
        metric=metric,
        block=block,
        capacity=capacity,
        mode="pallas" if force_pallas else None,
        sorted_by_weight=True,
        weights=weights[order],
    )
    roots = _components_from_pairs(n, order[pairs] if len(pairs) else pairs)
    return _result_from_roots(roots, n)


def dedup_by_sketch_blocked(
    sketches: np.ndarray,
    sketch_dim: int,
    threshold: float,
    block: int = 1024,
) -> DedupResult:
    """Pre-engine reference: blocked scan with per-block host sync and a
    per-pair union-find feed.  Kept for equivalence tests and as the
    benchmark baseline the streaming pass is measured against."""
    n = sketches.shape[0]
    uf = _UnionFind(n)
    sk = jnp.asarray(sketches)
    for i0 in range(0, n, block):
        a = sk[i0 : i0 + block]
        for j0 in range(i0, n, block):
            b = sk[j0 : j0 + block]
            d = np.asarray(_cham_matrix_jit(a, b, sketch_dim))
            ii, jj = np.where(d < threshold)
            for di, dj in zip(ii.tolist(), jj.tolist()):
                gi, gj = i0 + di, j0 + dj
                if gi < gj:
                    uf.union(gi, gj)
    roots = np.asarray([uf.find(i) for i in range(n)])
    return _result_from_roots(roots, n)


def dedup_exact(
    indices: np.ndarray, values: np.ndarray, vocab_size: int, threshold: float,
) -> DedupResult:
    """Exact-HD dedup baseline (the expensive full-dimension path)."""
    n = indices.shape[0]
    uf = _UnionFind(n)
    dense = np.zeros((n, vocab_size), dtype=np.int32)
    rows = np.repeat(np.arange(n), indices.shape[1])
    dense[rows, indices.ravel()] = values.ravel()
    for i in range(n):
        hd = (dense[i + 1 :] != dense[i]).sum(axis=1)
        for j in np.where(hd < threshold)[0]:
            uf.union(i, i + 1 + int(j))
    roots = np.asarray([uf.find(i) for i in range(n)])
    return _result_from_roots(roots, n)
