"""Data substrate: synthetic categorical data, tokenizer, LM pipeline, dedup."""
