"""AdamW + cosine schedule (pure JAX, no optax dependency).

Moments are kept in cfg-selected dtype (bf16 moments shave 4 bytes/param for
the 671B fit analysis; f32 default).  The optimizer state tree mirrors the
param tree, so the sharding rules of repro.distributed.sharding apply to the
moments verbatim — that plus ZeRO-3 param sharding gives optimizer-state
sharding for free under pjit.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.layers import dt


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class AdamWState:
    step: jnp.ndarray
    mu: dict
    nu: dict


def init_state(params, moment_dtype: str = "float32") -> AdamWState:
    mdt = dt(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def lr_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def _is_matrix(p) -> bool:
    return p.ndim >= 2  # weight decay only on matrices (not norms/biases)


def apply_updates(cfg: TrainConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mu_n / bc1
        vhat = nu_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
