"""Cross-pod gradient compression: EF-SignSGD with packed sign bits.

At 512+ chips the inter-pod hop (DCN) is the slow link; intra-pod ICI is an
order of magnitude faster.  This module compresses ONLY the cross-pod
gradient reduction:

  1. within-pod mean over ('data',) happens in the backward pass as usual;
  2. signs of the pod-local gradient are packed 32/lane into int32 using the
     paper's bit-packing substrate (repro.core.packing semantics — same
     LSB-first layout as Cabin sketches),
  3. packed words are all-gathered across 'pod' (16x fewer bytes than bf16,
     32x fewer than f32),
  4. pods combine by majority vote (popcount over the pod axis) scaled by
     the mean |g| (1-bit SGD's scale restoration),
  5. the compression residual e = g - decompress(compress(g)) is fed back
     into the next step's gradient (error feedback keeps convergence).

All steps are jnp inside shard_map over the pod axis; the packed all-gather
is the only cross-pod collective in the compressed path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _pack_signs_1d(g: jnp.ndarray) -> jnp.ndarray:
    """g: (n,) float -> (ceil(n/32),) int32 of sign bits (1 = positive)."""
    n = g.shape[0]
    pad = (-n) % 32
    bits = (g >= 0).astype(jnp.uint32)
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((pad,), jnp.uint32)])
    lanes = bits.reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32).astype(jnp.int32)


def _unpack_signs_1d(words: jnp.ndarray, n: int) -> jnp.ndarray:
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words.astype(jnp.uint32)[:, None] >> shifts) & jnp.uint32(1)
    signs = bits.reshape(-1)[:n].astype(jnp.float32) * 2.0 - 1.0
    return signs


def compress_decompress_local(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device reference: returns (reconstruction, packed_words)."""
    flat = g.reshape(-1).astype(jnp.float32)
    words = _pack_signs_1d(flat)
    scale = jnp.mean(jnp.abs(flat))
    recon = (_unpack_signs_1d(words, flat.shape[0]) * scale).reshape(g.shape)
    return recon.astype(g.dtype), words


def cross_pod_sign_allreduce(g: jnp.ndarray, axis_name: str = "pod"):
    """Inside shard_map: combine pod-local mean gradients by sign majority.

    g: pod-local gradient (already reduced within the pod).  Returns the
    sign-majority combined gradient with magnitude = mean over pods of
    mean|g|.  Communication: one all-gather of packed int32 (n/32 words) and
    one psum of a scalar, instead of psum of n floats.
    """
    flat = g.reshape(-1).astype(jnp.float32)
    words = _pack_signs_1d(flat)
    n_pods = jax.lax.psum(1, axis_name)
    all_words = jax.lax.all_gather(words, axis_name)  # (P, n/32) int32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (all_words.astype(jnp.uint32)[..., None] >> shifts) & jnp.uint32(1)
    votes = jnp.sum(bits, axis=0)  # (n/32, 32) counts of positive votes
    majority = (votes * 2 >= n_pods).reshape(-1)[: flat.shape[0]]
    signs = majority.astype(jnp.float32) * 2.0 - 1.0
    scale = jax.lax.pmean(jnp.mean(jnp.abs(flat)), axis_name)
    return (signs * scale).reshape(g.shape).astype(g.dtype)


def ef_correct(grads, error_feedback):
    """g_tilde = g + e (error feedback injection)."""
    if error_feedback is None:
        return grads
    return jax.tree_util.tree_map(
        lambda g, e: g + e.astype(g.dtype), grads, error_feedback)


def ef_residual(grads_corrected, grads_applied):
    """e' = g_tilde - applied."""
    return jax.tree_util.tree_map(
        lambda gt, ga: (gt.astype(jnp.float32) - ga.astype(jnp.float32)),
        grads_corrected, grads_applied)


def compress_tree_cross_pod(grads, mesh, error_feedback=None):
    """shard_map wrapper applying cross-pod sign compression to a grad tree.

    Only used when the mesh has a 'pod' axis; grads are assumed already
    psum-med over 'data' (pjit backward does this).  Returns
    (combined_grads, new_error_feedback).
    """
    from jax.experimental.shard_map import shard_map

    corrected = ef_correct(grads, error_feedback)

    def comm(g):
        return cross_pod_sign_allreduce(g, "pod")

    def one(g):
        fn = shard_map(
            comm, mesh=mesh,
            in_specs=P(),  # replicated within pod for optimizer-visible grads
            out_specs=P(),
            check_rep=False,
        )
        return fn(g)

    applied = jax.tree_util.tree_map(one, corrected)
    new_ef = ef_residual(corrected, applied)
    return applied, new_ef
