"""Training loop: checkpoint/resume, heartbeats, straggler tracking, metrics.

The Trainer is deliberately thin: all heavy lifting is in the jitted step
function built by make_train_step; the loop owns restart semantics (resume
from latest checkpoint — restart-safe because the data pipeline is seeded
and the step index replays its position) and failure-injection hooks used by
the fault-tolerance tests.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import transformer as T
from repro.runtime.fault_tolerance import HeartbeatWriter, StragglerMonitor
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


@dataclass
class TrainerReport:
    steps_run: int = 0
    final_step: int = 0
    metrics_history: list[dict] = field(default_factory=list)
    resumed_from: int | None = None


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        pcfg: ParallelConfig,
        tcfg: TrainConfig,
        batch_iter,
        ckpt_dir: str,
        ckpt_every: int = 50,
        host_id: int = 0,
        heartbeat_dir: str | None = None,
        jit: bool = True,
    ):
        self.cfg, self.pcfg, self.tcfg = cfg, pcfg, tcfg
        self.batch_iter = batch_iter
        self.ckpt = Checkpointer(ckpt_dir, keep=3)
        self.ckpt_every = ckpt_every
        self.heartbeat = (HeartbeatWriter(heartbeat_dir, host_id)
                          if heartbeat_dir else None)
        self.straggler = StragglerMonitor()
        self.host_id = host_id
        step_fn = make_train_step(cfg, pcfg, tcfg)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1)) if jit else step_fn

    # -- state --------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = T.init_params(self.cfg, jax.random.PRNGKey(seed))
        opt_state = opt.init_state(params, self.cfg.precision.moment_dtype)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        params, opt_state, step = self.init_state(seed)
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt_state, 0, None
        state, _ = self.ckpt.restore({"params": params, "opt": opt_state})
        return state["params"], state["opt"], latest, latest

    # -- loop ---------------------------------------------------------------
    def run(self, n_steps: int, seed: int = 0,
            fail_at: int | None = None,
            on_metrics: Callable[[int, dict], None] | None = None
            ) -> TrainerReport:
        params, opt_state, start, resumed = self.restore_or_init(seed)
        report = TrainerReport(resumed_from=resumed)
        step = start
        while step < n_steps:
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(self.batch_iter)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt_step = time.perf_counter() - t0
            self.straggler.record(self.host_id, dt_step)
            step += 1
            report.steps_run += 1
            report.metrics_history.append({"step": step, **metrics,
                                           "sec": dt_step})
            if self.heartbeat:
                self.heartbeat.beat(step, {"loss": metrics.get("loss")})
            if on_metrics:
                on_metrics(step, metrics)
            if step % self.ckpt_every == 0 or step == n_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        self.ckpt.wait()
        report.final_step = step
        self._final = (params, opt_state)
        return report
