"""Training step: loss, microbatched gradients, optimizer update.

Built for pjit: the exported `make_train_step(cfg, pcfg, tcfg)` returns a
pure function (params, opt_state, batch, rng) -> (params, opt_state,
metrics) that the launcher jits with in/out shardings.  Features:

  * cross-entropy with z-loss (logit drift control at scale),
  * frontend-token masking for VLM (loss only on text positions),
  * gradient accumulation over `pcfg.microbatches` via lax.scan (activation
    memory / collective-size knob),
  * remat policy inherited from the model stack (pcfg.remat),
  * optional cross-pod EF-sign gradient compression hook (pcfg.grad_compress_pods)
    applied by the launcher between grad and optimizer (see launch/train.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.distributed.sharding import constrain, current_mesh, param_specs
from repro.models import transformer as T
from repro.train import optimizer as opt


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None, z_loss: float):
    """logits (B, S, V) f32, labels (B, S) int32. Returns (loss, metrics)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * lse**2
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    acc = ((logits.argmax(-1) == labels) * mask).sum() / denom
    return loss, {"nll": (nll * mask).sum() / denom, "accuracy": acc}


def loss_fn(cfg: ModelConfig, params, batch: dict,
            pcfg: ParallelConfig, tcfg: TrainConfig):
    logits, aux = T.forward(cfg, params, batch, pcfg)
    labels = batch["labels"]
    if cfg.frontend is not None and cfg.kind != "encdec":
        # VLM: logits cover [frontend; text]; loss on text positions only.
        logits = logits[:, cfg.n_frontend_tokens:, :]
    mask = batch.get("mask")
    loss, metrics = cross_entropy(logits, labels, mask, tcfg.z_loss)
    metrics["aux_loss"] = aux
    metrics["loss"] = loss + aux
    return loss + aux, metrics


def _split_microbatches(batch: dict, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} must divide microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def grads_fn(cfg: ModelConfig, params, batch: dict,
             pcfg: ParallelConfig, tcfg: TrainConfig):
    """Returns (grads, metrics) with microbatch accumulation."""
    vg = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, pcfg, tcfg), has_aux=True)

    if pcfg.microbatches <= 1:
        (loss, metrics), grads = vg(params, batch)
        return grads, metrics

    micro = _split_microbatches(batch, pcfg.microbatches)

    def body(carry, mb):
        acc_g, acc_m = carry
        (loss, metrics), grads = vg(params, mb)
        acc_g = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        acc_m = jax.tree_util.tree_map(lambda a, m: a + m, acc_m, metrics)
        return (acc_g, acc_m), None

    zeros_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = {"nll": 0.0, "accuracy": 0.0, "aux_loss": 0.0, "loss": 0.0}
    zeros_m = jax.tree_util.tree_map(jnp.float32, zeros_m)
    (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m), micro)
    inv = 1.0 / pcfg.microbatches
    grads = jax.tree_util.tree_map(
        lambda g: (g * inv), grads)
    metrics = jax.tree_util.tree_map(lambda m: m * inv, metrics)
    grads = jax.tree_util.tree_map(
        lambda g, p: g.astype(p.dtype) if p.dtype == jnp.float32 else g,
        grads, params)
    return grads, metrics


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig, tcfg: TrainConfig,
                    grad_hook=None):
    """grad_hook: optional (grads, hook_state) -> (grads, hook_state) applied
    before the optimizer (used for cross-pod sign compression)."""

    def train_step(params, opt_state, batch, hook_state=None):
        batch = {k: constrain(v, "dp", *([None] * (v.ndim - 1)))
                 for k, v in batch.items()}
        grads, metrics = grads_fn(cfg, params, batch, pcfg, tcfg)
        if current_mesh() is not None:
            # Pin gradients to the parameter layout straight out of the
            # backward pass: turns the data-axis gradient sync into
            # reduce-scatters landing on the ZeRO shards instead of full
            # f32 all-reduce + slice (EXPERIMENTS.md section Perf, cell A
            # iteration 6 — 45 GB/layer of expert grads at deepseek-v3).
            import jax as _jax

            specs = param_specs(grads)
            grads = _jax.tree_util.tree_map(
                lambda g, s: _jax.lax.with_sharding_constraint(g, s),
                grads, specs)
        if grad_hook is not None:
            grads, hook_state = grad_hook(grads, hook_state)
        params, opt_state, opt_metrics = opt.apply_updates(
            tcfg, params, grads, opt_state)
        metrics.update(opt_metrics)
        if grad_hook is not None:
            return params, opt_state, metrics, hook_state
        return params, opt_state, metrics

    return train_step
