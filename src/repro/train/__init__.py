"""Training substrate."""
