"""Fault-tolerance runtime: heartbeats, failure detection, straggler
mitigation, elastic degradation policy.

On a real multi-pod deployment each host runs a HeartbeatWriter; a
coordinator (or every peer) runs FailureDetector over the shared filesystem
/ object store.  The control actions are the generic ones a JAX
single-controller stack supports:

  * on failure: all survivors restart from the last checkpoint; the elastic
    policy (`plan_degraded_mesh`) picks the largest (data, model) grid that
    fits the surviving host count, and Checkpointer.restore(..., shardings=)
    resharding brings the state up under the new mesh.
  * stragglers: per-step duration tracking flags hosts whose step time
    exceeds `threshold x median` over a window; the mitigation hook lets the
    launcher rebalance (drop the host => elastic path) or shrink its data
    shard (documented policy — data reassignment happens in the pipeline's
    host_index/n_hosts parameters).

Exercised by real tests rather than asserted here: heartbeat timeout and
malformed-beat handling in tests/test_train_checkpoint_ft.py
(test_heartbeat_failure_detection, test_dead_hosts_tolerates_malformed_beat),
elastic degradation in test_plan_degraded_mesh, and crash recovery itself —
injected process kills at every registered crash point — in
tests/test_faultinject.py via repro.runtime.faultinject.  The file protocol
is host-agnostic.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field

from repro.runtime import faultinject

# between writing the .tmp beat and publishing it with os.replace — a
# crash here orphans the .tmp file (the published beat, if any, stays
# intact; FailureDetector never reads .tmp)
_CP_HB_TMP = faultinject.declare("heartbeat.tmp_written")


class HeartbeatWriter:
    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"heartbeat_{host_id}.json")
        os.makedirs(directory, exist_ok=True)
        self.host_id = host_id
        # sweep OUR orphaned staging file from a previous incarnation that
        # died between write and publish (mirrors Checkpointer's
        # .tmp_step_* sweep).  Only this host's .tmp: a peer may be
        # mid-beat on the shared directory right now.
        try:
            os.remove(self.path + ".tmp")
        except OSError:
            pass

    def beat(self, step: int, extra: dict | None = None) -> None:
        payload = {"host": self.host_id, "step": step, "time": time.time(),
                   **(extra or {})}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        faultinject.crash_point(_CP_HB_TMP)
        os.replace(tmp, self.path)


class FailureDetector:
    def __init__(self, directory: str, timeout_s: float = 60.0):
        self.directory = directory
        self.timeout_s = timeout_s

    def read_all(self) -> dict[int, dict]:
        beats = {}
        if not os.path.isdir(self.directory):
            return beats
        for name in os.listdir(self.directory):
            if name.startswith("heartbeat_") and name.endswith(".json"):
                try:
                    with open(os.path.join(self.directory, name)) as f:
                        b = json.load(f)
                    beats[int(b["host"])] = b
                except (json.JSONDecodeError, KeyError, ValueError):
                    continue  # torn write: treat as missing this round
        return beats

    def dead_hosts(self, expected_hosts: list[int],
                   now: float | None = None) -> list[int]:
        now = time.time() if now is None else now
        beats = self.read_all()
        dead = []
        for h in expected_hosts:
            b = beats.get(h)
            # a beat missing "time" (or carrying a non-numeric one) passed
            # read_all's "host" check but proves nothing about liveness —
            # treat it exactly like no beat at all
            t = b.get("time") if b is not None else None
            if not isinstance(t, (int, float)) or now - t > self.timeout_s:
                dead.append(h)
        return dead


@dataclass
class StragglerMonitor:
    """Flags hosts whose recent step times exceed threshold x median."""

    window: int = 20
    threshold: float = 2.0
    history: dict[int, list[float]] = field(default_factory=dict)

    def record(self, host: int, duration_s: float) -> None:
        h = self.history.setdefault(host, [])
        h.append(duration_s)
        if len(h) > self.window:
            del h[: len(h) - self.window]

    def medians(self) -> dict[int, float]:
        import statistics

        return {h: statistics.median(v) for h, v in self.history.items() if v}

    def stragglers(self) -> list[int]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        global_med = sorted(meds.values())[len(meds) // 2]
        return [h for h, m in meds.items() if m > self.threshold * global_med]


def plan_degraded_mesh(n_surviving_hosts: int, chips_per_host: int = 4,
                       model_parallel: int = 16) -> tuple[int, int]:
    """Largest (data, model) grid on the survivors, keeping TP intact.

    Returns (data, model).  Model parallelism is pinned (weights are sharded
    model-ways and must stay whole); the data axis absorbs the loss —
    standard elastic-DP degradation.
    """
    chips = n_surviving_hosts * chips_per_host
    if chips < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with {chips} chips")
    data = chips // model_parallel
    # largest power-of-two data axis for predictable collectives
    p = 1
    while p * 2 <= data:
        p *= 2
    return p, model_parallel
