"""Runtime: fault tolerance, elasticity."""
