"""Fault injection: named crash points that turn "recovers from a crash
anywhere" into an enumerable property.

The recovery claims this repo makes (Checkpointer's atomic publish, the
index's crash-safe migration journal) are only as strong as the set of
interruption points they were actually tested at.  This module gives every
durability-critical code path a NAMED crash point::

    _CP_PUBLISH = faultinject.declare("checkpointer.save.published")
    ...
    faultinject.crash_point(_CP_PUBLISH)

`declare` runs at import time, so the full set of points is enumerable
(`registered_points()`) without executing any path — the crash-matrix test
in tests/test_faultinject.py arms each one in turn and asserts recovery.

Two trigger mechanisms:

  * programmatic — `arm(name)` / the `armed(name)` context manager make the
    next hit of that point raise `InjectedCrash` (a BaseException subclass,
    so no library `except Exception` can swallow it).  The point disarms on
    fire: one arm, one crash.  This is the in-process test path — the test
    catches InjectedCrash at its top level and then recovers FROM DISK ONLY,
    which is exactly the state a killed process would leave behind.
  * environment — set REPRO_CRASH_POINT=<name> (and optionally
    REPRO_CRASH_MODE=exit) before starting a subprocess: the first hit of
    that point calls os._exit(EXIT_CODE), an un-catchable process death
    with no atexit/finally cleanup — the honest crash.  The subprocess test
    uses this to validate that in-process raising is not hiding behind
    interpreter teardown.

When nothing is armed, `crash_point` is a single global-is-None check —
cheap enough to leave in serving hot paths (the idle-overhead bench bar in
ISSUE 6 covers this).  Triggers are process-wide module state rather than
contextvars because crash points fire from helper threads too
(Checkpointer's async save), and contextvars do not propagate into
`threading.Thread` targets.
"""

from __future__ import annotations

import contextlib
import os
import threading

EXIT_CODE = 17  # distinguishes an injected kill from any real failure

_ENV_POINT = "REPRO_CRASH_POINT"
_ENV_MODE = "REPRO_CRASH_MODE"

_registry: set[str] = set()
_armed: str | None = None
_armed_mode: str = "raise"
# serializes the disarm-and-fire transition: with the front door's real
# threads, several callers can cross the same armed point concurrently,
# and "one arm, one crash" must mean exactly one of them dies.  The
# disarmed fast path in crash_point stays a lock-free global-is-None
# check; the lock is only taken once a hit looks live.
_fire_lock = threading.Lock()
_record = False  # hit recording is test-only: a server must not grow a log
_hits: list[str] = []  # points crossed while recording was on, in order
_observer = None  # repro.obs hook: every crossing becomes a trace instant


class InjectedCrash(BaseException):
    """Raised (not Exception — nothing may swallow it) at an armed point."""

    def __init__(self, point: str):
        super().__init__(f"injected crash at {point!r}")
        self.point = point


def declare(name: str) -> str:
    """Register a crash-point name (idempotent) and return it.  Call at
    module import so `registered_points` enumerates every point without
    executing the paths that contain them."""
    _registry.add(name)
    return name


def registered_points() -> tuple[str, ...]:
    """All declared crash points, sorted — the crash-matrix test's domain."""
    return tuple(sorted(_registry))


def arm(name: str, mode: str = "raise") -> None:
    """Arm `name`: its next `crash_point` hit fires once, then disarms.
    mode "raise" raises InjectedCrash; mode "exit" calls os._exit."""
    global _armed, _armed_mode
    if name not in _registry:
        raise ValueError(f"unknown crash point {name!r}; "
                         f"registered: {registered_points()}")
    if mode not in ("raise", "exit"):
        raise ValueError(f"mode must be 'raise' or 'exit', got {mode!r}")
    with _fire_lock:
        _armed, _armed_mode = name, mode


def disarm() -> None:
    global _armed
    with _fire_lock:
        _armed = None


@contextlib.contextmanager
def armed(name: str, mode: str = "raise"):
    """Context manager form of arm(); always disarms on exit (the point may
    not have been reached — e.g. enumerating points some scenario skips)."""
    arm(name, mode)
    try:
        yield
    finally:
        disarm()


def record_hits(enabled: bool = True) -> None:
    """Toggle hit recording (off by default: a long-lived server must not
    accumulate a hit log)."""
    global _record
    _record = enabled


def hits() -> tuple[str, ...]:
    """Crash points crossed while recording was enabled, in order — lets
    tests assert a scenario actually reaches a point before trusting a
    no-crash run of it."""
    return tuple(_hits)


def clear_hits() -> None:
    del _hits[:]


def set_observer(fn) -> None:
    """Install `fn(name)` to run at every crash-point crossing (None to
    remove).  The one consumer is repro.obs, which records crossings as
    trace instant events; the disabled-path cost stays a single global-
    is-None check.  The observer runs BEFORE any armed crash fires, so a
    trace exported after recovery shows the point the process died at."""
    global _observer
    _observer = fn


def crash_point(name: str) -> None:
    """Die here iff `name` is armed (programmatically or via env)."""
    global _armed
    if _record:
        _hits.append(name)
    if _observer is not None:
        _observer(name)
    if _armed is not None and name == _armed:
        with _fire_lock:
            if _armed != name:
                return  # another thread won the race and already fired
            _armed = None  # one arm, one crash
            mode = _armed_mode
        if mode == "exit":
            os._exit(EXIT_CODE)
        raise InjectedCrash(name)


# env trigger, picked up once at import: subprocess tests set
# REPRO_CRASH_POINT before exec'ing the child, so the armed state exists
# before any call site runs, and the serving-path cost of crash_point stays
# one global comparison regardless of trigger mechanism.
if os.environ.get(_ENV_POINT):
    _armed = os.environ[_ENV_POINT]
    _armed_mode = os.environ.get(_ENV_MODE, "exit")
