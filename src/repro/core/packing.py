"""Bit-packing utilities: {0,1}^d vectors <-> packed int32 lanes.

TPU-native representation of binary sketches: d bits live in ceil(d/32) int32
words.  All downstream distance math (XOR/AND + popcount) operates on the
packed form; these helpers are the jnp reference implementations that the
Pallas kernels mirror.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

LANE_BITS = 32


def packed_width(d: int) -> int:
    return (d + LANE_BITS - 1) // LANE_BITS


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack (..., d) {0,1} int array into (..., ceil(d/32)) int32.

    Bit j of the vector lands in word j // 32 at position j % 32 (LSB-first).
    """
    *lead, d = bits.shape
    w = packed_width(d)
    pad = w * LANE_BITS - d
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*lead, pad), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(*lead, w, LANE_BITS).astype(jnp.uint32)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    words = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def unpack_bits(words: jnp.ndarray, d: int) -> jnp.ndarray:
    """Inverse of pack_bits: (..., w) int32 -> (..., d) int32 in {0,1}."""
    *lead, w = words.shape
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (words.astype(jnp.uint32)[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*lead, w * LANE_BITS)[..., :d].astype(jnp.int32)


def popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of each int32 word (returns int32 counts 0..32).

    This is the exact bit-trick sequence the Pallas kernels use on the VPU —
    TPUs expose no popcount primitive through XLA.
    """
    v = x.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def popcount_rows(words: jnp.ndarray) -> jnp.ndarray:
    """Hamming weight of each packed row: (..., w) int32 -> (...,) int32."""
    return jnp.sum(popcount32(words), axis=-1)


def packed_hamming(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """HD between packed rows (broadcasting over leading dims)."""
    return jnp.sum(popcount32(a ^ b), axis=-1)


def packed_inner(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bitwise inner product <a, b> between packed rows."""
    return jnp.sum(popcount32(a & b), axis=-1)


def pad_to_multiple(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    """Zero-pad `axis` up to the next multiple of `mult` — the grid-shape
    alignment rule shared by the Pallas kernel wrappers."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pow2_bucket(n: int, floor: int = 8) -> int:
    """Next power of two >= max(n, floor) — THE shape-bucketing rule.

    Shared by the all-pairs engine's row padding, the index store's buffer
    capacities, and the query engine's micro-batching: keeping one rule in
    one place is what bounds the number of distinct compiled graphs to
    O(log N) across every caller at once.
    """
    target = floor
    while target < n:
        target *= 2
    return target


def pad_rows_pow2(x: jnp.ndarray, floor: int = 8) -> jnp.ndarray:
    """Zero-pad leading rows up to pow2_bucket(n): bounds the number of
    distinct compiled shapes to O(log n) across varying row counts."""
    n = x.shape[0]
    target = pow2_bucket(n, floor)
    if target == n:
        return x
    widths = ((0, target - n),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, widths)


def padded_take(x: jnp.ndarray, rows: np.ndarray, floor: int = 8
                ) -> jnp.ndarray:
    """Gather `rows` of x into a pow2_bucket-padded device matrix (pad
    slots replicate row 0 — callers mask them via traced valid counts).
    The one gather idiom behind the index store/band views."""
    perm = np.zeros(pow2_bucket(len(rows), floor), np.int64)
    perm[: len(rows)] = rows
    return jnp.take(x, jnp.asarray(perm), axis=0)


def np_popcount_rows(words: np.ndarray) -> np.ndarray:
    """NumPy twin of popcount_rows for host-side planning (dedup weight
    ordering, index band layout): (N, w) int32 -> (N,) int64."""
    if words.size == 0:
        return np.zeros(words.shape[0], np.int64)
    return np.unpackbits(
        np.ascontiguousarray(words).view(np.uint8), axis=1).sum(
            axis=1, dtype=np.int64)


def np_pack_bits(bits: np.ndarray) -> np.ndarray:
    """NumPy twin of pack_bits for host-side pipelines (dedup, tests)."""
    *lead, d = bits.shape
    w = packed_width(d)
    pad = w * LANE_BITS - d
    if pad:
        bits = np.concatenate(
            [bits, np.zeros((*lead, pad), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(*lead, w, LANE_BITS).astype(np.uint32)
    shifts = np.arange(LANE_BITS, dtype=np.uint32)
    return np.sum(bits << shifts, axis=-1, dtype=np.uint32).astype(np.int32)
