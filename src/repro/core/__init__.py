"""Core library: the paper's contribution (Cabin + Cham) and its substrate.

Public API:
    CabinParams, sketch_dense, sketch_sparse, binem, binsketch   (cabin)
    cham, cham_matrix, binhamming, inner/cosine/jaccard_estimate (cham)
    sketch_dim, theorem2_bound                                   (theory)
    pack_bits, unpack_bits, popcount_rows, packed_hamming        (packing)
    threshold_pairs, argmin_rows, topk_rows(_banded), rowsum     (allpairs)

The query-shaped entry points over a PERSISTENT collection — SketchStore,
BandedLayout, QueryEngine (repro.index) and ClusterIndex (repro.cluster) —
are re-exported here lazily (PEP 562) so `from repro.core import
QueryEngine` works without importing the index subsystem (which itself
imports repro.core) at package-init time.
"""

from repro.core.allpairs import (  # noqa: F401
    argmin_rows,
    prune_factor,
    prune_score_host,
    rowsum,
    threshold_pairs,
    topk_rows,
    topk_rows_banded,
)

from repro.core.cabin import (  # noqa: F401
    CabinParams,
    binem,
    binsketch,
    sketch_dense,
    sketch_dense_jit,
    sketch_sparse,
    sketch_sparse_jit,
)
from repro.core.cham import (  # noqa: F401
    binhamming,
    binhamming_from_stats,
    cham,
    cham_matrix,
    cosine_estimate,
    density_estimate,
    hamming_matrix_exact,
    inner_estimate,
    jaccard_estimate,
)
from repro.core.packing import (  # noqa: F401
    np_popcount_rows,
    pack_bits,
    packed_hamming,
    packed_inner,
    packed_width,
    popcount32,
    popcount_rows,
    pow2_bucket,
    unpack_bits,
)
from repro.core.theory import sketch_dim, theorem2_bound  # noqa: F401

# repro.index / repro.cluster entry points, resolved lazily to break the
# import cycle (both import repro.core at module load).
_INDEX_EXPORTS = ("SketchStore", "BandedLayout", "TieredLayout",
                  "QueryEngine")
_CLUSTER_EXPORTS = ("ClusterIndex",)


def __getattr__(name):
    if name in _INDEX_EXPORTS:
        from repro import index as _index

        return getattr(_index, name)
    if name in _CLUSTER_EXPORTS:
        from repro import cluster as _cluster

        return getattr(_cluster, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
