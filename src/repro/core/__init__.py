"""Core library: the paper's contribution (Cabin + Cham) and its substrate.

Public API:
    CabinParams, sketch_dense, sketch_sparse, binem, binsketch   (cabin)
    cham, cham_matrix, binhamming, inner/cosine/jaccard_estimate (cham)
    sketch_dim, theorem2_bound                                   (theory)
    pack_bits, unpack_bits, popcount_rows, packed_hamming        (packing)
    threshold_pairs, argmin_rows, topk_rows, rowsum              (allpairs)
"""

from repro.core.allpairs import (  # noqa: F401
    argmin_rows,
    rowsum,
    threshold_pairs,
    topk_rows,
)

from repro.core.cabin import (  # noqa: F401
    CabinParams,
    binem,
    binsketch,
    sketch_dense,
    sketch_dense_jit,
    sketch_sparse,
    sketch_sparse_jit,
)
from repro.core.cham import (  # noqa: F401
    binhamming,
    binhamming_from_stats,
    cham,
    cham_matrix,
    cosine_estimate,
    density_estimate,
    hamming_matrix_exact,
    inner_estimate,
    jaccard_estimate,
)
from repro.core.packing import (  # noqa: F401
    pack_bits,
    packed_hamming,
    packed_inner,
    packed_width,
    popcount32,
    popcount_rows,
    unpack_bits,
)
from repro.core.theory import sketch_dim, theorem2_bound  # noqa: F401
