"""Clustering-quality metrics from the paper (Section 3.2): purity, NMI, ARI.

NumPy implementations (host-side evaluation, not in the jit path).
"""

from __future__ import annotations

import numpy as np


def _contingency(truth: np.ndarray, pred: np.ndarray) -> np.ndarray:
    kt = int(truth.max()) + 1
    kp = int(pred.max()) + 1
    table = np.zeros((kt, kp), dtype=np.int64)
    np.add.at(table, (truth, pred), 1)
    return table


def purity(truth: np.ndarray, pred: np.ndarray) -> float:
    table = _contingency(truth, pred)
    return float(table.max(axis=0).sum() / len(truth))


def nmi(truth: np.ndarray, pred: np.ndarray) -> float:
    """Normalised mutual information (arithmetic-mean normalisation)."""
    table = _contingency(truth, pred).astype(np.float64)
    m = table.sum()
    pij = table / m
    pi = pij.sum(axis=1, keepdims=True)
    pj = pij.sum(axis=0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        terms = pij * np.log(pij / (pi * pj))
    mi = np.nansum(terms)

    def ent(p):
        p = p[p > 0]
        return -np.sum(p * np.log(p))

    denom = 0.5 * (ent(pi.ravel()) + ent(pj.ravel()))
    return float(mi / denom) if denom > 0 else 0.0


def ari(truth: np.ndarray, pred: np.ndarray) -> float:
    table = _contingency(truth, pred)
    a = table.sum(axis=1)
    b = table.sum(axis=0)
    m = len(truth)

    def c2(x):
        x = x.astype(np.float64)
        return (x * (x - 1) / 2.0).sum()

    sum_ij = c2(table.ravel())
    sum_a = c2(a)
    sum_b = c2(b)
    total = m * (m - 1) / 2.0
    expected = sum_a * sum_b / total if total else 0.0
    max_index = 0.5 * (sum_a + sum_b)
    denom = max_index - expected
    return float((sum_ij - expected) / denom) if denom else 0.0
