"""Theoretical quantities from the paper: sketch dimension + error bounds."""

from __future__ import annotations

import math


def sketch_dim(s: int, delta: float = 0.1) -> int:
    """Paper's dimension choice d = s * sqrt(s/2 * ln(6/delta)).

    s is an upper bound on the DENSITY (# non-missing features) of the data;
    note d is independent of the original dimension n.
    """
    if s <= 0:
        raise ValueError("density bound s must be positive")
    return max(8, int(math.ceil(s * math.sqrt(s / 2.0 * math.log(6.0 / delta)))))


def theorem2_bound(s: int, delta: float = 0.1) -> float:
    """Theorem 2 additive error: |Cham - HD| <= 11 sqrt(s ln(7/delta)) w.p. 1-delta."""
    return 11.0 * math.sqrt(s * math.log(7.0 / delta))


def lemma1_tail(a: int, eps: float) -> float:
    """Lemma 1(c): Pr[|a' - a/2| >= eps] <= exp(-2 eps^2 / a)."""
    return math.exp(-2.0 * eps * eps / max(a, 1))


def lemma2_tail(hd: int, eps: float) -> float:
    """Lemma 2(b): Pr[|HD(u',v') - HD(u,v)/2| > eps] <= exp(-2 eps^2 / HD)."""
    return math.exp(-2.0 * eps * eps / max(hd, 1))


def theorem1_accuracy(s: int, delta: float = 0.1) -> float:
    """BinSketch Thm 1 inner-product accuracy O(sqrt(s ln 1/delta))."""
    return math.sqrt(s * math.log(1.0 / delta))
