"""Theoretical quantities from the paper: sketch dimension + error bounds."""

from __future__ import annotations

import math


def sketch_dim(s: int, delta: float = 0.1) -> int:
    """Paper's dimension choice d = s * sqrt(s/2 * ln(6/delta)).

    s is an upper bound on the DENSITY (# non-missing features) of the data;
    note d is independent of the original dimension n.
    """
    if s <= 0:
        raise ValueError("density bound s must be positive")
    return max(8, int(math.ceil(s * math.sqrt(s / 2.0 * math.log(6.0 / delta)))))


def max_density_for_dim(d: int, delta: float = 0.1) -> int:
    """Largest density bound s whose paper-prescribed dimension fits in d —
    the inverse of `sketch_dim`, monotone in s.  A serving index built at
    sketch dimension d keeps its Theorem 1/2 guarantees only while observed
    row density stays <= this value; crossing it is the drift signal that
    triggers a spec migration (index/migrate.py).
    """
    if d < 8:
        raise ValueError("sketch dimension must be >= 8")
    lo, hi = 1, 2
    while sketch_dim(hi, delta) <= d:
        hi *= 2
    while lo < hi:  # invariant: sketch_dim(lo) <= d < sketch_dim(hi + 1)
        mid = (lo + hi + 1) // 2
        if sketch_dim(mid, delta) <= d:
            lo = mid
        else:
            hi = mid - 1
    return lo


def theorem2_bound(s: int, delta: float = 0.1) -> float:
    """Theorem 2 additive error: |Cham - HD| <= 11 sqrt(s ln(7/delta)) w.p. 1-delta."""
    return 11.0 * math.sqrt(s * math.log(7.0 / delta))


def lemma1_tail(a: int, eps: float) -> float:
    """Lemma 1(c): Pr[|a' - a/2| >= eps] <= exp(-2 eps^2 / a)."""
    return math.exp(-2.0 * eps * eps / max(a, 1))


def lemma2_tail(hd: int, eps: float) -> float:
    """Lemma 2(b): Pr[|HD(u',v') - HD(u,v)/2| > eps] <= exp(-2 eps^2 / HD)."""
    return math.exp(-2.0 * eps * eps / max(hd, 1))


def theorem1_accuracy(s: int, delta: float = 0.1) -> float:
    """BinSketch Thm 1 inner-product accuracy O(sqrt(s ln 1/delta))."""
    return math.sqrt(s * math.log(1.0 / delta))
