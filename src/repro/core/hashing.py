"""Stateless integer hash functions used by Cabin / BinSketch / CabinEmbed.

The paper uses "uniformly random mappings" psi and pi.  A production system
cannot store a table of n random values for n ~ 1.3M features across hosts, so
we use stateless mixing hashes keyed by a 32-bit seed: every host, restart, and
shard derives identical mappings from the seed alone.  splitmix32-style
finalizers are 2-universal-grade in practice and pass our uniformity tests.

All functions are pure jnp on int32/uint32 and run unchanged inside Pallas
kernel bodies (no gather, no tables).
"""

from __future__ import annotations

import jax.numpy as jnp

# Odd multiplicative constants (splitmix64 / murmur3 finalizer family,
# truncated to 32 bits).  Kept as PYTHON ints and wrapped with jnp.uint32(...)
# inside each traced function: module-level device arrays would be captured
# as constants by Pallas kernel traces, which Pallas rejects.
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_M3 = 0x9E3779B9  # golden-ratio increment


def _as_u32(x) -> jnp.ndarray:
    if isinstance(x, int):
        return jnp.uint32(x & 0xFFFFFFFF)
    return jnp.asarray(x).astype(jnp.uint32)


def mix32(x) -> jnp.ndarray:
    """murmur3 fmix32: bijective avalanche mixer on uint32."""
    x = _as_u32(x)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def hash_u32(x, seed) -> jnp.ndarray:
    """Seeded hash of one uint32 stream."""
    return mix32(_as_u32(x) + mix32(_as_u32(seed) * jnp.uint32(_M3)))


def hash2_u32(x, y, seed) -> jnp.ndarray:
    """Seeded hash of a pair (x, y) — used for psi(attribute, category)."""
    hx = hash_u32(x, seed)
    return mix32(hx ^ (_as_u32(y) * jnp.uint32(_M3) + (hx >> 7)))


def psi_bits(attr_idx, categories, seed) -> jnp.ndarray:
    """The paper's category mapping psi: (attribute i, category a) -> {0,1}.

    psi(i, 0) = 0 by construction (missing features stay 0); for a != 0 the
    bit is an independent fair coin per (i, a) pair, which is exactly what the
    Lemma 2 independence argument needs (see DESIGN.md section 1.1).
    """
    bits = hash2_u32(attr_idx, categories, seed) & jnp.uint32(1)
    return jnp.where(_as_u32(categories) == 0, jnp.uint32(0), bits).astype(jnp.int32)


def pi_buckets(attr_idx, d: int, seed) -> jnp.ndarray:
    """The paper's attribute mapping pi: {0..n-1} -> {0..d-1}.

    Uses the high-entropy top bits via a 64-bit-free 'fast range' alternative:
    (hash * d) >> 32 computed in uint64-free form is awkward on int32-only
    Pallas, so we use modulo of the mixed hash; bias is <= d / 2^32 which is
    negligible for d <= 2^20.
    """
    return (hash_u32(attr_idx, seed) % jnp.uint32(d)).astype(jnp.int32)


def uniform01(x, seed) -> jnp.ndarray:
    """Hash to a float in [0, 1) — used by baselines (e.g. SimHash planes)."""
    return hash_u32(x, seed).astype(jnp.float32) * (1.0 / 4294967296.0)


def rademacher(x, seed) -> jnp.ndarray:
    """Hash to {-1, +1} float32."""
    return jnp.where(hash_u32(x, seed) & jnp.uint32(1), 1.0, -1.0).astype(jnp.float32)
