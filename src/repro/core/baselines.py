"""Baseline sketching algorithms from the paper's experimental section.

Each baseline compresses the BinEm binary embedding u' in {0,1}^n (the paper
applies BCS and H-LSH "on a BinEm embedding"; FH/SimHash likewise operate on
the binary representation) and provides a Hamming-distance estimator so all
methods are scored on the same RMSE task (paper Fig. 3 / Fig. 5).

  * BCS    — parity (XOR) aggregation per bucket [Pratap et al., BigData'18].
             Estimator: each differing coordinate flips one random bucket's
             parity, so E[HD(y_u,y_v)] = d(1-(1-2/d)^h)/2 and
             h_hat = log(1 - 2 HD_s / d) / log(1 - 2/d).
  * H-LSH  — coordinate sampling [Gionis-Indyk-Motwani'99 as implemented in
             the paper]: sample d coords, h_hat = HD_sampled * n / d.
  * FH     — feature hashing [Weinberger et al.'09]: y[j] = sum sigma(i) x_i
             over bucket j; <y_u, y_v> is an unbiased estimator of <u',v'>;
             h_hat = |u'| + |v'| - 2 <y_u, y_v> (densities stored as two
             scalars per point, favouring the baseline — see DESIGN.md 7).
  * SimHash— signed random projections [Charikar'02]: sign bits of hashed
             Rademacher projections; collision fraction -> angle -> inner
             product (with stored norms) -> Hamming.

All baselines are stateless-hash based (same infrastructure as Cabin) so the
speed comparison in benchmarks is apples-to-apples.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import hashing

_EPS = 1e-9


@dataclass(frozen=True)
class BaselineParams:
    n_dims: int
    sketch_dim: int
    seed: int = 0


# ---------------------------------------------------------------------------
# BCS: parity buckets
# ---------------------------------------------------------------------------


def bcs_sketch(p: BaselineParams, bits: jnp.ndarray) -> jnp.ndarray:
    """(..., n) {0,1} -> (..., d) {0,1} parity sketch."""
    n = bits.shape[-1]
    buckets = hashing.pi_buckets(jnp.arange(n, dtype=jnp.uint32), p.sketch_dim,
                                 p.seed + 101)
    flat = bits.reshape(-1, n)
    out = jnp.zeros((flat.shape[0], p.sketch_dim), dtype=jnp.int32)
    out = out.at[:, buckets].add(flat.astype(jnp.int32), mode="drop")
    return (out & 1).reshape(*bits.shape[:-1], p.sketch_dim)


def bcs_estimate(p: BaselineParams, yu: jnp.ndarray, yv: jnp.ndarray) -> jnp.ndarray:
    d = p.sketch_dim
    hs = jnp.sum(yu != yv, axis=-1).astype(jnp.float32)
    ratio = jnp.clip(1.0 - 2.0 * hs / d, _EPS, 1.0)
    return jnp.log(ratio) / jnp.log1p(-2.0 / d)


# ---------------------------------------------------------------------------
# Hamming-LSH: coordinate sampling
# ---------------------------------------------------------------------------


def hlsh_indices(p: BaselineParams) -> jnp.ndarray:
    """d sampled coordinates (with replacement, hash-derived)."""
    j = jnp.arange(p.sketch_dim, dtype=jnp.uint32)
    return (hashing.hash_u32(j, p.seed + 202) % jnp.uint32(p.n_dims)).astype(jnp.int32)


def hlsh_sketch(p: BaselineParams, bits: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(bits, hlsh_indices(p), axis=-1)


def hlsh_estimate(p: BaselineParams, yu: jnp.ndarray, yv: jnp.ndarray) -> jnp.ndarray:
    hs = jnp.sum(yu != yv, axis=-1).astype(jnp.float32)
    return hs * (p.n_dims / p.sketch_dim)


# ---------------------------------------------------------------------------
# Feature hashing
# ---------------------------------------------------------------------------


def fh_sketch(p: BaselineParams, bits: jnp.ndarray) -> jnp.ndarray:
    """(..., n) {0,1} -> (..., d) int32 signed-sum sketch."""
    n = bits.shape[-1]
    idx = jnp.arange(n, dtype=jnp.uint32)
    buckets = hashing.pi_buckets(idx, p.sketch_dim, p.seed + 303)
    signs = jnp.where(hashing.hash_u32(idx, p.seed + 404) & jnp.uint32(1), 1, -1)
    flat = bits.reshape(-1, n).astype(jnp.int32) * signs
    out = jnp.zeros((flat.shape[0], p.sketch_dim), dtype=jnp.int32)
    out = out.at[:, buckets].add(flat, mode="drop")
    return out.reshape(*bits.shape[:-1], p.sketch_dim)


def fh_estimate(
    p: BaselineParams, yu: jnp.ndarray, yv: jnp.ndarray,
    wu: jnp.ndarray, wv: jnp.ndarray,
) -> jnp.ndarray:
    inner = jnp.sum(yu * yv, axis=-1).astype(jnp.float32)
    return wu + wv - 2.0 * inner


# ---------------------------------------------------------------------------
# SimHash
# ---------------------------------------------------------------------------


def simhash_sketch(p: BaselineParams, bits: jnp.ndarray) -> jnp.ndarray:
    """d sign bits of Rademacher projections, computed in d-sized chunks.

    Projection matrix entries are hash-derived on the fly: R[j, i] in {-1,+1}.
    """
    n = bits.shape[-1]
    flat = bits.reshape(-1, n).astype(jnp.float32)

    def one_plane(j):
        r = hashing.rademacher(
            jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(j) * jnp.uint32(n),
            p.seed + 505,
        )
        return (flat @ r) >= 0.0

    planes = jax.vmap(one_plane)(jnp.arange(p.sketch_dim, dtype=jnp.uint32))
    out = jnp.transpose(planes).astype(jnp.int32)
    return out.reshape(*bits.shape[:-1], p.sketch_dim)


def simhash_estimate(
    p: BaselineParams, yu: jnp.ndarray, yv: jnp.ndarray,
    wu: jnp.ndarray, wv: jnp.ndarray,
) -> jnp.ndarray:
    frac = jnp.mean((yu != yv).astype(jnp.float32), axis=-1)
    theta = jnp.pi * frac
    inner = jnp.cos(theta) * jnp.sqrt(wu * wv)
    return jnp.maximum(wu + wv - 2.0 * inner, 0.0)
