"""Streaming all-pairs engine over packed Cabin sketches.

Every O(N^2) consumer in this repo (dedup candidate generation, k-mode
assignment, medoid updates) used to materialise full (N, M) Cham/Hamming
matrices and sync them to host block by block.  This module replaces that
with device-resident tiled passes: the distance tile is computed, REDUCED,
and discarded inside a single fused `lax` loop, so peak memory is
O(N * block) and exactly one host transfer happens per query — the compact
result.

This is a BATCH engine: it consumes whole matrices of packed sketches.  The
query-shaped API over a persistent, incrementally updated collection lives
in `repro.index` (SketchStore / QueryEngine, DESIGN.md section 8), which
drives the reductions below — `topk_rows` for k-NN serving and
`threshold_pairs` for radius queries — over its device-resident buffers and
is re-exported from `repro.core` for discoverability.

Reductions provided:

  threshold_pairs(a, b, d, threshold)  -> compact (i, j) candidate list of
                                          pairs with dist < threshold
                                          (dedup candidate generation)
  argmin_rows(a, b, d)                 -> per-row nearest column + distance
                                          (k-mode assignment)
  topk_rows(a, b, d, k)                -> per-row k smallest distances +
                                          indices (neighbour queries)
  rowsum(a, b, d)                      -> per-row total distance
                                          (k-medoid centre updates)

Distance semantics are IDENTICAL to repro.core.cham.cham_matrix /
hamming_matrix_exact: the pairwise statistics (wa, wb, inner) are exact
integers however the tile is computed, and the Cham estimator is elementwise
on those integers, so results are bit-identical to the dense reference
regardless of tiling — this is what lets data.dedup swap engines without
changing a single DedupResult.

Tile backends (`mode`):
  * "popcount" — the jnp SWAR popcount contraction (repro.core.cham): the
                 contraction depth is d/32 packed words, which XLA CPU
                 vectorises well — the default off-TPU.
  * "matmul"   — unpack the packed words to {0,1} float32 and take the tile
                 inner product as a GEMM.  Counts <= d < 2^24 are exactly
                 representable in float32, so this is EXACT too; it does
                 32x more raw MACs than "popcount" but wins on hardware
                 with idle matmul units.
  * "pallas"   — the repro.kernels.hamming pair_stats TPU kernel.
  * None       — auto: "pallas" on TPU, "popcount" elsewhere.

Metrics: "cham" (estimated HD of the original categorical vectors, float32)
and "hamming" (exact HD between packed binary rows, computed as
wa + wb - 2*inner, returned as float32 so both metrics share one code path).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.cham import binhamming_from_stats


def _auto_mode(mode: str | None) -> str:
    if mode is not None:
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "popcount"


# Slack added to every weight-band prune test: distances are O(10..1000),
# cross-graph float noise between the bound and the estimator's internals is
# O(1e-3), so the margin makes the prune sound without costing selectivity.
PRUNE_MARGIN = 0.05


def prune_factor(metric: str) -> float:
    """`dist(i, j) >= prune_factor * |s_i - s_j|` for the per-row prune
    score s (see prune_score_host): 2 for cham, 1 for exact hamming."""
    if metric == "cham":
        return 2.0
    if metric == "hamming":
        return 1.0
    raise ValueError(f"unknown metric {metric!r}")


def _tile_inner(a_blk: jnp.ndarray, b_blk: jnp.ndarray, d: int, mode: str
                ) -> jnp.ndarray:
    """Exact pairwise <a_i, b_j> bit inner products for one tile."""
    if mode == "matmul":
        ua = packing.unpack_bits(a_blk, d).astype(jnp.float32)
        ub = packing.unpack_bits(b_blk, d).astype(jnp.float32)
        return jnp.dot(ua, ub.T,
                       preferred_element_type=jnp.float32).astype(jnp.int32)
    if mode == "popcount":
        return jnp.sum(
            packing.popcount32(a_blk[:, None, :] & b_blk[None, :, :]), axis=-1
        )
    if mode == "pallas":
        from repro.kernels.hamming import kernel as _hk

        inner, _ = _hk.pair_stats(a_blk, b_blk, op_ham=False,
                                  interpret=jax.default_backend() != "tpu")
        return inner
    raise ValueError(f"unknown tile mode {mode!r}")


def _tile_dist(a_blk: jnp.ndarray, b_blk: jnp.ndarray, d: int, metric: str,
               mode: str) -> jnp.ndarray:
    """One (bm, bn) float32 distance tile; bit-identical to cham_matrix /
    hamming_matrix_exact on the same rows."""
    wa = packing.popcount_rows(a_blk)
    wb = packing.popcount_rows(b_blk)
    inner = _tile_inner(a_blk, b_blk, d, mode)
    if metric == "cham":
        return 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)
    if metric == "hamming":
        return (wa[:, None] + wb[None, :] - 2 * inner).astype(jnp.float32)
    raise ValueError(f"unknown metric {metric!r}")


def _pad_rows(x: jnp.ndarray, block: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % block
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


_pow2_rows = packing.pad_rows_pow2


# ---------------------------------------------------------------------------
# threshold candidate extraction (dedup)
# ---------------------------------------------------------------------------


def _append_hits(carry, flat, n_hits, i0, j0, width, capacity):
    """Append this tile's hits to the (buf_i, buf_j, count) carry.

    Buffers carry `capacity` extra slack slots: each tile appends with one
    dynamic_update_slice of length `capacity` starting at the running count;
    slots past the tile's hit count hold garbage but are overwritten by the
    next tile (its window starts exactly at the new count) and never escape
    the final [:count] slice.  Rank r's hit lives at the first flat index
    with cumsum == r: a log(tile) binary-search gather per output slot, far
    cheaper than scattering the whole tile into the buffer.  Tiles with no
    candidates skip extraction entirely.
    """

    def extract(c):
        bi, bj, cnt = c
        csum = jnp.cumsum(flat)
        ranks = jnp.arange(1, capacity + 1, dtype=csum.dtype)
        pos = jnp.searchsorted(csum, ranks)
        pos = jnp.minimum(pos, flat.shape[0] - 1)
        gi_v = (i0 + pos // width).astype(jnp.int32)
        gj_v = (j0 + pos % width).astype(jnp.int32)
        off = jnp.minimum(cnt, capacity)
        bi = jax.lax.dynamic_update_slice(bi, gi_v, (off,))
        bj = jax.lax.dynamic_update_slice(bj, gj_v, (off,))
        return bi, bj, cnt + n_hits

    return jax.lax.cond(
        n_hits > 0, extract, lambda c: (c[0], c[1], c[2] + n_hits), carry)


def _prune_scores(x_p, n_valid, d, metric):
    """Per-row lower-bound score s with the property
    dist(i, j) >= factor * |s_i - s_j| (factor 2 for cham, 1 for hamming):
    cham >= 2|a_hat - b_hat| because the union estimate u_hat >= max(a_hat,
    b_hat); exact HD >= |wu - wv|.  Padded rows get (+inf, -inf) so fully
    padded tiles always prune."""
    w = packing.popcount_rows(x_p).astype(jnp.float32)
    if metric == "cham":
        from repro.core.cham import density_estimate

        s = density_estimate(w, d)
    else:
        s = w
    valid = jnp.arange(x_p.shape[0]) < n_valid
    s_min = jnp.where(valid, s, jnp.inf)
    s_max = jnp.where(valid, s, -jnp.inf)
    return s_min, s_max


@functools.partial(
    jax.jit,
    static_argnames=("block", "capacity", "symmetric", "metric", "mode", "d"),
)
def _threshold_pairs_impl(a_p, b_p, offsets, threshold, n, m, *, block,
                          capacity, symmetric, metric, mode, d):
    # n and m are TRACED valid-row counts: repro.index pads its query batches
    # and store gathers to power-of-two shapes, so the compile cache must key
    # on the bucketed shapes only, not on the live row counts.
    n_tiles = offsets.shape[0]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    factor = prune_factor(metric)
    # weight-band tile prune: per-block score ranges; a tile whose blocks'
    # score intervals are further apart than threshold/factor cannot contain
    # a candidate, so its distance tile is never computed (PRUNE_MARGIN
    # absorbs float noise between this bound and the estimator's internals).
    sa_min, sa_max = _prune_scores(a_p, n, d, metric)
    sb_min, sb_max = _prune_scores(b_p, m, d, metric)
    blk_a_min = sa_min.reshape(-1, block).min(axis=1)
    blk_a_max = sa_max.reshape(-1, block).max(axis=1)
    blk_b_min = sb_min.reshape(-1, block).min(axis=1)
    blk_b_max = sb_max.reshape(-1, block).max(axis=1)
    buf_len = 2 * capacity  # slack slots for _append_hits windows

    def body(t, carry):
        i0 = offsets[t, 0]
        j0 = offsets[t, 1]
        ib = i0 // block
        jb = j0 // block
        gap = jnp.maximum(
            jnp.maximum(blk_b_min[jb] - blk_a_max[ib],
                        blk_a_min[ib] - blk_b_max[jb]), 0.0)
        prunable = factor * gap >= threshold + PRUNE_MARGIN

        def compute(carry):
            a_blk = jax.lax.dynamic_slice(a_p, (i0, 0), (block, a_p.shape[1]))
            b_blk = jax.lax.dynamic_slice(b_p, (j0, 0), (block, b_p.shape[1]))
            dist = _tile_dist(a_blk, b_blk, d, metric, mode)
            gi = i0 + row_iota
            gj = j0 + col_iota
            mask = (dist < threshold) & (gi < n) & (gj < m)
            if symmetric:
                mask &= gi < gj
            flat = mask.ravel().astype(jnp.int32)
            return _append_hits(carry, flat, jnp.sum(flat), i0, j0, block,
                                capacity)

        return jax.lax.cond(prunable, lambda c: c, compute, carry)

    buf_i = jnp.full((buf_len,), -1, jnp.int32)
    buf_j = jnp.full((buf_len,), -1, jnp.int32)
    count = jnp.int32(0)
    buf_i, buf_j, count = jax.lax.fori_loop(
        0, n_tiles, body, (buf_i, buf_j, count))
    return buf_i, buf_j, count


@functools.partial(
    jax.jit,
    static_argnames=("n", "block", "width", "capacity", "metric", "mode",
                     "d", "logfree"),
)
def _banded_pairs_impl(a_pp, threshold, *, n, block, width, capacity, metric,
                       mode, d, logfree):
    """Symmetric weight-sorted fast path: for each row block, all candidate
    columns j > i live in [i0, i0 + width) — one (block, width) strip per
    row block instead of a tile grid, so the loop has few, large, well-
    vectorised iterations.

    `logfree` (cham, no saturated sketches) replaces the per-pair log-based
    estimator with the exactly equivalent inner-product test

        cham(u, v) < t  <=>  st > wa + wb - d + d * D^(t/4) * ra * rb,
        ra = sqrt(1 - wa/d) = D^(a_hat/2),

    obtained by inverting the monotone union estimate u_hat: the per-pair
    work drops from three logarithm evaluations to one multiply.  Requires
    max weight < d (else the estimator's log clamping has no inner-product
    twin; the caller checks and falls back)."""
    n_blocks = (n + block - 1) // block
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (block, width), 0)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (block, width), 1)
    buf_len = 2 * capacity
    w_rows = packing.popcount_rows(a_pp).astype(jnp.float32)
    if logfree:
        log_d = jnp.log1p(-1.0 / jnp.float32(d))
        k_thr = jnp.float32(d) * jnp.exp(log_d * threshold * 0.25)
        radii = jnp.sqrt(jnp.maximum(1.0 - w_rows / d, 0.0))

    def body(ib, carry):
        i0 = ib * block
        a_blk = jax.lax.dynamic_slice(a_pp, (i0, 0), (block, a_pp.shape[1]))
        strip = jax.lax.dynamic_slice(a_pp, (i0, 0), (width, a_pp.shape[1]))
        gi = i0 + row_iota
        gj = i0 + col_iota
        if logfree:
            inner = _tile_inner(a_blk, strip, d, mode).astype(jnp.float32)
            wa = jax.lax.dynamic_slice(w_rows, (i0,), (block,))
            wb = jax.lax.dynamic_slice(w_rows, (i0,), (width,))
            ra = jax.lax.dynamic_slice(radii, (i0,), (block,))
            rb = jax.lax.dynamic_slice(radii, (i0,), (width,))
            bound = (wa[:, None] + wb[None, :] - d
                     + k_thr * ra[:, None] * rb[None, :])
            mask = (inner > bound) & (gi < gj) & (gj < n)
        else:
            dist = _tile_dist(a_blk, strip, d, metric, mode)  # (block, width)
            mask = (dist < threshold) & (gi < gj) & (gj < n)
        flat = mask.ravel().astype(jnp.int32)
        return _append_hits(carry, flat, jnp.sum(flat), i0, i0, width,
                            capacity)

    buf_i = jnp.full((buf_len,), -1, jnp.int32)
    buf_j = jnp.full((buf_len,), -1, jnp.int32)
    count = jnp.int32(0)
    buf_i, buf_j, count = jax.lax.fori_loop(
        0, n_blocks, body, (buf_i, buf_j, count))
    return buf_i, buf_j, count


# pad sentinel for k-best candidate lists: a (inf, KBEST_KEY_PAD) entry
# sorts after every real (value, key) candidate in kbest_lex_merge
KBEST_KEY_PAD = np.iinfo(np.int64).max


def kbest_lex_merge(k: int, values: np.ndarray, keys: np.ndarray,
                    *extras: np.ndarray) -> tuple[np.ndarray, ...]:
    """Exact (value, key)-lexicographic k-best over per-row candidate
    lists: `values`/`keys`/`extras` are (Q, C >= k) concatenated candidate
    columns; returns each reduced to its k best columns, ascending by
    (value, key).  THE one merge rule behind every multi-list top-k in the
    repo — topk_rows_banded's cross-chunk merge and the index's cross-tier
    merge share it, which is what makes their bit-identity with a single
    `topk_rows` scan structural rather than by convention.  Pad candidate
    lists short of k with (np.inf, KBEST_KEY_PAD) entries; they sort after
    any real candidate and survive only if fewer than k real ones exist.
    k must be >= 0 (k = 0 is a valid empty reduction)."""
    if k < 0:
        raise ValueError(f"kbest_lex_merge: k must be >= 0, got {k}")
    order = np.lexsort((keys, values), axis=-1)[:, :k]

    def take(a: np.ndarray) -> np.ndarray:
        return np.take_along_axis(a, order, axis=1)

    return (take(values), take(keys)) + tuple(take(a) for a in extras)


def prune_score_host(weights: np.ndarray, d: int, metric: str) -> np.ndarray:
    """Host twin of _prune_scores for band planning (float64; PRUNE_MARGIN
    absorbs the f32/f64 gap).  Shared with repro.index.bands, which uses the
    same `dist >= prune_factor * |s_i - s_j|` bound to skip whole weight
    bands of its store before any distance tile is computed."""
    if metric == "cham":
        w = weights.astype(np.float64)
        return np.log(np.clip(1.0 - w / d, 1e-9, 1.0)) / np.log1p(-1.0 / d)
    return weights.astype(np.float64)


def _band_width(scores: np.ndarray, n: int, block: int, threshold: float,
                factor: float) -> int:
    """Max strip width so that every j >= i0 + width is prunable for row
    block i0 (columns beyond it satisfy factor*gap >= threshold + margin)."""
    reach = (threshold + PRUNE_MARGIN) / factor
    width = block
    for i0 in range(0, n, block):
        s_hi = scores[min(i0 + block, n) - 1]
        hi = int(np.searchsorted(scores, s_hi + reach, side="left"))
        width = max(width, hi - i0)
    n_pad = ((n + block - 1) // block) * block
    # bucket to a block multiple: fewer recompiles across similar corpora
    return min(((width + block - 1) // block) * block, n_pad)


def threshold_pairs(
    a,
    b=None,
    *,
    d: int,
    threshold: float,
    metric: str = "cham",
    block: int = 256,
    capacity: int | None = None,
    mode: str | None = None,
    sorted_by_weight: bool = False,
    weights: np.ndarray | None = None,
    n_valid: int | None = None,
    m_valid: int | None = None,
) -> np.ndarray:
    """All pairs (i, j) with dist(a[i], b[j]) < threshold, as a compact
    (K, 2) int32 host array.

    b=None scans the upper triangle of a vs itself (i < j) — the dedup case.
    `capacity` bounds the candidate buffer on device; on overflow the pass
    transparently re-runs with doubled capacity (a recompile, so size it
    generously when the duplicate rate is known).

    `n_valid` / `m_valid` declare how many leading rows of a / b are real
    when the caller has padded the arrays to bucketed shapes (repro.index
    pads to powers of two so its query mix reuses a handful of compiled
    graphs); rows past the valid count never produce pairs.  The counts are
    traced, so varying them does NOT recompile.  Asymmetric path only.

    `sorted_by_weight=True` (symmetric only) promises the rows are sorted by
    sketch Hamming weight; the scan then switches to banded strips whose
    width comes from the weight bound dist >= factor*|s_i - s_j| — columns
    outside the band provably cannot be candidates, so total work drops from
    O(N^2/2) to O(N * band).  The banded cham pass also swaps the per-pair
    log estimator for the exactly-equivalent log-free inner-product test
    (see _banded_pairs_impl); it decides knife-edge pairs whose distance
    equals the threshold to within a float ulp by different rounding than
    the log formula, so choose thresholds away from exact distance values
    when bit-stable candidate sets matter.  `weights` optionally passes the
    per-row sketch Hamming weights the caller already has (skips one
    device popcount + host sync).
    """
    symmetric = b is None
    if symmetric and (n_valid is not None or m_valid is not None):
        raise ValueError("n_valid/m_valid require an explicit b "
                         "(asymmetric scan)")
    a = jnp.asarray(a)
    b_arr = a if symmetric else jnp.asarray(b)
    n = a.shape[0] if n_valid is None else n_valid
    m = b_arr.shape[0] if m_valid is None else m_valid
    if not (0 <= n <= a.shape[0] and 0 <= m <= b_arr.shape[0]):
        raise ValueError(f"n_valid/m_valid ({n}, {m}) outside the supplied "
                         f"rows ({a.shape[0]}, {b_arr.shape[0]})")
    if n == 0 or m == 0:
        return np.zeros((0, 2), np.int32)
    # block and capacity are STATIC jit args of the impls: derive block from
    # the (bucketed) array shapes and round capacity to a power of two, so
    # callers whose valid counts drift by a few rows per call (the index
    # engine's radius path under add/remove churn) reuse compiled graphs
    block = max(1, min(block, max(a.shape[0], b_arr.shape[0])))
    if capacity is None:
        capacity = max(4096, 8 * max(n, m))
    capacity = packing.pow2_bucket(capacity)
    mode = _auto_mode(mode)

    def run_with_capacity(run, capacity):
        # overflow -> transparent re-run with a doubled (recompiled) buffer
        while True:
            bi, bj, cnt = run(capacity)
            cnt = int(cnt)
            if cnt <= capacity:
                return np.stack(
                    [np.asarray(bi)[:cnt], np.asarray(bj)[:cnt]], axis=1)
            capacity = packing.pow2_bucket(max(2 * capacity, cnt))

    if symmetric and sorted_by_weight:
        if weights is None:
            weights = np.asarray(packing.popcount_rows(a))
        if np.any(np.diff(weights) < 0):
            raise ValueError("sorted_by_weight=True but rows are not sorted "
                             "by sketch weight")
        scores = prune_score_host(weights, d, metric)
        factor = prune_factor(metric)
        width = _band_width(scores, n, block, threshold, factor)
        n_pad = ((n + block - 1) // block) * block
        a_pp = jnp.pad(a, ((0, n_pad + width - n), (0, 0)))
        # log-free inner-product test needs the estimator unclamped
        logfree = metric == "cham" and int(weights.max(initial=0)) < d
        return run_with_capacity(
            lambda cap: _banded_pairs_impl(
                a_pp, jnp.float32(threshold), n=n, block=block, width=width,
                capacity=cap, metric=metric, mode=mode, d=d, logfree=logfree),
            capacity)

    a_p = _pad_rows(a, block)
    b_p = a_p if symmetric else _pad_rows(b_arr, block)
    nb_a = a_p.shape[0] // block
    nb_b = b_p.shape[0] // block
    if symmetric:
        offs = [(i * block, j * block)
                for i in range(nb_a) for j in range(i, nb_b)]
    else:
        offs = [(i * block, j * block)
                for i in range(nb_a) for j in range(nb_b)]
    offsets = jnp.asarray(offs, dtype=jnp.int32)

    return run_with_capacity(
        lambda cap: _threshold_pairs_impl(
            a_p, b_p, offsets, jnp.float32(threshold), jnp.int32(n),
            jnp.int32(m), block=block, capacity=cap, symmetric=symmetric,
            metric=metric, mode=mode, d=d),
        capacity)


# ---------------------------------------------------------------------------
# row-wise argmin (k-mode assignment)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("block", "metric", "mode", "d"))
def _argmin_rows_impl(a_p, b_p, m, *, block, metric, mode, d):
    # m is a TRACED valid-row count (cf. _rowsum_impl): the k-mode medoid
    # loop calls this with a different member/centre count per cluster per
    # iteration, so the jit cache must key on the (power-of-two bucketed)
    # shapes only — a static m recompiled per cluster size.
    n_tiles = b_p.shape[0] // block

    def body(t, carry):
        best, besti = carry
        j0 = t * block
        b_blk = jax.lax.dynamic_slice(b_p, (j0, 0), (block, b_p.shape[1]))
        dist = _tile_dist(a_p, b_blk, d, metric, mode)  # (n, block)
        col = j0 + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        dist = jnp.where(col < m, dist, jnp.inf)
        tmin = jnp.min(dist, axis=1)
        targ = j0 + jnp.argmin(dist, axis=1).astype(jnp.int32)
        # strict < keeps the FIRST global minimum — matches np.argmin on the
        # full (n, m) matrix, which is what the seed k-mode loop used
        upd = tmin < best
        return jnp.where(upd, tmin, best), jnp.where(upd, targ, besti)

    best = jnp.full((a_p.shape[0],), jnp.inf, jnp.float32)
    besti = jnp.zeros((a_p.shape[0],), jnp.int32)
    return jax.lax.fori_loop(0, n_tiles, body, (best, besti))


def argmin_rows(a, b, *, d: int, metric: str = "cham", block: int = 2048,
                mode: str | None = None, m_valid: int | None = None):
    """Per-row nearest column: returns (indices (N,), distances (N,)) on
    host, streaming over blocks of b.  Tie-break = first minimum, identical
    to np.argmin over the dense matrix.  Both row counts are bucketed to
    powers of two and the valid column count is traced, so repeated calls
    with drifting sizes (the k-mode loops) reuse O(log N) compiled graphs.

    `m_valid` declares how many leading rows of b are real when the caller
    hands over an already pow2-padded block (repro.core.kmode keeps its
    centre block device-resident and padded once, instead of reshaping it
    per iteration); it is traced, so varying it does not recompile."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    n, m = a.shape[0], b.shape[0] if m_valid is None else m_valid
    if not 0 <= m <= b.shape[0]:
        raise ValueError(f"m_valid={m} outside the {b.shape[0]} supplied "
                         "rows")
    a_p = _pow2_rows(a)
    b_p2 = _pow2_rows(b)
    block = max(1, min(block, b_p2.shape[0]))
    b_p = _pad_rows(b_p2, block)
    best, besti = _argmin_rows_impl(a_p, b_p, jnp.int32(m), block=block,
                                    metric=metric, mode=_auto_mode(mode), d=d)
    return np.asarray(besti)[:n], np.asarray(best)[:n]


# ---------------------------------------------------------------------------
# row-wise top-k (neighbour queries)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("k", "block", "metric", "mode", "d"))
def _topk_rows_impl(a, b_p, m, *, k, block, metric, mode, d):
    # m is a TRACED valid-row count (cf. _threshold_pairs_impl): repro.index
    # queries a power-of-two-padded store gather whose live size changes with
    # every add/remove — keying the compile cache on it would recompile per
    # mutation.  Columns past m are masked to +inf and can never be returned.
    n_tiles = b_p.shape[0] // block
    n = a.shape[0]
    kt = min(k, block)  # per-tile survivors: a tile holds `block` candidates

    def body(t, carry):
        vals, idxs = carry  # (n, k) running smallest, (value, index)-sorted
        j0 = t * block
        b_blk = jax.lax.dynamic_slice(b_p, (j0, 0), (block, b_p.shape[1]))
        dist = _tile_dist(a, b_blk, d, metric, mode)  # (n, block)
        col = j0 + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        dist = jnp.where(col < m, dist, jnp.inf)
        # O(k) merge, no (k + block) argsort: top_k of the negated tile
        # keeps its kt smallest (ties -> lower position = lower column), and
        # a second top_k over [carry | survivors] — carry FIRST, so on equal
        # values the earlier (lower-index) entry wins, exactly the stable-
        # argsort tie-break this merge replaced.  Negation is a sign-bit
        # flip, so round-tripping through -x is bit-exact.
        tile_neg, tpos = jax.lax.top_k(-dist, kt)
        tile_i = jnp.take_along_axis(
            jnp.broadcast_to(col, (n, block)), tpos, axis=1)
        cand_v = jnp.concatenate([vals, -tile_neg], axis=1)
        cand_i = jnp.concatenate([idxs, tile_i], axis=1)
        best_neg, bpos = jax.lax.top_k(-cand_v, k)
        return -best_neg, jnp.take_along_axis(cand_i, bpos, axis=1)

    vals = jnp.full((n, k), jnp.inf, jnp.float32)
    idxs = jnp.full((n, k), -1, jnp.int32)
    return jax.lax.fori_loop(0, n_tiles, body, (vals, idxs))


def topk_rows(a, b, k: int, *, d: int, metric: str = "cham",
              block: int = 2048, mode: str | None = None,
              m_valid: int | None = None, pad_k: bool = False):
    """Per-row k nearest columns of b: (indices (N, k), distances (N, k)),
    ascending by distance, streaming over blocks of b.  Ties are broken by
    the LOWER column index (stable merge).  `m_valid` declares how many
    leading rows of b are real when b is padded to a bucketed shape
    (repro.index); it is traced, so varying it does not recompile.

    `pad_k=True` keeps the requested k even when it exceeds the valid row
    count: the surplus tail columns come back as (+inf, -1) padding.  This
    is the small-tier serving mode — k is a STATIC jit argument, so a
    caller whose collection drifts through sizes below k (the index
    engine's delta tier) must NOT let k track the size, or every mutation
    recompiles; with pad_k the compile key stays fixed and the caller
    strips the pads in its own merge.  Forces the jnp tile loop (the
    fused kernel assumes k <= m, and a collection this small never wants
    a kernel launch anyway).

    mode "pallas" routes through the fused repro.kernels.topk_select kernel
    (distance tile + running k-best merge in one VMEM pass — losing columns
    never materialise an f32 row in HBM); the jnp tile loop above is the
    reference the kernel is pinned against."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    m = b.shape[0] if m_valid is None else m_valid
    if not 0 <= m <= b.shape[0]:
        raise ValueError(f"m_valid={m} outside the {b.shape[0]} supplied "
                         "rows")
    if pad_k:
        if k < 0:
            raise ValueError(f"k must be >= 0, got {k}")
        mode = "popcount" if _auto_mode(mode) == "pallas" else mode
    else:
        k = min(k, m)
    if k == 0:
        return (np.zeros((a.shape[0], 0), np.int32),
                np.zeros((a.shape[0], 0), np.float32))
    mode = _auto_mode(mode)
    if mode == "pallas":
        from repro.kernels.topk_select import ops as _topk_ops

        vals, idxs = _topk_ops.topk_select(a, b, k, d=d, metric=metric,
                                           m_valid=m, bn=block,
                                           use_pallas=True)
        return np.asarray(idxs), np.asarray(vals)
    block = max(1, min(block, b.shape[0]))
    b_p = _pad_rows(b, block)
    vals, idxs = _topk_rows_impl(a, b_p, jnp.int32(m), k=k, block=block,
                                 metric=metric, mode=mode, d=d)
    return np.asarray(idxs), np.asarray(vals)


def topk_rows_banded(a, b, k: int, *, d: int, q_scores: np.ndarray,
                     band_lo: np.ndarray, band_hi: np.ndarray,
                     band_rows: int, n_valid: int, metric: str = "cham",
                     block: int = 2048, mode: str | None = None,
                     order_by: np.ndarray | None = None,
                     q_valid: int | None = None,
                     alive: np.ndarray | None = None,
                     stats_out: dict | None = None,
                     deadline=None,
                     init_kth: np.ndarray | None = None):
    """Progressive band-expansion top-k over weight-banded rows.

    `b` holds `n_valid` rows sorted by ascending prune score and cut into
    contiguous bands of `band_rows` rows whose host score intervals are
    `[band_lo[i], band_hi[i]]` (repro.index.BandedLayout layout).  Bands are
    visited in ascending prune-score distance from the query batch; after
    each round the running k-th best distance is compared against the weight
    bound of every unvisited band, and the scan STOPS with an exactness
    certificate once

        prune_factor(metric) * gap(q, band) >= kth(q) + PRUNE_MARGIN

    holds for every query and unvisited band: any unseen row is then
    provably strictly farther than the current k-th neighbour (the strict
    margin also settles knife-edge ties), so the answer equals the full
    scan's.  Visited chunks double in row count, and each chunk is gathered
    to a power-of-two shape, so one query compiles O(log N) graphs and
    touches O(answer neighbourhood) rows instead of O(N).

    `order_by` assigns each row the tie-break key the results must honour
    (repro.index passes external ids; default: row position).  Within each
    chunk columns are laid out in ascending key order, so the tile merge's
    lower-column tie-break IS the key tie-break, and the host-side merge
    across chunks is an exact (value, key)-lexicographic k-best.

    `alive` optionally masks rows out (bool over the n_valid sorted rows —
    the tiered layout's tombstones): dead rows are dropped on host before
    each chunk gather, so they cost no device work and can never be
    returned.  The band score intervals are computed over the UNMASKED
    rows, which makes them conservative supersets for the alive subset —
    the certificate under-prunes but stays sound, and the result equals
    `topk_rows` over just the alive rows in key order.

    `deadline` (any object with an `expired` property — repro.serve's
    Deadline) turns the walk into a budgeted one: between rounds, an
    expired deadline stops band expansion where the certificate check
    would have continued it.  The first round always completes (a
    budgeted call returns the gap-zero bands' candidates at minimum),
    and `stats_out` reports `partial=True` with `cert_gap` = how far
    the certificate was from closing (max over queries and unvisited
    bands of `kth + PRUNE_MARGIN - prune_factor * gap`, 0 when it holds,
    inf when fewer than k rows were seen) — the serving layer's
    graceful-degradation contract.  Without a deadline (or when the walk
    finishes before expiry) results are exact and `partial` stays False.

    `init_kth` (f32, one entry per valid query) is a cross-partition upper
    bound on the GLOBAL k-th best value — the running bound a
    `repro.index.partition.PartitionSet` accumulates while walking sibling
    partitions.  The certificate then prunes against
    `min(local kth, init_kth)`: any band it discards holds only rows
    strictly farther than the global k-th neighbour, so the rows this walk
    returns are still a SUFFICIENT SET for the cross-partition
    (value, key)-lex merge — the merged answer stays bit-identical to one
    scan over the union.  With a finite bound the walk may stop before k
    local candidates exist (including before visiting any band at all);
    unfilled columns carry position -1 / value inf even in exact
    (non-partial) results, and merge away against any real candidate.

    Returns (positions (Q, k) int64 into b's rows, distances (Q, k) f32) —
    bit-identical to `topk_rows` over the same rows arranged in key order.
    Positions can be -1 (column unfilled) only in a partial result or
    under an `init_kth` bound.
    """
    a = jnp.asarray(a)
    q = a.shape[0] if q_valid is None else q_valid
    n_live = n_valid if alive is None else int(
        np.count_nonzero(alive[:n_valid]))
    k = min(k, n_live)
    if stats_out is not None:
        # filled below; pre-set so early returns still report a full record
        stats_out.update(n_bands=len(band_lo), bands_visited=0,
                         rows_visited=0, early_stop=False,
                         partial=False, cert_gap=0.0)
    if q == 0 or k == 0:
        return np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32)
    q_scores = np.asarray(q_scores, np.float64)
    factor = prune_factor(metric)
    n_bands = len(band_lo)
    # per-(query, band) weight-bound gaps; visit priority = nearest first
    gap = np.maximum(np.maximum(band_lo[None, :] - q_scores[:, None],
                                q_scores[:, None] - band_hi[None, :]), 0.0)
    if init_kth is not None:
        init_kth = np.asarray(init_kth, np.float32)[:q]
        if np.all(factor * gap >= init_kth[:, None] + PRUNE_MARGIN):
            # every band is already outside the cross-partition bound:
            # nothing here can enter the merged top-k, skip the walk
            if stats_out is not None:
                stats_out["early_stop"] = True
            return np.zeros((q, 0), np.int64), np.zeros((q, 0), np.float32)
    band_gap = gap.min(axis=0)
    visit = np.argsort(band_gap, kind="stable")

    best_v = np.full((q, k), np.inf, np.float32)
    best_key = np.full((q, k), KBEST_KEY_PAD, np.int64)
    best_pos = np.full((q, k), -1, np.int64)

    def band_range(bb: int) -> np.ndarray:
        return np.arange(bb * band_rows, min((bb + 1) * band_rows, n_valid))

    ptr = 0
    visited_rows = 0
    while ptr < n_bands:
        take = [visit[ptr]]
        ptr += 1
        if visited_rows == 0:
            # round 1: every band the weight bound cannot separate from some
            # query (gap == 0) — the bands the answers almost surely live in
            while ptr < n_bands and band_gap[visit[ptr]] <= 0.0:
                take.append(visit[ptr])
                ptr += 1
        else:
            target = max(visited_rows, band_rows)  # geometric expansion
            cnt = len(band_range(take[0]))
            while ptr < n_bands and cnt < target:
                take.append(visit[ptr])
                cnt += len(band_range(visit[ptr]))
                ptr += 1
        rows = np.concatenate([band_range(bb) for bb in take])
        if alive is not None:
            rows = rows[alive[rows]]  # tombstoned rows never reach a tile
        visited_rows += len(rows)
        if len(rows):
            keys = rows if order_by is None else np.asarray(order_by)[rows]
            rows = rows[np.argsort(keys, kind="stable")]  # cols in key order
            sub = packing.padded_take(b, rows)
            kk = min(k, len(rows))
            pos_c, val_c = topk_rows(a, sub, kk, d=d, metric=metric,
                                     block=block, mode=mode,
                                     m_valid=len(rows))
            gpos = rows[pos_c[:q]]
            gkey = gpos if order_by is None else np.asarray(order_by)[gpos]
            if kk < k:  # pad the chunk's candidate list to k columns
                padw = ((0, 0), (0, k - kk))
                val_c = np.pad(val_c[:q], padw, constant_values=np.inf)
                gpos = np.pad(gpos, padw, constant_values=-1)
                gkey = np.pad(gkey, padw, constant_values=KBEST_KEY_PAD)
            else:
                val_c = val_c[:q]
            best_v, best_key, best_pos = kbest_lex_merge(
                k, np.concatenate([best_v, val_c], axis=1),
                np.concatenate([best_key, gkey], axis=1),
                np.concatenate([best_pos, gpos], axis=1))
        if ptr >= n_bands:
            break
        kth = best_v[:, k - 1]
        if init_kth is not None:
            kth = np.minimum(kth, init_kth)
        bound = factor * gap[:, visit[ptr:]]
        if np.all(bound >= kth[:, None] + PRUNE_MARGIN):
            if stats_out is not None:
                stats_out["early_stop"] = True
            break
        if deadline is not None and deadline.expired:
            # budget exhausted before the certificate closed: stop here
            # and report the residual gap — the distance the kth bound
            # would have to move for the partial answer to be provably
            # exact (inf when fewer than k candidates were even seen)
            if stats_out is not None:
                stats_out["partial"] = True
                stats_out["cert_gap"] = float(np.max(np.maximum(
                    kth[:, None] + PRUNE_MARGIN - bound, 0.0)))
            break
    if stats_out is not None:
        stats_out["bands_visited"] = ptr
        stats_out["rows_visited"] = visited_rows
    return best_pos, best_v


# ---------------------------------------------------------------------------
# row sums (k-medoid centre update)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("block", "metric", "mode", "d"))
def _rowsum_impl(a_p, b_p, m, *, block, metric, mode, d):
    # m is a TRACED scalar: rowsum is called from the k-mode medoid loop
    # with a different member count per cluster per iteration, so the jit
    # cache must key on the (power-of-two bucketed) shapes only
    n_tiles = b_p.shape[0] // block

    def body(t, acc):
        j0 = t * block
        b_blk = jax.lax.dynamic_slice(b_p, (j0, 0), (block, b_p.shape[1]))
        dist = _tile_dist(a_p, b_blk, d, metric, mode)
        col = j0 + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        dist = jnp.where(col < m, dist, 0.0)
        return acc + jnp.sum(dist, axis=1)

    return jax.lax.fori_loop(
        0, n_tiles, body, jnp.zeros((a_p.shape[0],), jnp.float32))


def rowsum(a, b=None, *, d: int, metric: str = "cham", block: int = 2048,
           mode: str | None = None, m_valid: int | None = None) -> np.ndarray:
    """Per-row total distance to all rows of b (b=None: of a itself),
    streaming over blocks of b.  Used for medoid selection; shapes are
    bucketed to powers of two so repeated calls with varying row counts
    (the k-mode medoid loop) reuse a handful of compiled graphs.

    `m_valid` declares how many leading rows of b (of a, when b is None)
    are real: columns past it contribute zero.  It is traced — the k-mode
    medoid loop passes `padded_take` member gathers whose pad rows
    REPLICATE row 0 and must not be counted.  Rows of a past the valid
    count still get (meaningless) sums; callers slice them off."""
    a = jnp.asarray(a)
    b = a if b is None else jnp.asarray(b)
    n, m = a.shape[0], b.shape[0] if m_valid is None else m_valid
    if not 0 <= m <= b.shape[0]:
        raise ValueError(f"m_valid={m} outside the {b.shape[0]} supplied "
                         "rows")
    a_p = _pow2_rows(a)
    b_p2 = _pow2_rows(b)
    block = max(1, min(block, b_p2.shape[0]))
    b_p = _pad_rows(b_p2, block)
    out = _rowsum_impl(a_p, b_p, jnp.int32(m), block=block, metric=metric,
                       mode=_auto_mode(mode), d=d)
    return np.asarray(out)[:n]
