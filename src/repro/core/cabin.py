"""Cabin: the paper's sketching algorithm (Algorithm 1).

Two stages:
  1. BinEm   — category mapping psi(i, a) -> {0,1} turns a categorical vector
               u in {0..c}^n into a binary vector u' in {0,1}^n (same dim).
  2. BinSketch — attribute mapping pi(i) -> {0..d-1} ORs bits into d buckets.

Both stages are one-pass and stateless (hash-derived mappings, DESIGN.md 1.1).
Sketches are produced directly in packed int32 form; the n-dimensional binary
intermediate is only materialised by the explicit `binem` API (used by the
paper's Figure-4 analysis) — the fused paths never allocate it at full width
per batch beyond the input itself.

Two input layouts are supported:
  * dense:  x (N, n) int32, 0 = missing feature.
  * sparse: (indices (N, m), values (N, m)) padded COO rows; value 0 = pad.
    This is the layout for the million-dimension datasets (Table 1).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import hashing, packing


def _derive_seeds(seed: int) -> tuple[int, int]:
    s = int(hashing.mix32(jnp.uint32(seed * 2 + 1)))
    return s & 0x7FFFFFFF, int(hashing.mix32(jnp.uint32(s + 17))) & 0x7FFFFFFF


@dataclass(frozen=True)
class CabinParams:
    """Static description of a Cabin sketcher: dims + hash seeds."""

    n_dims: int  # original dimension n
    sketch_dim: int  # d
    psi_seed: int
    pi_seed: int

    @classmethod
    def create(cls, n_dims: int, sketch_dim: int, seed: int = 0) -> "CabinParams":
        psi, pi = _derive_seeds(seed)
        return cls(n_dims=n_dims, sketch_dim=sketch_dim, psi_seed=psi, pi_seed=pi)

    @property
    def packed_width(self) -> int:
        return packing.packed_width(self.sketch_dim)


# ---------------------------------------------------------------------------
# Stage 1: BinEm
# ---------------------------------------------------------------------------


def binem(params: CabinParams, x: jnp.ndarray) -> jnp.ndarray:
    """BinEm on dense categorical rows: (..., n) {0..c} -> (..., n) {0,1}."""
    n = x.shape[-1]
    idx = jnp.arange(n, dtype=jnp.uint32)
    return hashing.psi_bits(idx, x, params.psi_seed)


# ---------------------------------------------------------------------------
# Stage 2: BinSketch (+ fused Cabin)
# ---------------------------------------------------------------------------


def binsketch(params: CabinParams, bits: jnp.ndarray) -> jnp.ndarray:
    """BinSketch on dense binary rows: (..., n) {0,1} -> packed (..., w) int32."""
    n = bits.shape[-1]
    buckets = hashing.pi_buckets(jnp.arange(n, dtype=jnp.uint32),
                                 params.sketch_dim, params.pi_seed)
    d = params.sketch_dim
    # OR-aggregation == max-aggregation on {0,1}: scatter-max into d buckets.
    flat = bits.reshape(-1, n)
    out = jnp.zeros((flat.shape[0], d), dtype=flat.dtype)
    out = out.at[:, buckets].max(flat, mode="drop")
    out = out.reshape(*bits.shape[:-1], d)
    return packing.pack_bits(out)


def sketch_dense(params: CabinParams, x: jnp.ndarray) -> jnp.ndarray:
    """Cabin on dense categorical rows -> packed sketches (..., w) int32."""
    return binsketch(params, binem(params, x))


def sketch_sparse_jnp(
    params: CabinParams, indices: jnp.ndarray, values: jnp.ndarray
) -> jnp.ndarray:
    """jnp reference path for Cabin on padded-COO rows: per-row scatter-max.

    This is the oracle the fused Pallas kernel
    (repro.kernels.cabin_build_sparse) is tested against bit-for-bit, and
    the fallback `sketch_sparse` uses when the sketch dim is not 128-aligned
    or no accelerator is present.
    """
    bits = hashing.psi_bits(indices.astype(jnp.uint32), values, params.psi_seed)
    buckets = hashing.pi_buckets(indices.astype(jnp.uint32),
                                 params.sketch_dim, params.pi_seed)
    bits = jnp.where(values != 0, bits, 0)
    m = indices.shape[-1]
    flat_bits = bits.reshape(-1, m)
    flat_buckets = buckets.reshape(-1, m)
    out = jnp.zeros((flat_bits.shape[0], params.sketch_dim), dtype=jnp.int32)
    out = jax.vmap(lambda o, b, v: o.at[b].max(v, mode="drop"))(
        out, flat_buckets, flat_bits
    )
    out = out.reshape(*indices.shape[:-1], params.sketch_dim)
    return packing.pack_bits(out)


def sketch_sparse(
    params: CabinParams,
    indices: jnp.ndarray,
    values: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Cabin on padded-COO rows -> packed sketches (..., w) int32.

    indices: (..., m) int32 feature positions; values: (..., m) categories,
    0 = padding / missing (psi maps it to 0, so padded entries can share
    index 0 safely).

    Dispatch: when the sketch dim is 128-aligned and a TPU is present (or the
    kernel is explicitly requested via use_pallas=True, e.g. under
    interpret=True in tests), the fused Pallas kernel
    repro.kernels.cabin_build_sparse builds the packed sketch in one pass;
    otherwise the jnp scatter-max reference path runs.  Both produce
    bit-identical output.
    """
    if use_pallas is None:
        use_pallas = (jax.default_backend() == "tpu"
                      and params.sketch_dim % 128 == 0)
    if use_pallas and params.sketch_dim % 128 == 0:
        # lazy import: repro.kernels.* imports this module for CabinParams
        from repro.kernels.cabin_build_sparse import kernel as _sparse_kernel

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        m = indices.shape[-1]
        lead = indices.shape[:-1]
        out = _sparse_kernel.cabin_build_sparse(
            indices.reshape(-1, m),
            values.reshape(-1, m),
            d=params.sketch_dim,
            psi_seed=params.psi_seed,
            pi_seed=params.pi_seed,
            interpret=bool(interpret),
        )
        return out.reshape(*lead, params.packed_width)
    return sketch_sparse_jnp(params, indices, values)


@functools.partial(jax.jit, static_argnums=0)
def sketch_dense_jit(params: CabinParams, x: jnp.ndarray) -> jnp.ndarray:
    return sketch_dense(params, x)


@functools.partial(jax.jit, static_argnums=0)
def sketch_sparse_jit(
    params: CabinParams, indices: jnp.ndarray, values: jnp.ndarray
) -> jnp.ndarray:
    return sketch_sparse(params, indices, values)
