"""Cham: Hamming-distance estimation from Cabin sketches (Algorithm 2).

Implements the BinSketch estimator the paper defers to ([33, Alg. 2]); the
formula printed in the provided text is PDF-garbled (see DESIGN.md 1.1).

Derivation, with d bins, D = 1 - 1/d, sketch weights wu = |u~|, wv = |v~| and
sketch inner product st = <u~, v~>:

  E[wu]           = d (1 - D^a)            a = |u'| (pre-sketch density)
  E[wu + wv - st] = d (1 - D^(a+b-ip))     bins hit by the support UNION
so
  a_hat  = log(1 - wu/d) / log D
  U_hat  = log(1 - (wu + wv - st)/d) / log D
  ip_hat = a_hat + b_hat - U_hat
  h_hat  = a_hat + b_hat - 2 ip_hat = 2 U_hat - a_hat - b_hat

and Cham(u~, v~) = 2 h_hat (Lemma 2: HD(u,v) = 2 E[HD(u',v')]).

Also provides the BinSketch bonus estimators (inner product / cosine /
Jaccard on the pre-sketch binary vectors) and all-pairs matrix forms used by
heatmap / clustering / dedup workloads.  The all-pairs packed popcount matmul
has a Pallas TPU kernel twin in repro.kernels.hamming.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing

_EPS = 1e-9


def _safe_log1m(x: jnp.ndarray) -> jnp.ndarray:
    """log(1 - x), clamped: saturated sketches (x -> 1) clip to a full bin."""
    return jnp.log(jnp.clip(1.0 - x, _EPS, 1.0))


def density_estimate(weight: jnp.ndarray, d: int) -> jnp.ndarray:
    """Estimate pre-sketch Hamming weight from sketch weight (BinSketch)."""
    log_d = jnp.log1p(-1.0 / d)
    return _safe_log1m(weight.astype(jnp.float32) / d) / log_d


def binhamming_from_stats(
    wu: jnp.ndarray, wv: jnp.ndarray, inner: jnp.ndarray, d: int,
    *, obs_u: jnp.ndarray | None = None, obs_v: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """h_hat = estimated HD(u', v') from sketch statistics (broadcasting).

    obs_u / obs_v (keyword-only, broadcasting like wu / wv) are per-row
    OBSERVED-dimension counts under the miss model of Shen et al. (online
    categorical sketching with misses): a row whose record dropped some
    categories can have at most obs set bits, so the density and union
    estimates are clamped into the feasible polytope
        a_hat <= obs_u,  b_hat <= obs_v,  max(a,b) <= u_hat <= a_hat + b_hat
    before the distance is formed.  With both None (the default) the
    arithmetic is bit-identical to the unmasked estimator — serving paths
    that never see misses pay nothing.  A saturated sketch of a heavily
    truncated row otherwise explodes a_hat through the log and corrupts
    every distance against it; clamping degrades it gracefully to "as far
    as its observed support allows".
    """
    log_d = jnp.log1p(-1.0 / d)
    wu = wu.astype(jnp.float32)
    wv = wv.astype(jnp.float32)
    st = inner.astype(jnp.float32)
    a_hat = _safe_log1m(wu / d) / log_d
    b_hat = _safe_log1m(wv / d) / log_d
    u_hat = _safe_log1m((wu + wv - st) / d) / log_d
    if obs_u is not None:
        a_hat = jnp.minimum(a_hat, obs_u.astype(jnp.float32))
    if obs_v is not None:
        b_hat = jnp.minimum(b_hat, obs_v.astype(jnp.float32))
    if obs_u is not None or obs_v is not None:
        u_hat = jnp.clip(u_hat, jnp.maximum(a_hat, b_hat), a_hat + b_hat)
    return jnp.maximum(2.0 * u_hat - a_hat - b_hat, 0.0)


def binhamming(u: jnp.ndarray, v: jnp.ndarray, d: int,
               *, obs_u: jnp.ndarray | None = None,
               obs_v: jnp.ndarray | None = None) -> jnp.ndarray:
    """BinHamming on packed sketches (..., w) -> estimated HD(u', v')."""
    wu = packing.popcount_rows(u)
    wv = packing.popcount_rows(v)
    inner = packing.packed_inner(u, v)
    return binhamming_from_stats(wu, wv, inner, d, obs_u=obs_u, obs_v=obs_v)


def cham(u: jnp.ndarray, v: jnp.ndarray, d: int,
         *, obs_u: jnp.ndarray | None = None,
         obs_v: jnp.ndarray | None = None) -> jnp.ndarray:
    """Cham(u~, v~) = 2 * BinHamming — estimates HD of the ORIGINAL vectors."""
    return 2.0 * binhamming(u, v, d, obs_u=obs_u, obs_v=obs_v)


def inner_estimate(u: jnp.ndarray, v: jnp.ndarray, d: int) -> jnp.ndarray:
    """Estimated <u', v'> (BinSketch Theorem 1 quantity)."""
    wu = packing.popcount_rows(u)
    wv = packing.popcount_rows(v)
    st = packing.packed_inner(u, v)
    log_d = jnp.log1p(-1.0 / d)
    a_hat = _safe_log1m(wu.astype(jnp.float32) / d) / log_d
    b_hat = _safe_log1m(wv.astype(jnp.float32) / d) / log_d
    u_hat = _safe_log1m((wu + wv - st).astype(jnp.float32) / d) / log_d
    return jnp.maximum(a_hat + b_hat - u_hat, 0.0)


def cosine_estimate(u: jnp.ndarray, v: jnp.ndarray, d: int) -> jnp.ndarray:
    wu = density_estimate(packing.popcount_rows(u), d)
    wv = density_estimate(packing.popcount_rows(v), d)
    ip = inner_estimate(u, v, d)
    return ip / jnp.maximum(jnp.sqrt(wu * wv), _EPS)


def jaccard_estimate(u: jnp.ndarray, v: jnp.ndarray, d: int) -> jnp.ndarray:
    wu = density_estimate(packing.popcount_rows(u), d)
    wv = density_estimate(packing.popcount_rows(v), d)
    ip = inner_estimate(u, v, d)
    return ip / jnp.maximum(wu + wv - ip, _EPS)


# ---------------------------------------------------------------------------
# All-pairs (matrix) forms — heatmaps, RMSE, k-mode, dedup.
# ---------------------------------------------------------------------------


def sketch_stats_matrix(
    a: jnp.ndarray, b: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pairwise (wa, wb, inner) between packed rows a (N, w) and b (M, w).

    jnp reference path: O(N*M*w) popcounts.  The Pallas kernel in
    repro.kernels.hamming computes the same tiled in VMEM.
    """
    wa = packing.popcount_rows(a)
    wb = packing.popcount_rows(b)
    inner = jnp.sum(
        packing.popcount32(a[:, None, :] & b[None, :, :]), axis=-1
    )
    return wa, wb, inner


def cham_matrix(a: jnp.ndarray, b: jnp.ndarray, d: int) -> jnp.ndarray:
    """All-pairs Cham estimates: (N, w), (M, w) packed -> (N, M) float32."""
    wa, wb, inner = sketch_stats_matrix(a, b)
    return 2.0 * binhamming_from_stats(wa[:, None], wb[None, :], inner, d)


def hamming_matrix_exact(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Exact pairwise HD between packed BINARY rows (used on u'/full data)."""
    return jnp.sum(packing.popcount32(a[:, None, :] ^ b[None, :, :]), axis=-1)
