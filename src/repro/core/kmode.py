"""k-mode clustering (Huang'98): k-means analogue under Hamming distance.

Used by the paper for ground-truth clustering on the full categorical data
and for clustering binary sketches (binary vectors are categorical with c=2).
NumPy host implementation with chunked distance computation; deterministic
k-means++-style seeding so all methods start from identical centres (the
paper fixes the seed across baselines for exactly this reason).

`kmode_precomputed` additionally supports packed Cabin sketches directly
(sketch_dim=...): assignment and medoid updates then stream through
repro.core.allpairs on device instead of calling a host distance oracle.
"""

from __future__ import annotations

import numpy as np


def _hamming_to_centers(x: np.ndarray, centers: np.ndarray,
                        chunk: int = 512) -> np.ndarray:
    n, k = x.shape[0], centers.shape[0]
    out = np.empty((n, k), dtype=np.int32)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        out[lo:hi] = (x[lo:hi, None, :] != centers[None, :, :]).sum(axis=2)
    return out


def _plusplus_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    d = (x != centers[0]).sum(axis=1).astype(np.float64)
    for _ in range(1, k):
        p = d / max(d.sum(), 1e-12)
        idx = rng.choice(n, p=p)
        centers.append(x[idx])
        d = np.minimum(d, (x != centers[-1]).sum(axis=1))
    return np.stack(centers)


def _modes(x: np.ndarray, labels: np.ndarray, k: int, n_cats: int) -> np.ndarray:
    """Per-cluster per-attribute mode via a (n_attrs, n_cats) count table."""
    n_attr = x.shape[1]
    centers = np.zeros((k, n_attr), dtype=x.dtype)
    cols = np.arange(n_attr)
    for c in range(k):
        members = x[labels == c]
        if len(members) == 0:
            continue
        table = np.zeros((n_attr, n_cats + 1), dtype=np.int32)
        for row in members:
            table[cols, row] += 1
        centers[c] = table.argmax(axis=1).astype(x.dtype)
    return centers


def kmode(
    x: np.ndarray,
    k: int,
    n_iter: int = 15,
    seed: int = 0,
    n_categories: int | None = None,
    n_init: int = 3,
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster rows of categorical matrix x into k clusters.

    Runs `n_init` k-means++-seeded restarts and keeps the one with the
    lowest within-cluster Hamming cost (standard restart practice; a single
    unlucky seeding otherwise dominates the comparison).
    Returns (labels (N,), centers (k, n_attrs)).
    """
    x = np.ascontiguousarray(x)
    if n_categories is None:
        n_categories = int(x.max())
    best = None
    for trial in range(max(n_init, 1)):
        rng = np.random.default_rng(seed * 1000 + trial)
        centers = _plusplus_init(x, k, rng)
        labels = np.zeros(x.shape[0], dtype=np.int64)
        for _ in range(n_iter):
            dist = _hamming_to_centers(x, centers)
            new_labels = dist.argmin(axis=1)
            if np.array_equal(new_labels, labels):
                labels = new_labels
                break
            labels = new_labels
            centers = _modes(x, labels, k, n_categories)
        cost = int(_hamming_to_centers(x, centers)[
            np.arange(x.shape[0]), labels].sum())
        if best is None or cost < best[0]:
            best = (cost, labels, centers)
    return best[1], best[2]


def kmode_precomputed(
    dist_fn,
    x_repr: np.ndarray,
    k: int,
    n_iter: int = 15,
    seed: int = 0,
    *,
    sketch_dim: int | None = None,
    block: int = 2048,
) -> np.ndarray:
    """k-medoids-flavoured variant: centres are member rows, assignment is
    nearest-centre under an estimated distance.

    Two modes:

    * `sketch_dim` given — x_repr is a matrix of PACKED Cabin sketches
      (N, d/32) int32 and every distance pass (seeding, assignment, medoid
      update) runs on the streaming all-pairs engine
      (repro.core.allpairs) under the Cham metric: assignment is a
      device-resident row-argmin against the centre block, medoid updates
      are streaming row-sums — no (N, k) or (s, s) float matrix is built on
      host.  `dist_fn` is ignored and may be None.  This is the path the
      packed Pallas kernels drive on TPU.

    * `sketch_dim` None — legacy oracle mode: `dist_fn(a, b) -> (len(a),
      len(b))` distance matrix, evaluated on host per iteration (kept for
      arbitrary representations and as the equivalence reference).

    Both modes draw the identical rng sequence, so on the same
    representation they produce the same clustering.
    """
    n = x_repr.shape[0]
    use_engine = sketch_dim is not None
    if use_engine:
        from repro.core import allpairs  # local: keep numpy-only import path

        def col_dist(rows: np.ndarray, center: np.ndarray) -> np.ndarray:
            # distances of `rows` to ONE centre row: (len(rows),) float
            _, vals = allpairs.argmin_rows(rows, center[None, :],
                                           d=sketch_dim, block=block)
            return vals

    rng = np.random.default_rng(seed)
    center_idx = [int(rng.integers(n))]
    if use_engine:
        d = col_dist(x_repr, x_repr[center_idx[0]]).astype(np.float64)
    else:
        d = np.asarray(dist_fn(x_repr, x_repr[center_idx]))[:, 0].astype(np.float64)
    for _ in range(1, k):
        p = np.maximum(d, 0)
        p = p / max(p.sum(), 1e-12)
        center_idx.append(int(rng.choice(n, p=p)))
        if use_engine:
            d = np.minimum(d, col_dist(x_repr, x_repr[center_idx[-1]]))
        else:
            d = np.minimum(
                d, np.asarray(dist_fn(x_repr, x_repr[[center_idx[-1]]]))[:, 0])
    centers = x_repr[np.asarray(center_idx)]
    labels = np.zeros(n, dtype=np.int64)
    for _ in range(n_iter):
        if use_engine:
            new_labels, _ = allpairs.argmin_rows(x_repr, centers,
                                                 d=sketch_dim, block=block)
            new_labels = new_labels.astype(np.int64)
        else:
            dist = np.asarray(dist_fn(x_repr, centers))
            new_labels = dist.argmin(axis=1)
        if np.array_equal(new_labels, labels):
            break
        labels = new_labels
        # medoid update: member minimising total distance to cluster members
        for c in range(k):
            members = np.where(labels == c)[0]
            if len(members) == 0:
                continue
            if use_engine:
                totals = allpairs.rowsum(x_repr[members], d=sketch_dim,
                                         block=block)
            else:
                sub = np.asarray(dist_fn(x_repr[members], x_repr[members]))
                totals = sub.sum(axis=1)
            centers[c] = x_repr[members[totals.argmin()]]
    return labels
